"""Quickstart: stand up an AerialDB store, ingest a drone fleet, query it.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datastore import (StoreConfig, init_store, insert_step,
                                  make_pred, query_step)
from repro.core.placement import ShardMeta
from repro.data.synthetic import CityConfig, DroneFleet, make_sites


def main():
    # --- deployment: 12 edge servers over the city (paper §3.3) ---
    n_edges = 12
    sites = make_sites(n_edges, CityConfig(), seed=3)
    cfg = StoreConfig(n_edges=n_edges, sites=tuple(map(tuple, sites.tolist())),
                      tuple_capacity=1 << 14, index_capacity=2048,
                      max_shards_per_query=64, records_per_shard=30)
    state = init_store(cfg)
    alive = jnp.ones(n_edges, bool)

    # --- ingest: 16 drones x 4 collection rounds (paper §3.4) ---
    fleet = DroneFleet(16, records_per_shard=30)
    for r in range(4):
        payload, meta = fleet.next_shards()
        meta = ShardMeta(*[jnp.asarray(x) for x in meta])
        state, info = insert_step(cfg, state, jnp.asarray(payload), meta, alive)
    per_edge = np.asarray(state.tup_count)
    print(f"ingested {per_edge.sum()} tuple replicas "
          f"(balance: min={per_edge.min()} max={per_edge.max()})")

    # --- query: spatio-temporal AND predicate (paper §3.5, Fig 6) ---
    pred = make_pred(q=2,
                     lat0=[12.90, 12.85], lat1=[13.00, 13.10],
                     lon0=[77.50, 77.45], lon1=[77.60, 77.75],
                     t0=[0.0, 0.0], t1=[300.0, 1e9],
                     has_spatial=True, has_temporal=True, is_and=True)
    result, info = query_step(cfg, state, pred, alive, jax.random.key(0))
    for i in range(2):
        print(f"query {i}: count={int(result.count[i])} "
              f"mean_v={float(result.vsum[i]) / max(int(result.count[i]), 1):.2f} "
              f"edges_queried={int(info.subquery_edges[i])}")

    # --- resilience: kill two edges, same query, exact answer (§3.5.3) ---
    alive2 = alive.at[jnp.asarray([2, 7])].set(False)
    result2, _ = query_step(cfg, state, pred, alive2, jax.random.key(1))
    assert int(result2.count[1]) == int(result.count[1]), "lost data!"
    print("2 edges down -> identical results (3-replica guarantee holds)")


if __name__ == "__main__":
    main()
