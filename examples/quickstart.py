"""Quickstart: stand up an AerialDB deployment, ingest a drone fleet, query
it — all through the unified ``repro.api`` facade.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.api import AerialDB, Query
from repro.data.synthetic import CityConfig, DroneFleet, make_sites


def main():
    # --- deployment: 12 edge servers over the city (paper §3.3) ---
    n_edges = 12
    sites = make_sites(n_edges, CityConfig(), seed=3)
    db = AerialDB.open(n_edges=n_edges,
                       sites=tuple(map(tuple, sites.tolist())),
                       tuple_capacity=1 << 14, index_capacity=2048,
                       max_shards_per_query=64, records_per_shard=30)

    # --- ingest: 16 drones x 4 collection rounds, one fused dispatch ---
    fleet = DroneFleet(16, records_per_shard=30)
    payloads, metas = fleet.next_rounds(4)
    db.ingest_rounds(payloads, metas)
    per_edge = np.asarray(db.state.tup_count)
    print(f"ingested {per_edge.sum()} tuple replicas "
          f"(balance: min={per_edge.min()} max={per_edge.max()})")

    # --- query: spatio-temporal AND predicates, one compiled batch ---
    pred, spec = Query.batch(
        Query().bbox(12.90, 13.00, 77.50, 77.60).time(0.0, 300.0)
               .agg("count", "mean"),
        Query().bbox(12.85, 13.10, 77.45, 77.75).time(0.0, 1e9)
               .agg("count", "mean"))
    result, info = db.query((pred, spec))
    for i in range(2):
        print(f"query {i}: count={int(result.count[i])} "
              f"mean_v={float(result.vmean[i]):.2f} "
              f"edges_queried={int(info.subquery_edges[i])}")

    # --- resilience: kill two edges, same query, exact answer (§3.5.3) ---
    db.fail_edges(2, 7)
    result2, _ = db.query((pred, spec))
    assert int(result2.count[1]) == int(result.count[1]), "lost data!"
    db.recover_edges(2, 7)
    print("2 edges down -> identical results (3-replica guarantee holds)")


if __name__ == "__main__":
    main()
