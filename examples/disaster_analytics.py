"""End-to-end driver (the paper's kind is a datastore, so the end-to-end
scenario is serving spatio-temporal analytics under failures):

100 drones stream sensor shards into 20 edges while analyst clients issue
the paper's 9 query workloads; midway through, edges start failing. The
driver reports per-phase latency, completeness, and planner telemetry —
Fig 9 + Fig 14 as one live scenario.

    PYTHONPATH=src python examples/disaster_analytics.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro.api import AerialDB
from repro.core.datastore import StoreConfig, make_pred
from repro.data.synthetic import CityConfig, DroneFleet, make_sites

# sized for this repo's 1-core CPU host; scale freely on real metal
N_EDGES, N_DRONES, ROUNDS = 20, 50, 5


def analyst_queries(anchors, rng, q=8, km=1.0, secs=1800.0):
    pick = anchors[rng.integers(0, len(anchors), q)]
    deg = km / 111.0
    return make_pred(
        q=q, lat0=pick[:, 1] - deg / 2, lat1=pick[:, 1] + deg / 2,
        lon0=pick[:, 2] - deg / 2, lon1=pick[:, 2] + deg / 2,
        t0=pick[:, 0] - secs / 2, t1=pick[:, 0] + secs / 2,
        has_spatial=True, has_temporal=True, is_and=True)


def main():
    rng = np.random.default_rng(0)
    sites = make_sites(N_EDGES, CityConfig(), seed=3)
    cfg = StoreConfig(n_edges=N_EDGES, sites=tuple(map(tuple, sites.tolist())),
                      tuple_capacity=1 << 15, index_capacity=4096,
                      max_shards_per_query=256, records_per_shard=30,
                      planner="min_shards")
    db = AerialDB.open(cfg)
    fleet = DroneFleet(N_DRONES, records_per_shard=30)

    anchors = []
    total_expected = 0
    for r in range(ROUNDS):
        payload, meta = fleet.next_shards()
        t0 = time.perf_counter()
        db.insert(payload, meta)
        jax.block_until_ready(db.state.tup_count)
        anchors.append(payload.reshape(-1, payload.shape[-1])[:, :3])
        total_expected += payload.shape[0] * payload.shape[1]

        # mid-mission failures: one edge dies at rounds 3 and 4 (§3.5.3)
        phase = "all-up"
        if r == 2:
            db.fail_edges(int(rng.integers(N_EDGES)))
            phase = "1 edge down"
        if r == 3:
            db.fail_edges(int(rng.integers(N_EDGES)))
            phase = "2 edges down"

        pred = analyst_queries(np.concatenate(anchors), rng)
        tq = time.perf_counter()
        result, qinfo = db.query(pred, key=jax.random.key(r))
        jax.block_until_ready(result.count)
        catch_all = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True)
        # audit query touches every shard: use the vectorized random planner
        # (MinShards' greedy loop is for normal-sized result sets)
        audit_db = AerialDB(dataclasses.replace(cfg, planner="random"),
                            db.state, db.alive, jax.random.key(100 + r))
        full, _ = audit_db.query(catch_all)
        assert not bool(np.asarray(full.overflow)[0]), \
            "shard budget overflow — raise max_shards_per_query"
        completeness = int(full.count[0]) / total_expected
        print(f"round {r} [{phase:13s}] insert={(tq - t0) * 1e3:7.1f}ms "
              f"query(8)={(time.perf_counter() - tq) * 1e3:7.1f}ms "
              f"rows={np.asarray(result.count).mean():7.1f} "
              f"edges/query={np.asarray(qinfo.subquery_edges).mean():4.1f} "
              f"completeness={completeness:.4f}")

    assert completeness == 1.0, "<=2 failures must stay exact"
    print("mission complete: exact results under 2 edge failures")


if __name__ == "__main__":
    main()
