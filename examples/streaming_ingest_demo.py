"""Streaming ingest demo: ragged drone telemetry through ``IngestPipeline``.

A fleet of drones reports position + sensor records as they arrive — out of
order, with duplicate re-sends, seq gaps, and partial payloads. The pipeline
dedups and coalesces them into the store's device-shaped shard batches
(double-buffered against the device scan), and the O(drones) latest-per-drone
hot cache answers "where is every drone right now" without touching the log
scan — including records still in flight, via the pending overlay.

    PYTHONPATH=src python examples/streaming_ingest_demo.py

(The XLA flag below must be set before jax is imported: jax locks the host
device count at backend initialization.)
"""

import os

_FORCE = "--xla_force_host_platform_device_count"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=4").strip()

import numpy as np            # noqa: E402

from repro.api import AerialDB, Query, StoreConfig                   # noqa: E402
from repro.data.synthetic import CityConfig, make_sites              # noqa: E402
from repro.ingest import IngestPipeline                              # noqa: E402
from repro.launch.mesh import make_edge_mesh                         # noqa: E402

D, R, ROUNDS = 24, 4, 3       # drones, records per shard, telemetry rounds


def main():
    n_edges = 8
    sites = make_sites(n_edges, CityConfig(), seed=3)
    cfg = StoreConfig(n_edges=n_edges, sites=tuple(map(tuple, sites.tolist())),
                      tuple_capacity=1 << 12, index_capacity=512,
                      records_per_shard=R, max_drones=D)
    db = AerialDB.open(cfg, mesh=make_edge_mesh(4))
    pipe = IngestPipeline(db)
    rng = np.random.default_rng(11)
    city = CityConfig()

    for rnd in range(ROUNDS):
        # Every drone emits R sequenced records...
        drone = np.repeat(np.arange(D), R)
        seq = np.tile(np.arange(rnd * R, (rnd + 1) * R), D)
        n = drone.size
        t = seq + rng.uniform(0, 0.5, n)
        lat = rng.uniform(city.lat_min, city.lat_max, n)
        lon = rng.uniform(city.lon_min, city.lon_max, n)
        vals = rng.normal(size=(n, cfg.n_values))
        vals[rng.random(n) < 0.1, 2:] = np.nan       # partial payloads
        # ...but the uplink drops some, re-sends others, and shuffles all.
        idx = np.nonzero(rng.random(n) >= 0.05)[0]
        idx = np.concatenate([idx, idx[rng.random(idx.size) < 0.08]])
        rng.shuffle(idx)
        pipe.submit_arrays(drone[idx], seq[idx], t[idx], lat[idx], lon[idx],
                           vals[idx])
        fl = pipe.flush()                            # full shards -> device
        c = pipe.counters
        print(f"round {rnd}: submitted={idx.size} accepted={c['accepted']} "
              f"duplicate={c['duplicate']} partial={c['partial']} | "
              f"flushed {fl['flushed_records']} records "
              f"({fl['dispatches']} dispatches), pending={pipe.pending}")

    # Latest-per-drone: store hot cache (flushed) + pending overlay.
    record, valid = pipe.latest()
    print(f"latest(): {int(valid.sum())}/{D} drones tracked; drone 0 at "
          f"t={record[0, 0]:.2f} ({record[0, 1]:.4f}, {record[0, 2]:.4f})")
    # The same hot path through the query builder (flushed records only):
    res = db.query(Query().latest())
    print(f"Query().latest(): {int(np.asarray(res.valid).sum())}/{D} drones "
          f"queryable on-device")

    pipe.flush(drain=True)                           # ship sub-shard tails
    audit = pipe.reconcile()
    assert audit["ok"], audit
    print(f"reconcile: accepted={audit['accepted']} == "
          f"flushed={audit['flushed_records']} + pending={audit['pending']}; "
          f"stored={audit['stored_tuples']} == flushed x "
          f"replication={cfg.replication}  -> ok")


if __name__ == "__main__":
    main()
