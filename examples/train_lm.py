"""Train a small LM end-to-end on the AerialDB-backed data pipeline, with
checkpointing and a simulated restart (fault-tolerance path).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import time

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import AerialPipeline, PipelineConfig
from repro.models.model import Model
from repro.train import checkpoint as ckpt
from repro.train import optimizer as optlib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/aerialdb_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = ModelConfig(name="lm-8m", family="dense", n_layers=4, d_model=128,
                      n_heads=4, n_kv=2, d_head=32, d_ff=512, vocab=512,
                      loss_chunk=512, attn_chunk_kv=64)
    model = Model(cfg)
    pipe = AerialPipeline(PipelineConfig(vocab=cfg.vocab, batch=8, seq=64))
    opt_cfg = optlib.OptConfig(lr=3e-3, warmup_steps=20,
                               total_steps=args.steps)

    params = model.init(jax.random.key(0))
    opt_state = optlib.init_opt_state(opt_cfg, params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params; data plane: AerialDB "
          f"({pipe.store_cfg.n_edges} edges, 3x replication)")

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, m = optlib.adamw_update(opt_cfg, grads, opt_state,
                                                   params)
        return params, opt_state, loss

    start = 0
    if ckpt.latest_step(args.ckpt_dir) is not None:
        restored, start = ckpt.restore_checkpoint(
            args.ckpt_dir, {"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        print(f"resumed from checkpoint at step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = pipe.get_batch(step)      # deterministic in step => exact resume
        params, opt_state, loss = train_step(params, opt_state, batch)
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            ckpt.save_checkpoint(args.ckpt_dir, step + 1,
                                 {"params": params, "opt": opt_state})
            print(f"step {step+1:4d} loss={float(loss):.4f} "
                  f"({(time.time()-t0)/(step-start+1)*1e3:.0f} ms/step) [ckpt]")
    print("done")


if __name__ == "__main__":
    main()
