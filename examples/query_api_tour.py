"""Tour of the composable query/aggregation API (paper §4.5 workload shapes).

Walks every aggregate op (count / sum / min / max / mean), channel selection,
the AND and OR combinators, shard-id point lookups, batching, and the
failure-handling session methods — all through the ``repro.api`` facade, on a
small single-device deployment.

    PYTHONPATH=src python examples/query_api_tour.py
"""

import numpy as np

from repro.api import AGG_OPS, AerialDB, Query
from repro.data.synthetic import DroneFleet


def show(label, res, spec):
    view = {op: float(np.asarray(v)[0]) for op, v in res.view(spec).items()}
    cells = "  ".join(f"{op}={val:10.2f}" for op, val in view.items())
    print(f"  {label:<34} {cells}")


def main():
    # --- open + load: the facade owns state/alive/key plumbing ---
    db = AerialDB.open(n_edges=8, tuple_capacity=1 << 12, index_capacity=1024,
                       max_shards_per_query=64, records_per_shard=20)
    fleet = DroneFleet(12, records_per_shard=20, seed=7)
    payloads, metas = fleet.next_rounds(5)
    db.ingest_rounds(payloads, metas)
    t_max = float(payloads[..., 0].max())
    print(f"loaded {int(np.asarray(db.state.tup_count).sum())} tuple replicas "
          f"over {db.cfg.n_edges} edges, t in [0, {t_max:.0f}]s\n")

    # --- every aggregate, one channel at a time ---
    print("aggregates over the whole deployment (per sensor channel):")
    window = Query().bbox(12.85, 13.10, 77.45, 77.75).time(0.0, t_max)
    for ch in range(db.cfg.n_values):
        q = window.agg(*AGG_OPS, channel=ch)
        res, _ = db.query(q)
        show(f"channel {ch}: all ops", res, q.spec)

    # --- single-op requests: .view projects what was asked for ---
    print("\nsingle-op requests:")
    for op in AGG_OPS:
        q = window.agg(op, channel=2)
        res, _ = db.query(q)
        show(f'.agg("{op}", channel=2)', res, q.spec)

    # --- fused multi-channel: every channel's aggregates from ONE scan ---
    print("\nmulti-channel (one scan of the log answers all channels):")
    q_mc = window.agg("count", "mean", "max",
                      channels=tuple(range(db.cfg.n_values)))
    res, _ = db.query(q_mc)
    view = res.view(q_mc.spec)               # count (Q,), others (Q, K)
    for ch in range(db.cfg.n_values):
        print(f"  channel {ch}: count={int(view['count'][0]):6d} "
              f"mean={float(view['mean'][0, ch]):8.2f} "
              f"max={float(view['max'][0, ch]):8.2f}")

    # --- AND combinator: tuples must satisfy every clause ---
    print("\ncombinators:")
    left = Query().bbox(12.90, 13.00, 77.50, 77.65)
    right = Query().time(0.0, t_max / 3)
    q_and = (left & right).agg("count", "mean")
    res, _ = db.query(q_and)
    show("bbox & time  (AND)", res, q_and.spec)

    # --- OR combinator: tuples may satisfy any clause ---
    q_or = (left | right).agg("count", "mean")
    res, _ = db.query(q_or)
    show("bbox | time  (OR)", res, q_or.spec)

    # --- shard-id point lookup chained with a time window ---
    q_sid = Query().shard(3, 1).time(0.0, t_max).agg("count", "min", "max")
    res, _ = db.query(q_sid)
    show("shard(3,1) & time", res, q_sid.spec)

    # --- a batch: one compiled scan answers all three spatial sizes ---
    print("\nbatched queries (one dispatch):")
    deg = 1.0 / 111.0
    # Center the boxes on a really-inserted tuple (analysts query where
    # drones actually flew), so the small windows are non-empty.
    anchor = payloads.reshape(-1, payloads.shape[-1])[100]
    center_lat, center_lon = float(anchor[1]), float(anchor[2])
    sizes = {"200m": 0.2 * deg, "1km": deg, "5km": 5 * deg}
    pred, spec = Query.batch(*[
        Query().bbox(center_lat - d / 2, center_lat + d / 2,
                     center_lon - d / 2, center_lon + d / 2)
               .time(0.0, t_max).agg("count", "mean")
        for d in sizes.values()])
    res, info = db.query((pred, spec))
    for i, name in enumerate(sizes):
        print(f"  {name:>5} box: count={int(res.count[i]):6d} "
              f"mean={float(res.vmean[i]):8.2f} "
              f"edges={int(info.subquery_edges[i])}")

    # --- failures: the session re-plans around dead edges ---
    print("\nresilience:")
    q = window.agg("count", channel=0)
    before, _ = db.query(q)
    db.fail_edges(1, 5)
    during, info = db.query(q)
    db.recover_edges(1, 5)
    after, _ = db.query(q)
    print(f"  count before/during/after 2 edge failures: "
          f"{int(before.count[0])}/{int(during.count[0])}/"
          f"{int(after.count[0])} "
          f"(replication covers dead edges; broadcast={bool(info.broadcast[0])})")

    # --- validation: inverted ranges raise instead of matching nothing ---
    print("\nvalidation:")
    try:
        Query().bbox(13.10, 12.85, 77.45, 77.75)
    except ValueError as e:
        print(f"  inverted bbox      -> ValueError: {str(e)[:58]}...")
    try:
        Query().time(600.0, 0.0)
    except ValueError as e:
        print(f"  inverted time      -> ValueError: {str(e)[:58]}...")
    try:
        db.query(window.agg("count", channel=99))
    except ValueError as e:
        print(f"  channel overflow   -> ValueError: {str(e)[:58]}...")
    try:
        (left & Query().time(0, 1)) | Query().shard(0, 0)
    except ValueError as e:
        print(f"  (A&B)|C            -> ValueError: {str(e)[:58]}...")


if __name__ == "__main__":
    main()
