"""Serve a small LM with batched requests through the decode engine —
the serve_step path the decode_* dry-run cells lower at production scale.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.serve.engine import Engine, ServeConfig


def main():
    cfg = ModelConfig(name="lm-serve", family="dense", n_layers=4, d_model=128,
                      n_heads=4, n_kv=2, d_head=32, d_ff=512, vocab=512,
                      attn_chunk_kv=64)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    engine = Engine(model, params, ServeConfig(max_new_tokens=24, max_seq=128))

    rng = np.random.default_rng(0)
    batch = rng.integers(1, cfg.vocab, (8, 12)).astype(np.int32)  # 8 requests
    t0 = time.time()
    out = engine.generate(batch)
    dt = time.time() - t0
    n_tok = out.size
    print(f"served 8 requests x 24 new tokens in {dt:.2f}s "
          f"({n_tok/dt:.0f} tok/s on CPU)")
    print("sample continuation ids:", out[0][:12].tolist())


if __name__ == "__main__":
    main()
