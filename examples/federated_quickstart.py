"""Federated quickstart: the same AerialDB deployment on a 4-device edge mesh.

Each device of the ``("edge",)`` mesh plays two of the eight ground edge
servers: StoreState arrays are sharded on their leading E dim, inserts and
queries run through shard_map (device-local scans, metadata-scale
collectives), and — the point of the exercise — results are identical to the
single-device jit path.

    PYTHONPATH=src python examples/federated_quickstart.py

(The XLA flag below must be set before jax is imported: jax locks the host
device count at backend initialization.)
"""

import os

_FORCE = "--xla_force_host_platform_device_count"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=4").strip()

import jax                    # noqa: E402
import jax.numpy as jnp       # noqa: E402
import numpy as np            # noqa: E402

from repro.core.datastore import (StoreConfig, init_store, insert_step,  # noqa: E402
                                  make_pred, query_step)
from repro.core.placement import ShardMeta                               # noqa: E402
from repro.data.synthetic import CityConfig, DroneFleet, make_sites      # noqa: E402
from repro.distributed.federation import (federated_query_step,          # noqa: E402
                                          ingest_rounds, shard_store)
from repro.launch.mesh import make_edge_mesh                             # noqa: E402


def main():
    n_edges, n_dev = 8, 4
    mesh = make_edge_mesh(n_dev)
    print(f"edge mesh: {n_dev} devices x {n_edges // n_dev} edges each "
          f"({jax.device_count()} host devices)")

    sites = make_sites(n_edges, CityConfig(), seed=3)
    cfg = StoreConfig(n_edges=n_edges, sites=tuple(map(tuple, sites.tolist())),
                      tuple_capacity=1 << 13, index_capacity=1024,
                      max_shards_per_query=64, records_per_shard=30)
    alive = jnp.ones(n_edges, bool)

    # --- ingest: 16 drones x 4 rounds, one fused lax.scan dispatch ---
    fleet = DroneFleet(16, records_per_shard=30)
    payloads, metas = fleet.next_rounds(4)
    fed_state, _ = ingest_rounds(cfg, shard_store(init_store(cfg), mesh),
                                 payloads, metas, alive, mesh=mesh)
    per_edge = np.asarray(fed_state.tup_count)
    print(f"ingested {per_edge.sum()} tuple replicas across the mesh "
          f"(per-edge min={per_edge.min()} max={per_edge.max()})")

    # --- the same rounds through the single-device jit path ---
    ref_state = init_store(cfg)
    for i in range(payloads.shape[0]):
        meta = ShardMeta(*[jnp.asarray(np.asarray(f)[i]) for f in metas])
        ref_state, _ = insert_step(cfg, ref_state, jnp.asarray(payloads[i]),
                                   meta, alive)

    # --- differential check: same query, both runtimes ---
    pred = make_pred(q=2,
                     lat0=[12.90, 12.85], lat1=[13.00, 13.10],
                     lon0=[77.50, 77.45], lon1=[77.60, 77.75],
                     t0=[0.0, 0.0], t1=[300.0, 1e9],
                     has_spatial=True, has_temporal=True, is_and=True)
    key = jax.random.key(0)
    fed_res, fed_info = federated_query_step(cfg, fed_state, pred, alive,
                                             key, mesh)
    ref_res, _ = query_step(cfg, ref_state, pred, alive, key)

    for i in range(2):
        print(f"query {i}: sharded count={int(fed_res.count[i])} "
              f"(single-device {int(ref_res.count[i])}), "
              f"edges_queried={int(fed_info.subquery_edges[i])}")
    np.testing.assert_array_equal(np.asarray(fed_res.count),
                                  np.asarray(ref_res.count))
    state_equal = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ref_state), jax.tree.leaves(fed_state)))
    print(f"sharded == single-device: results exact, state identical="
          f"{state_equal}")


if __name__ == "__main__":
    main()
