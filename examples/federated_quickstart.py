"""Federated quickstart: the same AerialDB deployment on a 4-device edge mesh.

Each device of the ``("edge",)`` mesh plays two of the eight ground edge
servers. Both deployments are driven through the unified ``repro.api``
facade — ``AerialDB.open`` with a mesh shards the state and routes every
operation through shard_map; without one it runs the single-device jit path —
and, the point of the exercise, the results are identical.

    PYTHONPATH=src python examples/federated_quickstart.py

(The XLA flag below must be set before jax is imported: jax locks the host
device count at backend initialization.)
"""

import os

_FORCE = "--xla_force_host_platform_device_count"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=4").strip()

import jax                    # noqa: E402
import numpy as np            # noqa: E402

from repro.api import AerialDB, Query, StoreConfig                       # noqa: E402
from repro.data.synthetic import CityConfig, DroneFleet, make_sites      # noqa: E402
from repro.launch.mesh import make_edge_mesh                             # noqa: E402


def main():
    n_edges, n_dev = 8, 4
    mesh = make_edge_mesh(n_dev)
    print(f"edge mesh: {n_dev} devices x {n_edges // n_dev} edges each "
          f"({jax.device_count()} host devices)")

    sites = make_sites(n_edges, CityConfig(), seed=3)
    cfg = StoreConfig(n_edges=n_edges, sites=tuple(map(tuple, sites.tolist())),
                      tuple_capacity=1 << 13, index_capacity=1024,
                      max_shards_per_query=64, records_per_shard=30)

    # --- one facade per runtime: the dispatch is the ONLY difference ---
    fed = AerialDB.open(cfg, mesh=mesh)
    ref = AerialDB.open(cfg)

    # --- ingest: 16 drones x 4 rounds, one fused lax.scan dispatch ---
    payloads, metas = DroneFleet(16, records_per_shard=30).next_rounds(4)
    fed.ingest_rounds(payloads, metas)
    ref.ingest_rounds(payloads, metas)
    per_edge = np.asarray(fed.state.tup_count)
    print(f"ingested {per_edge.sum()} tuple replicas across the mesh "
          f"(per-edge min={per_edge.min()} max={per_edge.max()})")

    # --- differential check: the same built queries, both runtimes ---
    queries = Query.batch(
        Query().bbox(12.90, 13.00, 77.50, 77.60).time(0.0, 300.0)
               .agg("count", "mean"),
        Query().bbox(12.85, 13.10, 77.45, 77.75).time(0.0, 1e9)
               .agg("count", "mean"))
    key = jax.random.key(0)
    fed_res, fed_info = fed.query(queries, key=key)
    ref_res, _ = ref.query(queries, key=key)

    for i in range(2):
        print(f"query {i}: sharded count={int(fed_res.count[i])} "
              f"mean={float(fed_res.vmean[i]):.2f} "
              f"(single-device {int(ref_res.count[i])}), "
              f"edges_queried={int(fed_info.subquery_edges[i])}")
    np.testing.assert_array_equal(np.asarray(fed_res.count),
                                  np.asarray(ref_res.count))
    state_equal = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ref.state), jax.tree.leaves(fed.state)))
    print(f"sharded == single-device: results exact, state identical="
          f"{state_equal}")


if __name__ == "__main__":
    main()
