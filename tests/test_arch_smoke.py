"""Per-architecture smoke tests: reduced config of the same family, one
forward + loss + grad step AND one decode step on CPU; asserts output shapes
and finiteness (no NaNs). Exercises the exact code paths the dry-run lowers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_MODULES, get_config, list_configs, reduce_for_smoke
from repro.models.model import Model

ARCH_IDS = ["internlm2-1.8b", "qwen3-14b", "deepseek-7b", "stablelm-12b",
            "grok-1-314b", "deepseek-v2-236b", "seamless-m4t-large-v2",
            "zamba2-1.2b", "qwen2-vl-72b", "falcon-mamba-7b"]

# The compile-heaviest archs (MoE / SSM / enc-dec) dominate suite wall time;
# marked slow so `-m "not slow"` gives a quick pass. Tier-1 still runs them.
_HEAVY = {"deepseek-v2-236b", "zamba2-1.2b", "seamless-m4t-large-v2",
          "falcon-mamba-7b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
               for a in ARCH_IDS]

B, S = 2, 64


def make_batch(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {}
    if cfg.family == "encdec":
        es = max(S // cfg.enc_seq_ratio, 1)
        batch["enc_embeds"] = jax.random.normal(ks[0], (B, es, cfg.d_model),
                                                jnp.float32)
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    elif cfg.embed_input:
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_and_grad(arch):
    cfg = reduce_for_smoke(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))

    hidden, aux = jax.jit(model.forward)(params, batch)
    assert hidden.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), float(loss)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # loss magnitude sane for random init: ~ln(vocab)
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_decode_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    max_seq = 32
    cache = model.init_cache(B, max_seq)
    if cfg.embed_input:
        inputs = {"embeds": jax.random.normal(jax.random.key(2),
                                              (B, 1, cfg.d_model), jnp.float32)}
    else:
        inputs = {"tokens": jnp.ones((B, 1), jnp.int32)}

    step = jax.jit(model.decode_step)
    cache, logits = step(params, cache, inputs, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    cache, logits2 = step(params, cache, inputs, jnp.int32(1))
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_all_configs_registered():
    cfgs = list_configs()
    assert len(cfgs) == 10
    for a in ARCH_IDS:
        assert a in cfgs


def test_exact_assigned_dimensions():
    """Configs must match the assigned table exactly."""
    table = {
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
    }
    for name, (nl, d, h, kv, ff, v) in table.items():
        cfg = get_config(name)
        assert cfg.n_layers == nl and cfg.d_model == d, name
        assert cfg.n_heads == h and cfg.n_kv == kv, name
        ff_got = cfg.d_ff_expert if name == "deepseek-v2-236b" else cfg.d_ff
        assert ff_got == ff, name
        assert cfg.vocab == v, name
    assert get_config("grok-1-314b").n_experts == 8
    assert get_config("deepseek-v2-236b").n_experts == 160
    assert get_config("deepseek-v2-236b").top_k == 6
    assert get_config("deepseek-v2-236b").kv_lora == 512
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("falcon-mamba-7b").ssm_state == 16
