"""Deterministic fallback for the subset of the `hypothesis` API this repo
uses, for environments where the real package cannot be installed (the
canonical dependency is declared in pyproject's ``[test]`` extra and CI
installs it). ``tests/conftest.py`` installs this module under the
``hypothesis`` / ``hypothesis.strategies`` names only when the real import
fails, so test modules stay byte-identical either way.

Semantics: ``@given`` draws ``max_examples`` examples (default 25) from a PRNG
seeded by the test's qualified name, so runs are reproducible; there is no
shrinking or example database. ``assume(False)`` rejects the current example;
a test whose every example is rejected fails loudly rather than silently
passing.
"""

from __future__ import annotations

import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25
_REJECT_MULTIPLIER = 20      # draw budget per accepted example before giving up


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class SearchStrategy:
    def do_draw(self, rng: np.random.Generator):
        raise NotImplementedError


class _Floats(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.min_value = float(min_value)
        self.max_value = float(max_value)

    def do_draw(self, rng):
        # Hit the endpoints with elevated probability — boundary values are
        # where range/slicing properties break.
        u = rng.random()
        if u < 0.05:
            return self.min_value
        if u < 0.10:
            return self.max_value
        return float(rng.uniform(self.min_value, self.max_value))


class _Integers(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.min_value = int(min_value)
        self.max_value = int(max_value)

    def do_draw(self, rng):
        u = rng.random()
        if u < 0.05:
            return self.min_value
        if u < 0.10:
            return self.max_value
        return int(rng.integers(self.min_value, self.max_value + 1))


class _Lists(SearchStrategy):
    def __init__(self, elements, min_size, max_size):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size

    def do_draw(self, rng):
        n = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elements.do_draw(rng) for _ in range(n)]


class _Tuples(SearchStrategy):
    def __init__(self, strategies):
        self.strategies = strategies

    def do_draw(self, rng):
        return tuple(s.do_draw(rng) for s in self.strategies)


class _SampledFrom(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def do_draw(self, rng):
        return self.elements[int(rng.integers(0, len(self.elements)))]


class _Sets(SearchStrategy):
    def __init__(self, elements, min_size, max_size):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size

    def do_draw(self, rng):
        target = int(rng.integers(self.min_size, self.max_size + 1))
        out = set()
        for _ in range(1000):
            if len(out) >= target:
                break
            out.add(self.elements.do_draw(rng))
        if len(out) < self.min_size:
            raise UnsatisfiedAssumption()  # element domain too small
        return out


class _DataObject:
    """Interactive draws inside a test body (``st.data()``)."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.do_draw(self._rng)


class _Data(SearchStrategy):
    def do_draw(self, rng):
        return _DataObject(rng)


def floats(min_value=None, max_value=None, allow_nan=None, allow_infinity=None,
           width=64, **_):
    if min_value is None or max_value is None:
        raise NotImplementedError("fallback floats() needs explicit bounds")
    return _Floats(min_value, max_value)


def integers(min_value=None, max_value=None):
    if min_value is None or max_value is None:
        raise NotImplementedError("fallback integers() needs explicit bounds")
    return _Integers(min_value, max_value)


def lists(elements, min_size=0, max_size=None, **_):
    return _Lists(elements, min_size, max_size if max_size is not None
                  else min_size + 10)


def tuples(*strategies):
    return _Tuples(strategies)


def sets(elements, min_size=0, max_size=None, **_):
    return _Sets(elements, min_size, max_size if max_size is not None
                 else min_size + 10)


def sampled_from(elements):
    return _SampledFrom(elements)


def data():
    return _Data()


class settings:
    """Decorator recording max_examples; deadline/other knobs are ignored."""

    def __init__(self, deadline=None, max_examples=DEFAULT_MAX_EXAMPLES, **_):
        self.max_examples = max_examples

    def __call__(self, f):
        f._fallback_settings = self
        return f


def given(*strategies):
    def decorate(f):
        def runner():
            cfg = (getattr(runner, "_fallback_settings", None)
                   or getattr(f, "_fallback_settings", None))
            max_examples = cfg.max_examples if cfg else DEFAULT_MAX_EXAMPLES
            seed = zlib.crc32(f.__qualname__.encode())
            rng = np.random.default_rng(seed)
            executed = 0
            for _ in range(max_examples * _REJECT_MULTIPLIER):
                if executed >= max_examples:
                    break
                try:
                    args = [s.do_draw(rng) for s in strategies]
                    f(*args)
                except UnsatisfiedAssumption:
                    continue
                executed += 1
            if executed == 0:
                raise RuntimeError(
                    f"{f.__qualname__}: every generated example was rejected "
                    "by assume(); the strategy bounds are unsatisfiable")

        # Intentionally no functools.wraps: __wrapped__ would make pytest
        # resurrect the inner signature and demand fixtures for drawn args.
        runner.__name__ = f.__name__
        runner.__qualname__ = f.__qualname__
        runner.__doc__ = f.__doc__
        runner.__module__ = f.__module__
        if hasattr(f, "pytestmark"):
            runner.pytestmark = f.pytestmark
        return runner

    return decorate


strategies = types.ModuleType("hypothesis.strategies")
strategies.floats = floats
strategies.integers = integers
strategies.lists = lists
strategies.tuples = tuples
strategies.sets = sets
strategies.sampled_from = sampled_from
strategies.data = data
strategies.SearchStrategy = SearchStrategy
