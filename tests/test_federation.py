"""Differential test harness for the sharded federated runtime.

The core equivalence oracle: the same inserts and queries driven through the
single-device jit path (``insert_step``/``query_step``) and through the
shard_map path (``distributed.federation``) on a forced 4-host-device mesh —
parametrized over the 1-D ``(4,) ("edge",)`` layout AND the 2-D ``(2, 2)
("fleet", "edge")`` cross-host layout (hierarchical merge + double-buffered
query tiling) — must produce identical ``StoreState`` (bitwise — the sharded
path scatters the same values into the same slots) and identical
``QueryResult``/``QueryInfo``. The only tolerated difference is ``vsum`` (and
the derived ``vmean``), where the final (Q, E) combine crosses devices and
float accumulation order may differ; counts/min/max/telemetry are
order-independent and compared exactly. The same oracle is driven through the
unified ``repro.api`` facade (``AerialDB`` adopting each runtime) with
non-default ``AggSpec``s, pinning the whole generalized aggregation pipeline
— and the deprecated ``insert_step``/``query_step`` shims against it.

``tests/conftest.py`` forces ``--xla_force_host_platform_device_count=4``
before jax initializes, so the mesh is real multi-device even on CPU.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import AerialDB, AggSpec, Query
from repro.core.datastore import (StoreConfig, init_store, insert_step,
                                  make_pred, query_step)
from repro.core.placement import ShardMeta
from repro.data.synthetic import CityConfig, DroneFleet, make_sites
from repro.distributed.federation import (federated_insert_step,
                                          federated_query_step, ingest_rounds,
                                          shard_store, store_partition_specs)
from repro.distributed.sharding import mesh_edge_axes, mesh_edge_devices
from repro.launch.mesh import make_edge_mesh, make_fleet_mesh

N_DEV = 4
E = 8
ROUNDS = 6

pytestmark = pytest.mark.skipif(
    jax.device_count() < N_DEV,
    reason=f"needs {N_DEV} host devices (conftest forces them via XLA_FLAGS)")


def make_cfg(**overrides):
    sites = make_sites(E, CityConfig(), seed=3)
    kw = dict(n_edges=E, sites=tuple(map(tuple, sites.tolist())),
              tuple_capacity=2048, index_capacity=512, max_shards_per_query=64,
              records_per_shard=12, retention_every=2,
              # Latest-per-drone hot cache enabled everywhere: its replicated
              # state rides every bitwise state comparison below for free.
              max_drones=16)
    kw.update(overrides)
    return StoreConfig(**kw)


def fleet_rounds(n_drones=12, rounds=ROUNDS, records=12, seed=1):
    fleet = DroneFleet(n_drones, records_per_shard=records, seed=seed)
    return fleet.next_rounds(rounds)


def both_paths(cfg, mesh, payloads, metas, alive):
    """Drive identical inserts through both paths; returns (ref, fed) states."""
    ref = init_store(cfg)
    for i in range(payloads.shape[0]):
        meta = ShardMeta(*[jnp.asarray(np.asarray(f)[i]) for f in metas])
        ref, _ = insert_step(cfg, ref, jnp.asarray(payloads[i]), meta, alive)
    fed, _ = ingest_rounds(cfg, shard_store(init_store(cfg), mesh),
                           payloads, metas, alive, mesh=mesh)
    return ref, fed


def assert_states_identical(ref, fed):
    names = [jax.tree_util.keystr(p) for p, _
             in jax.tree_util.tree_flatten_with_path(ref)[0]]
    for name, a, b in zip(names, jax.tree.leaves(ref), jax.tree.leaves(fed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def assert_queries_identical(r1, i1, r2, i2):
    for f in r1._fields:
        a, b = np.asarray(getattr(r1, f)), np.asarray(getattr(r2, f))
        if f in ("vsum", "vmean"):  # cross-device accumulation order
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6, err_msg=f)
        else:
            np.testing.assert_array_equal(a, b, err_msg=f)
    for f in i1._fields:
        np.testing.assert_array_equal(np.asarray(getattr(i1, f)),
                                      np.asarray(getattr(i2, f)), err_msg=f)


@pytest.fixture(scope="module", params=["edge4", "fleet2x2"])
def mesh(request):
    """Every mesh-driven test below runs on BOTH datastore layouts: the 1-D
    ``(4,) ("edge",)`` mesh and the 2-D ``(2, 2) ("fleet", "edge")`` mesh
    (hierarchical candidate merge + double-buffered query tiling) — the
    same 4 devices, two mesh contracts, one single-device oracle."""
    if request.param == "edge4":
        return make_edge_mesh(N_DEV)
    return make_fleet_mesh(2, N_DEV // 2)


@pytest.fixture(scope="module")
def loaded(mesh):
    """One store, fully loaded through both paths (shared across tests —
    queries below are read-only)."""
    cfg = make_cfg()
    alive = jnp.ones(E, bool)
    payloads, metas = fleet_rounds()
    ref, fed = both_paths(cfg, mesh, payloads, metas, alive)
    return cfg, ref, fed, alive


QUERY_PREDS = {
    "and_spatiotemporal": make_pred(
        q=3, lat0=[12.85, 12.90, 12.95], lat1=[13.10, 13.00, 13.05],
        lon0=[77.45, 77.50, 77.55], lon1=[77.75, 77.60, 77.65],
        t0=[0.0, 0.0, 60.0], t1=[1e9, 120.0, 180.0],
        has_spatial=True, has_temporal=True, is_and=True),
    "or": make_pred(q=2, lat0=12.9, lat1=12.95, lon0=77.5, lon1=77.6,
                    t0=[0.0, 30.0], t1=[60.0, 90.0],
                    has_spatial=True, has_temporal=True, is_and=False),
    "sid_point": make_pred(q=2, sid_hi=[3, 7], sid_lo=[1, 4], has_sid=True,
                           is_and=True),
    "catch_all_temporal": make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True,
                                    is_and=True),
}


def test_insert_state_identical(loaded):
    """After N rounds (including retention sweeps: retention_every=2), every
    StoreState leaf — tuple ring, counters, and the whole index — is bitwise
    identical between the jit and shard_map paths."""
    _, ref, fed, _ = loaded
    assert int(np.asarray(ref.steps)) == ROUNDS  # sweeps actually ran
    assert_states_identical(ref, fed)


def test_insert_info_identical(mesh):
    """Per-step info (per-edge telemetry, replicas, retention watermark) is
    identical, round by round, including sweep rounds."""
    cfg = make_cfg()
    alive = jnp.ones(E, bool)
    payloads, metas = fleet_rounds(rounds=4)
    ref = init_store(cfg)
    fed = shard_store(init_store(cfg), mesh)
    for i in range(payloads.shape[0]):
        meta = ShardMeta(*[jnp.asarray(np.asarray(f)[i]) for f in metas])
        p = jnp.asarray(payloads[i])
        ref, ri = insert_step(cfg, ref, p, meta, alive)
        fed, fi = federated_insert_step(cfg, fed, p, meta, alive, mesh)
        for k in ri:
            np.testing.assert_array_equal(np.asarray(ri[k]), np.asarray(fi[k]),
                                          err_msg=f"round {i}: {k}")
    assert_states_identical(ref, fed)


@pytest.mark.parametrize("pred_name", sorted(QUERY_PREDS))
def test_query_identical(loaded, mesh, pred_name):
    cfg, ref, fed, alive = loaded
    pred = QUERY_PREDS[pred_name]
    key = jax.random.key(0)
    r1, i1 = query_step(cfg, ref, pred, alive, key)
    r2, i2 = federated_query_step(cfg, fed, pred, alive, key, mesh)
    assert_queries_identical(r1, i1, r2, i2)


@pytest.mark.parametrize("planner", ["random", "min_edges", "min_shards"])
def test_query_identical_across_planners(loaded, mesh, planner):
    """Planning runs replicated in the sharded path — same key, same
    assignment, identical QueryInfo (which exposes the assignment shape)."""
    cfg, ref, fed, alive = loaded
    cfg = dataclasses.replace(cfg, planner=planner)
    pred = QUERY_PREDS["and_spatiotemporal"]
    key = jax.random.key(7)
    r1, i1 = query_step(cfg, ref, pred, alive, key)
    r2, i2 = federated_query_step(cfg, fed, pred, alive, key, mesh)
    assert_queries_identical(r1, i1, r2, i2)


def test_query_identical_with_failures(loaded, mesh):
    """Edges die AFTER insertion (the paper's experiment shape — so the
    loaded store is reusable): lookup fallback, planner re-routing, and the
    scan must stay equivalent."""
    cfg, ref, fed, alive = loaded
    alive2 = alive.at[jnp.asarray([1, 5])].set(False)
    for name, pred in QUERY_PREDS.items():
        key = jax.random.key(11)
        r1, i1 = query_step(cfg, ref, pred, alive2, key)
        r2, i2 = federated_query_step(cfg, fed, pred, alive2, key, mesh)
        assert_queries_identical(r1, i1, r2, i2)


def test_query_identical_under_overflow(loaded, mesh):
    """max_shards_per_query smaller than the matched set (query-time config —
    the loaded state is layout-identical): the distributed top-S candidate
    merge must clip to exactly the same shard set and raise the same overflow
    flags as the single-device lookup."""
    cfg, ref, fed, alive = loaded
    cfg = dataclasses.replace(cfg, max_shards_per_query=4)
    pred = QUERY_PREDS["catch_all_temporal"]
    key = jax.random.key(3)
    r1, i1 = query_step(cfg, ref, pred, alive, key)
    r2, i2 = federated_query_step(cfg, fed, pred, alive, key, mesh)
    assert bool(np.asarray(r1.overflow).all())  # overflow actually exercised
    assert_queries_identical(r1, i1, r2, i2)


def test_broadcast_baseline_identical(mesh):
    """Feather-like config (no index, replication=1): the scan-all sentinel
    path through shard_map equals the jit path."""
    cfg = make_cfg(use_index=False, replication=1)
    alive = jnp.ones(E, bool)
    payloads, metas = fleet_rounds(seed=2, rounds=3)
    ref, fed = both_paths(cfg, mesh, payloads, metas, alive)
    assert_states_identical(ref, fed)
    pred = make_pred(q=1, lat0=12.9, lat1=13.0, lon0=77.5, lon1=77.65,
                     t0=0.0, t1=200.0, has_spatial=True, has_temporal=True)
    key = jax.random.key(4)
    r1, i1 = query_step(cfg, ref, pred, alive, key)
    r2, i2 = federated_query_step(cfg, fed, pred, alive, key, mesh)
    assert_queries_identical(r1, i1, r2, i2)


@pytest.mark.slow
def test_query_kernel_path_identical(loaded, mesh):
    """The Pallas st_scan kernel dispatches per-device inside shard_map; the
    sharded kernel path must equal the single-device kernel path."""
    cfg, ref, fed, alive = loaded
    pred = QUERY_PREDS["and_spatiotemporal"]
    key = jax.random.key(0)
    r1, i1 = query_step(cfg, ref, pred, alive, key, use_kernel=True,
                        interpret=True)
    r2, i2 = federated_query_step(cfg, fed, pred, alive, key, mesh,
                                  use_kernel=True, interpret=True)
    assert_queries_identical(r1, i1, r2, i2)


# ---------------------------------------------------------------------------
# Unified API facade: the same differential oracle, driven through AerialDB
# ---------------------------------------------------------------------------

AGG_SPECS = {
    "default": AggSpec(),
    "ch2_all": AggSpec(channel=2),
    "ch1_mean": AggSpec(channel=1, ops=("mean",)),
    "ch3_minmax": AggSpec(channel=3, ops=("min", "max")),
    # multi-channel: fused (Q, K) partials cross the device combine
    "multi_ch": AggSpec(channels=(0, 2, 3)),
}


@pytest.fixture(scope="module")
def loaded_facades(loaded, mesh):
    """AerialDB sessions adopting the PR-2-loaded states: one per runtime.
    The facade owns alive/key custody; explicit keys below keep the planner
    draws identical across paths."""
    cfg, ref, fed, alive = loaded
    return (AerialDB(cfg, ref, alive, jax.random.key(0)),
            AerialDB(cfg, fed, alive, jax.random.key(0), mesh=mesh))


@pytest.mark.parametrize("spec_name", sorted(AGG_SPECS))
@pytest.mark.parametrize("pred_name", sorted(QUERY_PREDS))
def test_facade_query_identical_per_aggspec(loaded_facades, spec_name,
                                            pred_name):
    """AerialDB.query with non-default AggSpecs: sharded and single-device
    results bit-identical (vsum/vmean up to cross-device accumulation
    order), for every predicate shape x channel/ops combination."""
    db_ref, db_fed = loaded_facades
    spec = AGG_SPECS[spec_name]
    key = jax.random.key(13)
    r1, i1 = db_ref.query(QUERY_PREDS[pred_name], agg=spec, key=key)
    r2, i2 = db_fed.query(QUERY_PREDS[pred_name], agg=spec, key=key)
    assert_queries_identical(r1, i1, r2, i2)


def test_facade_builder_query_identical(loaded_facades):
    """Builder-composed queries (AND/OR combinators, agg channels) through
    both runtimes — one compiled batch, identical answers."""
    db_ref, db_fed = loaded_facades
    q = Query.batch(
        Query().bbox(12.85, 13.10, 77.45, 77.75) & Query().time(0.0, 1e9),
        Query().bbox(12.9, 12.95, 77.5, 77.6) | Query().time(0.0, 60.0),
        Query().shard(3, 1).time(0.0, 1e9))
    pred, _ = q
    spec = AggSpec(channel=2, ops=("count", "mean"))
    key = jax.random.key(29)
    r1, i1 = db_ref.query((pred, spec), key=key)
    r2, i2 = db_fed.query((pred, spec), key=key)
    assert_queries_identical(r1, i1, r2, i2)
    assert set(r1.view(spec)) == {"count", "mean",
                                  "completeness_bound", "replicas_lost"}


def test_facade_ingest_and_failures_identical(mesh):
    """Full session lifecycle through the facade on both runtimes: fused
    ingest, edge failures, queries mid-failure, recovery — states bitwise
    identical and every answer equal."""
    cfg = make_cfg()
    db_ref = AerialDB.open(cfg)
    db_fed = AerialDB.open(cfg, mesh=mesh)
    payloads, metas = fleet_rounds(seed=31, rounds=4)
    db_ref.ingest_rounds(payloads, metas)
    db_fed.ingest_rounds(payloads, metas)
    assert_states_identical(db_ref.state, db_fed.state)

    db_ref.fail_edges(1, 5)
    db_fed.fail_edges(1, 5)
    q = Query().time(0.0, 1e9).agg("count", "mean", channel=1)
    key = jax.random.key(7)
    r1, i1 = db_ref.query(q, key=key)
    r2, i2 = db_fed.query(q, key=key)
    assert_queries_identical(r1, i1, r2, i2)

    # Insert while edges are down, then recover: still identical.
    p, m = DroneFleet(6, records_per_shard=12, seed=8).next_shards()
    db_ref.insert(p, m)
    db_fed.insert(p, m)
    db_ref.recover_edges(1, 5)
    db_fed.recover_edges(1, 5)
    assert_states_identical(db_ref.state, db_fed.state)
    r1, i1 = db_ref.query(q, key=key)
    r2, i2 = db_fed.query(q, key=key)
    assert_queries_identical(r1, i1, r2, i2)


def test_query_identical_whole_device_dead(loaded, mesh):
    """An ENTIRE device's edge block dies (edges 2·E/N..3·E/N): its local
    index matches, candidate contributions, and scan partials must all mask
    out identically in both runtimes, for every predicate shape."""
    cfg, ref, fed, alive = loaded
    block = jnp.arange(2 * (E // N_DEV), 3 * (E // N_DEV))
    alive2 = alive.at[block].set(False)
    for name, pred in QUERY_PREDS.items():
        key = jax.random.key(17)
        r1, i1 = query_step(cfg, ref, pred, alive2, key)
        r2, i2 = federated_query_step(cfg, fed, pred, alive2, key, mesh)
        assert_queries_identical(r1, i1, r2, i2)


def test_facade_device_failure_and_repair_identical(mesh):
    """The full failure-domain lifecycle through the facade on both
    runtimes: device failure, during-outage ingest, recovery with the
    anti-entropy repair pass — states bitwise identical and every answer
    equal at each stage (the repair pass is deterministic host-side work,
    re-sharded onto the mesh afterwards)."""
    cfg = make_cfg(n_failure_domains=N_DEV)
    db_ref = AerialDB.open(cfg)
    db_fed = AerialDB.open(cfg, mesh=mesh)
    fleet = DroneFleet(10, records_per_shard=12, seed=41)
    pay, met = fleet.next_rounds(2)
    db_ref.ingest_rounds(pay, met)
    db_fed.ingest_rounds(pay, met)

    db_ref.fail_device(1)
    db_fed.fail_device(1)
    assert int(db_ref.alive.sum()) == E - E // N_DEV
    np.testing.assert_array_equal(np.asarray(db_ref.alive),
                                  np.asarray(db_fed.alive))

    pay2, met2 = fleet.next_rounds(2)
    db_ref.ingest_rounds(pay2, met2)
    db_fed.ingest_rounds(pay2, met2)
    assert_states_identical(db_ref.state, db_fed.state)

    q = Query().time(0.0, 1e9).agg("count", "mean", channel=1)
    key = jax.random.key(19)
    r1, i1 = db_ref.query(q, key=key)
    r2, i2 = db_fed.query(q, key=key)
    assert_queries_identical(r1, i1, r2, i2)

    db_ref.recover_device(1)
    db_fed.recover_device(1)
    assert db_ref.last_repair == db_fed.last_repair
    assert db_ref.last_repair["shards_replaced"] > 0
    assert_states_identical(db_ref.state, db_fed.state)
    r1, i1 = db_ref.query(q, key=key)
    r2, i2 = db_fed.query(q, key=key)
    assert_queries_identical(r1, i1, r2, i2)
    # recovered + repaired: the full window is complete again
    total = int(np.prod(pay.shape[:3])) + int(np.prod(pay2.shape[:3]))
    assert int(np.asarray(r1.count)[0]) == total
    assert float(np.asarray(i1.completeness_bound)[0]) == 1.0


def test_shim_return_values_unchanged(loaded, mesh):
    """The deprecated insert_step/query_step shims still return exactly what
    the PR-2 harness pinned: default-AggSpec facade answers equal shim
    answers on the same loaded state, on both runtimes."""
    cfg, ref, fed, alive = loaded
    pred = QUERY_PREDS["and_spatiotemporal"]
    key = jax.random.key(0)
    r_shim, i_shim = query_step(cfg, ref, pred, alive, key)
    r_fed, i_fed = federated_query_step(cfg, fed, pred, alive, key, mesh)
    db_ref = AerialDB(cfg, ref, alive, jax.random.key(0))
    r_api, i_api = db_ref.query(pred, key=key)
    assert_queries_identical(r_shim, i_shim, r_api, i_api)
    assert_queries_identical(r_shim, i_shim, r_fed, i_fed)


def test_fused_ingest_matches_python_loop():
    """The lax.scan ingest driver (1-device) is bitwise equivalent to the
    sequential insert_step loop it replaces."""
    cfg = make_cfg()
    alive = jnp.ones(E, bool)
    payloads, metas = fleet_rounds(seed=13)
    ref = init_store(cfg)
    for i in range(payloads.shape[0]):
        meta = ShardMeta(*[jnp.asarray(np.asarray(f)[i]) for f in metas])
        ref, _ = insert_step(cfg, ref, jnp.asarray(payloads[i]), meta, alive)
    fused, info = ingest_rounds(cfg, init_store(cfg), payloads, metas, alive)
    assert_states_identical(ref, fused)
    # info is stacked over rounds
    assert np.asarray(info["intake_per_edge"]).shape == (ROUNDS, E)


def test_store_sharding_layout(mesh):
    """shard_store realizes the layout contract: leading-E arrays split into
    E/n_dev contiguous blocks, one per device (fleet-major on the 2-D mesh);
    the step counter replicates."""
    cfg = make_cfg()
    state = shard_store(init_store(cfg), mesh)
    assert len(state.tup_f.sharding.device_set) == N_DEV
    shard_shapes = {s.data.shape for s in state.tup_f.addressable_shards}
    assert shard_shapes == {(E // N_DEV,) + state.tup_f.shape[1:]}
    assert state.steps.sharding.is_fully_replicated
    axes = mesh_edge_axes(mesh)
    assert mesh_edge_devices(mesh) == N_DEV
    specs = store_partition_specs(axes)
    assert specs.tup_f[0] == axes  # leading E dim over the axis product


def test_partition_specs_congruent_with_state(mesh):
    """Property: the ``store_partition_specs`` pytree is structure-congruent
    with ``StoreState`` (including the nested ``IndexState``) under both the
    1-D and 2-D mesh contracts, and every per-edge leaf (leading logical-E
    dim) is partitioned over exactly the mesh's edge-bearing axes — so a
    future state field can't silently ship replicated-by-default or with a
    missing spec."""
    from jax.sharding import PartitionSpec as P
    cfg = make_cfg()
    state = init_store(cfg)
    axes = mesh_edge_axes(mesh)
    specs = store_partition_specs(axes)
    is_spec = lambda x: isinstance(x, P)
    assert (jax.tree.structure(specs, is_leaf=is_spec)
            == jax.tree.structure(state))
    spec_leaves = jax.tree_util.tree_flatten_with_path(specs,
                                                       is_leaf=is_spec)[0]
    for (path, spec), leaf in zip(spec_leaves, jax.tree.leaves(state)):
        name = jax.tree_util.keystr(path)
        leaf = np.asarray(leaf)
        if leaf.ndim == 0:
            assert spec == P(), name  # the one replicated scalar (steps)
            assert "steps" in name
        elif "latest" in name:
            # The latest-per-drone cache is the one replicated array family:
            # its leading dim is DRONES, and every device holds the whole
            # identically-updated copy.
            assert spec == P(), name
            assert leaf.shape[0] == cfg.max_drones, name
        else:
            assert spec == P(axes), name
            assert leaf.shape[0] == cfg.n_edges, name


def test_facade_latest_identical(loaded_facades):
    """AerialDB.latest() (and the Query().latest() dispatch): the replicated
    hot cache answers bitwise identically on the single-device and sharded
    runtimes, on both mesh layouts, and agrees with a brute-force max-t
    oracle over everything ever inserted (nothing aged out at this scale)."""
    db_ref, db_fed = loaded_facades
    l_ref = db_ref.latest()
    l_fed = db_fed.latest()
    for f in l_ref._fields:
        np.testing.assert_array_equal(np.asarray(getattr(l_ref, f)),
                                      np.asarray(getattr(l_fed, f)),
                                      err_msg=f)
    l_q = db_fed.query(Query().latest())
    for f in l_ref._fields:
        np.testing.assert_array_equal(np.asarray(getattr(l_fed, f)),
                                      np.asarray(getattr(l_q, f)), err_msg=f)
    # Against the host oracle (12 drones inserted, cache sized for 16).
    payloads, metas = fleet_rounds()
    p = np.asarray(payloads).reshape(-1, *payloads.shape[2:])   # (N*B, R, W)
    hi = np.asarray(metas.sid_hi).reshape(-1)
    rec = np.asarray(l_ref.record)
    seen = np.asarray(l_ref.valid)
    for d in range(db_ref.cfg.max_drones):
        rows = p[hi == d].reshape(-1, p.shape[-1])
        if rows.size == 0:
            assert not seen[d]
            continue
        assert seen[d]
        best = rows[np.argmax(rows[:, 0])]
        np.testing.assert_array_equal(rec[d], best)


def test_facade_latest_disabled_raises():
    db = AerialDB.open(make_cfg(max_drones=0))
    with pytest.raises(ValueError, match="max_drones"):
        db.latest()
    with pytest.raises(ValueError, match="max_drones"):
        db.query(Query().latest())


def test_mesh_divisibility_rejected(mesh):
    cfg = make_cfg(n_edges=6, sites=())
    with pytest.raises(ValueError, match="not divisible"):
        federated_query_step(cfg, init_store(cfg),
                             QUERY_PREDS["catch_all_temporal"],
                             jnp.ones(6, bool), jax.random.key(0), mesh)


def test_mesh_factories_validate_at_construction():
    """Satellite: the divisibility check moved into the mesh factories —
    both raise the shared actionable error at construction time instead of
    failing later inside the federated runtime."""
    with pytest.raises(ValueError, match="not divisible"):
        make_edge_mesh(N_DEV, n_edges=6)
    with pytest.raises(ValueError, match="not divisible"):
        make_fleet_mesh(2, N_DEV // 2, n_edges=6)
    with pytest.raises(ValueError, match="does not divide"):
        make_fleet_mesh(3)  # 3 fleets over 4 devices
    assert make_edge_mesh(N_DEV, n_edges=E).shape == {"edge": N_DEV}
    assert make_fleet_mesh(2, n_edges=E).shape == {"fleet": 2, "edge": 2}


def test_fleet_mesh_equals_edge_mesh():
    """The cross-mesh differential, stated directly: the SAME lifecycle
    (ingest -> device failure -> degraded ingest + query -> recover + repair
    -> query) on the (2, 2) fleet mesh and the (4,) 1-D mesh yields bitwise
    identical states and identical answers — the hierarchical merge and the
    double-buffered tiling change the schedule, never the result."""
    mesh_1d = make_edge_mesh(N_DEV)
    mesh_2d = make_fleet_mesh(2, N_DEV // 2)
    cfg = make_cfg(n_failure_domains=N_DEV)
    db1 = AerialDB.open(cfg, mesh=mesh_1d)
    db2 = AerialDB.open(cfg, mesh=mesh_2d)
    fleet = DroneFleet(10, records_per_shard=12, seed=43)
    pay, met = fleet.next_rounds(3)
    db1.ingest_rounds(pay, met)
    db2.ingest_rounds(pay, met)
    assert_states_identical(db1.state, db2.state)

    q = Query().time(0.0, 1e9).agg("count", "mean", channel=1)
    for db in (db1, db2):
        db.fail_device(1)
    pay2, met2 = fleet.next_rounds(1)
    db1.ingest_rounds(pay2, met2)
    db2.ingest_rounds(pay2, met2)
    key = jax.random.key(23)
    r1, i1 = db1.query(q, key=key)
    r2, i2 = db2.query(q, key=key)
    assert_queries_identical(r1, i1, r2, i2)

    db1.recover_device(1)
    db2.recover_device(1)
    assert db1.last_repair == db2.last_repair
    assert_states_identical(db1.state, db2.state)
    r1, i1 = db1.query(q, key=key)
    r2, i2 = db2.query(q, key=key)
    assert_queries_identical(r1, i1, r2, i2)
