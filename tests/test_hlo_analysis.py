"""Unit tests for the structural HLO analyzer on hand-written modules —
the roofline numbers are only as good as this parser."""

import textwrap

from repro.launch import hlo_analysis as H

MODULE = textwrap.dedent("""\
    HloModule test

    %body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16] get-tuple-element(%p), index=1
      %w = f32[16,16] constant({...})
      %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16] all-reduce(%dot.1), replica_groups={}
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
    }

    %cond.2 (p2: (s32[], f32[8,16])) -> pred[] {
      %p2 = (s32[], f32[8,16]) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(%i2, %n), direction=LT
    }

    ENTRY %main (a: f32[8,16]) -> f32[8,16] {
      %a = f32[8,16] parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]) tuple(%zero, %a)
      %w1 = (s32[], f32[8,16]) while(%init), condition=%cond.2, body=%body.1
      ROOT %out = f32[8,16] get-tuple-element(%w1), index=1
    }
""")


def test_trip_count_and_flops():
    comps, entry = H.parse_module(MODULE)
    assert entry == "main"
    assert set(comps) == {"body.1", "cond.2", "main"}
    counts = H.exec_counts(comps, entry)
    assert counts["body.1"] == 10         # loop bound from the condition
    assert counts["cond.2"] == 11
    res = H.analyze(MODULE)
    # dot: 2 * (8*16 out) * 16 contraction = 4096 flops, x10 trips
    assert res["flops"] == 10 * 2 * 8 * 16 * 16
    # all-reduce: 8*16*4B = 512 B x 10 trips; wire = 2x for the ring
    assert res["collectives"]["all-reduce"] == 10 * 512
    assert res["collectives"]["wire_bytes"] == 2 * 10 * 512
    assert res["collectives"]["counts"]["all-reduce"] == 10


def test_type_bytes_tuple_and_scalar():
    assert H.type_bytes("f32[8,16]") == 512
    assert H.type_bytes("(s32[], f32[8,16])") == 4 + 512
    assert H.type_bytes("bf16[2,3]{1,0}") == 12
    assert H.type_bytes("pred[]") == 1


def test_while_operand_not_charged():
    """Control-flow ops alias their carried tuple: charging it would count
    the full loop state as traffic once per while op."""
    res = H.analyze(MODULE)
    # traffic per iter: dot (x 512 + w 1024 + out 512), all-reduce
    # (512 + 512), add 12 — the 516 B while-carry tuple is never charged
    per_iter = (512 + 1024 + 512) + (512 + 512) + 12
    assert res["bytes_accessed"] <= 10 * per_iter + 200
