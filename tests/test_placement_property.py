"""Placement properties under arbitrary alive masks (paper §3.4.2 + §4.5.3).

The mass-failure contract (the `successor_resolve` total-failure bugfix):
``place_replicas`` returns, for every shard,

  * ``min(3, n_alive)`` real slots that are DISTINCT and ALIVE, and
  * the remaining slots explicitly degraded to ``-1`` — never a duplicate,
    never a dead edge (the historical fallback returned the unresolved hash
    candidate, which violated both and no caller handled it);

down to the 1-alive and 0-alive corners. With failure-domain spreading
(``n_domains > 1``) the real slots additionally span at least
``min(2, n_real_slots, #domains containing an alive edge)`` distinct
domains — the temporal replica avoids the spatial replica's domain whenever
possible — so a whole-device loss can never take out every copy (the sid
replica stays on the H_i successor chain so point-lookups keep working; see
``place_replicas``).

Runs under the real `hypothesis` package when installed, or the
deterministic fallback shim in tests/_hypothesis_fallback.py otherwise.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.placement import (ShardMeta, edge_domains, place_replicas,
                                  successor_resolve)
from repro.data.synthetic import CityConfig, make_sites

E = 12
SITES = jnp.asarray(make_sites(E, CityConfig(), seed=3))


def _meta(n, rng, city=CityConfig()):
    lat = rng.uniform(city.lat_min, city.lat_max, (n, 2)).astype(np.float32)
    lon = rng.uniform(city.lon_min, city.lon_max, (n, 2)).astype(np.float32)
    t = rng.uniform(0, 86400, (n, 2)).astype(np.float32)
    return ShardMeta(
        sid_hi=rng.integers(0, 100, n).astype(np.int32),
        sid_lo=rng.integers(0, 1 << 30, n).astype(np.int32),
        lat0=lat.min(1), lat1=lat.max(1),
        lon0=lon.min(1), lon1=lon.max(1),
        t0=t.min(1), t1=t.max(1))


def check_mass_failure_contract(reps, alive, n_domains=1):
    """The (B, 3) replica contract for ONE alive mask (module docstring)."""
    n_alive = int(alive.sum())
    dom = np.asarray(edge_domains(E, n_domains))
    n_alive_domains = len(set(dom[alive])) if n_alive else 0
    for row in reps:
        real = [int(r) for r in row if r >= 0]
        assert len(real) == min(3, n_alive), (row, alive)
        assert len(set(real)) == len(real), (row, alive)        # distinct
        assert all(alive[r] for r in real), (row, alive)        # alive
        # degraded slots trail (r0 fills first): -1s only after real slots
        k = len(real)
        assert all(int(r) == -1 for r in row[k:]), (row, alive)
        spanned = len({int(dom[r]) for r in real})
        assert spanned >= min(2, len(real), n_alive_domains), \
            (row, alive, dom, spanned)


@given(st.integers(min_value=0, max_value=E), st.data())
@settings(deadline=None, max_examples=30)
def test_replicas_mass_failure_contract(n_alive, data):
    """Random alive masks all the way down to 0 alive edges: slots are
    distinct+alive or explicitly -1, never a dead or duplicate id."""
    alive_idx = data.draw(st.sets(st.integers(0, E - 1), min_size=n_alive,
                                  max_size=n_alive))
    alive = np.zeros(E, bool)
    alive[list(alive_idx)] = True
    rng = np.random.default_rng(data.draw(st.integers(0, 1 << 30)))
    meta = _meta(8, rng)
    reps = np.asarray(place_replicas(meta, SITES, jnp.asarray(alive), 300.0))
    check_mass_failure_contract(reps, alive)


@given(st.integers(min_value=1, max_value=E), st.data())
@settings(deadline=None, max_examples=30)
def test_replicas_failure_domain_spreading(n_alive, data):
    """With contiguous failure domains, the replica set spans as many
    distinct domains as the alive mask allows — the invariant behind the
    'one device loss never loses all copies' durability claim."""
    n_domains = data.draw(st.sampled_from([2, 3, 4, 6]), label="domains")
    alive_idx = data.draw(st.sets(st.integers(0, E - 1), min_size=n_alive,
                                  max_size=n_alive))
    alive = np.zeros(E, bool)
    alive[list(alive_idx)] = True
    rng = np.random.default_rng(data.draw(st.integers(0, 1 << 30)))
    meta = _meta(8, rng)
    reps = np.asarray(place_replicas(meta, SITES, jnp.asarray(alive), 300.0,
                                     n_domains=n_domains))
    check_mass_failure_contract(reps, alive, n_domains=n_domains)


def test_one_alive_corner():
    """1 alive edge: every shard gets exactly (that edge, -1, -1)."""
    alive = np.zeros(E, bool)
    alive[5] = True
    meta = _meta(16, np.random.default_rng(0))
    reps = np.asarray(place_replicas(meta, SITES, jnp.asarray(alive), 300.0))
    assert (reps == np.asarray([5, -1, -1], np.int32)).all(), reps


def test_zero_alive_corner():
    """0 alive edges: all slots degrade to -1 (and successor_resolve itself
    returns the sentinel instead of the forbidden start edge)."""
    alive = np.zeros(E, bool)
    meta = _meta(4, np.random.default_rng(1))
    reps = np.asarray(place_replicas(meta, SITES, jnp.asarray(alive), 300.0))
    assert (reps == -1).all(), reps
    got = successor_resolve(jnp.asarray([3], jnp.int32),
                            jnp.ones((1, E), bool))
    assert int(got[0]) == -1


def test_spreading_never_packs_one_domain():
    """With >= 2 alive domains, a whole-domain loss leaves >= 1 replica:
    exhaustively over every shard of a large batch — no replica set may
    ever be contained in a single domain."""
    n_domains = 4
    meta = _meta(256, np.random.default_rng(2))
    reps = np.asarray(place_replicas(meta, SITES, jnp.ones(E, bool), 300.0,
                                     n_domains=n_domains))
    dom = np.asarray(edge_domains(E, n_domains))
    for row in reps:
        assert len(set(dom[row])) >= 2, (row, dom[row])


def test_spreading_keeps_sid_hash_replica():
    """The sid replica r_i must stay the plain successor of H_i(shardID)
    (spreading exempts it): when the hash edge is alive and distinct from
    r0/r1, r2 IS that edge — the invariant sid point-lookups rely on."""
    from repro.core import hashing
    meta = _meta(256, np.random.default_rng(4))
    reps = np.asarray(place_replicas(meta, SITES, jnp.ones(E, bool), 300.0,
                                     n_domains=4))
    cand_i = np.asarray(hashing.hash_shard_id(
        jnp.asarray(meta.sid_hi), jnp.asarray(meta.sid_lo), E))
    free = cand_i != reps[:, 0]
    free &= cand_i != reps[:, 1]
    assert free.any()
    np.testing.assert_array_equal(reps[free, 2], cand_i[free])


def test_single_domain_bit_identical_to_unconstrained():
    """n_domains=1 must not move a single replica (the single-device path
    is unchanged — the StoreConfig default)."""
    meta = _meta(128, np.random.default_rng(3))
    alive = jnp.ones(E, bool).at[jnp.asarray([2, 7])].set(False)
    a = np.asarray(place_replicas(meta, SITES, alive, 300.0))
    b = np.asarray(place_replicas(meta, SITES, alive, 300.0, n_domains=1))
    np.testing.assert_array_equal(a, b)


def test_edge_domains_validation():
    import pytest
    with pytest.raises(ValueError, match="divide"):
        edge_domains(E, 5)
    with pytest.raises(ValueError, match="divide"):
        edge_domains(E, 0)
