"""Numerical-equivalence tests between implementation variants: these pin
the semantics that the dry-run cells and §Perf variants rely on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, get_config, reduce_for_smoke
from repro.models import attention, mamba
from repro.models.model import Model


def test_flash_equals_naive_attention():
    key = jax.random.key(0)
    b, s, h, kv, dh = 2, 256, 8, 2, 32
    q = jax.random.normal(key, (b, s, h, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, dh), jnp.float32)
    for causal in (True, False):
        got = attention.flash_attention(q, k, v, causal=causal, chunk_kv=64)
        exp = attention.naive_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)


def test_flash_decode_offset():
    """Decode (Sq=1 at position p) must equal full-attention row p."""
    key = jax.random.key(1)
    b, s, h, kv, dh = 2, 128, 4, 4, 16
    q_full = jax.random.normal(key, (b, s, h, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, dh), jnp.float32)
    full = attention.naive_attention(q_full, k, v, causal=True)
    p = 77
    one = attention.flash_attention(q_full[:, p:p + 1], k, v, causal=True,
                                    q_offset=p, chunk_kv=32)
    np.testing.assert_allclose(np.asarray(one[:, 0]), np.asarray(full[:, p]),
                               rtol=2e-5, atol=2e-5)


def test_mamba1_associative_equals_sequential():
    rng = np.random.default_rng(0)
    b, s, di, n = 2, 64, 16, 4
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, di)).astype(np.float32))
    bm = jnp.asarray(rng.normal(0, 1, (b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(0, 1, (b, s, n)).astype(np.float32))
    xc = jnp.asarray(rng.normal(0, 1, (b, s, di)).astype(np.float32))
    a_log = jnp.asarray(rng.uniform(-1, 1, (di, n)).astype(np.float32))
    y1, h1 = mamba.selective_scan(dt, bm, cm, xc, a_log, chunk=16,
                                  mode="associative")
    y2, h2 = mamba.selective_scan(dt, bm, cm, xc, a_log, chunk=16,
                                  mode="sequential")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4,
                               atol=1e-4)


def test_ssd_chunked_equals_stepwise():
    """Mamba2 SSD chunked matmul form vs direct per-step recurrence."""
    rng = np.random.default_rng(1)
    b, s, h, p_dim, g, n = 1, 32, 2, 8, 1, 4
    xh = jnp.asarray(rng.normal(0, 1, (b, s, h, p_dim)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.3, (b, s, h)).astype(np.float32))
    a = jnp.asarray(-np.exp(rng.uniform(-1, 0.5, h)).astype(np.float32))
    bm = jnp.asarray(rng.normal(0, 1, (b, s, g, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(0, 1, (b, s, g, n)).astype(np.float32))
    h0 = jnp.zeros((b, h, n, p_dim), jnp.float32)
    y_got, h_got = mamba.ssd_chunked(xh, dt, a, bm, cm, h0, chunk=8)

    # stepwise oracle
    yo = np.zeros((b, s, h, p_dim), np.float32)
    hs = np.zeros((b, h, n, p_dim), np.float32)
    for t in range(s):
        for hh in range(h):
            decay = float(np.exp(dt[0, t, hh] * a[hh]))
            bx = np.outer(np.asarray(bm)[0, t, 0], np.asarray(xh)[0, t, hh]) \
                * float(dt[0, t, hh])
            hs[0, hh] = decay * hs[0, hh] + bx
            yo[0, t, hh] = np.asarray(cm)[0, t, 0] @ hs[0, hh]
    np.testing.assert_allclose(np.asarray(y_got), yo, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_got), hs, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "deepseek-v2-236b",
                                  "falcon-mamba-7b", "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the train-forward logits."""
    cfg = reduce_for_smoke(get_config(arch)).replace(
        param_dtype_str="float32", compute_dtype_str="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 1, 12
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    hidden, _ = model.forward(params, {"tokens": toks})
    full_logits = model.logits(params, hidden)           # (B, S, V)

    cache = model.init_cache(b, s)
    step = jax.jit(model.decode_step)
    dec = []
    for t in range(s):
        cache, lg = step(params, cache, {"tokens": toks[:, t:t + 1]},
                         jnp.int32(t))
        dec.append(np.asarray(lg))
    dec = np.stack(dec, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits), rtol=2e-3,
                               atol=2e-3)


def test_moe_dispatch_modes_agree():
    """GShard einsum dispatch vs scatter dispatch: same outputs."""
    from repro.models import moe as moe_lib
    cfg = ModelConfig(d_model=32, n_experts=4, top_k=2, d_ff_expert=16,
                      capacity_factor=8.0, n_shared=0,
                      param_dtype_str="float32", compute_dtype_str="float32")
    p = moe_lib.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 32), jnp.float32)
    y1, a1 = moe_lib.moe_apply(p, x, cfg.replace(moe_dispatch="einsum"))
    y2, a2 = moe_lib.moe_apply(p, x, cfg.replace(moe_dispatch="scatter"))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)
