"""Property tests for the query planners (paper §3.5.2, Alg. 1).

For random MatchedShards lookup results and random alive-masks, every planner
must satisfy the assignment contract the scan path relies on:

  1. soundness  — every non-(-1) assignment names an *alive* edge that really
                  is a replica of that (valid) shard;
  2. completeness — every valid shard with >= 1 alive replica is assigned
                  somewhere (no reachable shard is silently dropped);
  3. liveness   — no assignment ever targets a dead edge (explicitly asserted
                  for ``min_shards``, the paper's Alg. 1, but it holds for
                  all three and soundness implies it).

Runs under the real `hypothesis` package when installed, or the deterministic
fallback shim in tests/_hypothesis_fallback.py (same API) otherwise.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.index import MatchedShards
from repro.core.planner import plan

# Small fixed shape pool: properties are shape-generic, and reusing a few
# (S, E) combinations keeps the jitted while-loop planners' compile cache hot
# across examples. ``plan`` is jitted here because the bare function would
# re-trace its lax.while_loop on every drawn example.
SHAPES = [(4, 4), (8, 6), (12, 5)]
plan_jit = jax.jit(plan, static_argnums=(0,))


def build_case(data, planner_unused=None):
    s, e = SHAPES[data.draw(st.integers(0, len(SHAPES) - 1), label="shape")]
    draw_i = lambda lo, hi, n, label: np.asarray(
        [data.draw(st.integers(lo, hi), label=label) for _ in range(n)],
        np.int32)
    # Replica slots: mostly real edges, some -1 padding (unfilled slots).
    reps = draw_i(-1, e - 1, s * 3, "replica").reshape(s, 3)
    valid = draw_i(0, 1, s, "valid").astype(bool)
    alive = draw_i(0, 1, e, "alive").astype(bool)
    sid = np.arange(s, dtype=np.int32)
    matched = MatchedShards(
        sid_hi=jnp.asarray(sid[None]), sid_lo=jnp.asarray(sid[None]),
        replicas=jnp.asarray(reps[None]), valid=jnp.asarray(valid[None]),
        overflow=jnp.zeros((1,), jnp.bool_))
    return matched, reps, valid, jnp.asarray(alive), np.asarray(alive)


def check_contract(planner, matched, reps, valid, alive_np, assignment):
    s = reps.shape[0]
    alive_reps = (reps >= 0) & alive_np[np.clip(reps, 0, None)] & valid[:, None]
    reachable = alive_reps.any(axis=1)
    for i in range(s):
        a = int(assignment[0, i])
        if a != -1:
            # 1. soundness: assigned edge is an alive replica of a valid shard
            assert valid[i], (planner, i, a)
            assert a in reps[i], (planner, i, a, reps[i])
            assert alive_np[a], (planner, i, a)
        # 2. completeness: reachable shards are always assigned
        if reachable[i]:
            assert a != -1, (planner, i, reps[i], alive_np)


@given(st.data())
@settings(deadline=None, max_examples=25)
def test_planner_assignment_contract(data):
    """All three planners on the same drawn case (the hypothesis fallback
    shim can't combine @given with @pytest.mark.parametrize)."""
    matched, reps, valid, alive, alive_np = build_case(data)
    key = jax.random.key(data.draw(st.integers(0, 1 << 20), label="key"))
    for planner in ["random", "min_edges", "min_shards"]:
        assignment = np.asarray(plan_jit(planner, matched, alive, key))
        check_contract(planner, matched, reps, valid, alive_np, assignment)


@given(st.data())
@settings(deadline=None, max_examples=25)
def test_min_shards_never_assigns_dead_edge(data):
    """Paper Alg. 1 under random alive-masks: no sub-query may ever target a
    dead edge (the §3.5.3 failure-handling invariant)."""
    matched, reps, valid, alive, alive_np = build_case(data)
    assignment = np.asarray(plan_jit("min_shards", matched, alive, None))
    assigned = assignment[assignment >= 0]
    assert alive_np[assigned].all(), (assignment, alive_np)


def test_plan_random_tiling_invariant():
    """plan_random folds the key per GLOBAL query index, so a scalar key, the
    equivalent explicit (Q,) key batch, and any contiguous tiling of the
    batch all draw identical gumbels — the invariant the federated runtime's
    double-buffered query tiling (query_local overlap_tiles) relies on for
    bitwise equivalence."""
    rng = np.random.default_rng(5)
    q, s, e = 7, 6, 5
    reps = rng.integers(-1, e, size=(q, s, 3)).astype(np.int32)
    matched = MatchedShards(
        sid_hi=jnp.asarray(np.tile(np.arange(s, dtype=np.int32), (q, 1))),
        sid_lo=jnp.asarray(np.tile(np.arange(s, dtype=np.int32), (q, 1))),
        replicas=jnp.asarray(reps),
        valid=jnp.ones((q, s), bool),
        overflow=jnp.zeros((q,), jnp.bool_))
    alive = jnp.asarray(rng.integers(0, 2, size=e).astype(bool))
    key = jax.random.key(11)
    full = np.asarray(plan_jit("random", matched, alive, key))
    qkeys = jax.vmap(jax.random.fold_in, (None, 0))(key, jnp.arange(q))
    np.testing.assert_array_equal(
        full, np.asarray(plan_jit("random", matched, alive, qkeys)))
    for sl in (slice(0, 3), slice(3, 7), slice(2, 5)):
        tile = MatchedShards(*[f[sl] for f in matched])
        got = np.asarray(plan_jit("random", tile, alive, qkeys[sl]))
        np.testing.assert_array_equal(full[sl], got, err_msg=str(sl))


def test_planners_skip_fully_degraded_replica_rows():
    """Mass-failure placement degrades unsatisfiable replica slots to -1
    (down to ALL slots -1 when no edge was alive at insert time): every
    planner must leave such shards unassigned — -1 slots are skipped, never
    dereferenced as edge ids."""
    s, e = 6, 5
    reps = np.full((s, 3), -1, np.int32)
    reps[0] = [2, -1, -1]            # partially degraded: only edge 2 usable
    matched = MatchedShards(
        sid_hi=jnp.asarray(np.arange(s, dtype=np.int32)[None]),
        sid_lo=jnp.asarray(np.arange(s, dtype=np.int32)[None]),
        replicas=jnp.asarray(reps[None]),
        valid=jnp.ones((1, s), bool),
        overflow=jnp.zeros((1,), jnp.bool_))
    alive = jnp.ones(e, bool)
    for planner in ["random", "min_edges", "min_shards"]:
        a = np.asarray(plan_jit(planner, matched, alive, jax.random.key(0)))
        assert a[0, 0] == 2, (planner, a)
        assert (a[0, 1:] == -1).all(), (planner, a)
