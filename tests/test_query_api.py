"""Unified API tests: the ``Query`` builder, ``AggSpec`` pipeline, and the
``AerialDB`` session facade.

Three layers of guarantees:
  * builder-compiled ``QueryPred``s are field-identical to hand-built
    ``make_pred`` ones (hypothesis property over random clause sets), and
    invalid shapes — inverted ranges (the historical silently-empty-result
    bug), duplicate clauses, inexpressible (A AND B) OR C — raise eagerly;
  * every ``AggSpec`` (channel x ops) agrees with a numpy oracle and between
    the jnp-ref and Pallas-kernel engines (the federated path is covered in
    tests/test_federation.py on the 4-device mesh);
  * the facade's single-device dispatch returns exactly what the deprecated
    ``insert_step``/``query_step`` shims return — adopting the facade is
    observationally free.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import AGG_OPS, AerialDB, AggSpec, Query, make_pred
from repro.core.datastore import StoreConfig, init_store, insert_step, query_step
from repro.core.index import QueryPred
from repro.core.placement import ShardMeta
from repro.data.synthetic import CityConfig, DroneFleet, make_sites

E = 8


def small_cfg(**overrides):
    sites = make_sites(E, CityConfig(), seed=3)
    kw = dict(n_edges=E, sites=tuple(map(tuple, sites.tolist())),
              tuple_capacity=4096, index_capacity=512,
              max_shards_per_query=64, records_per_shard=12)
    kw.update(overrides)
    return StoreConfig(**kw)


@pytest.fixture(scope="module")
def loaded_db():
    """One facade-loaded store per module; query tests are read-only."""
    db = AerialDB.open(small_cfg())
    fleet = DroneFleet(12, records_per_shard=12, seed=5)
    payloads, metas = fleet.next_rounds(4)
    db.ingest_rounds(payloads, metas)
    flat = payloads.reshape(-1, payloads.shape[-1])
    return db, flat, metas


# ---------------------------------------------------------------------------
# Query builder: compilation equivalence + validation
# ---------------------------------------------------------------------------

def assert_preds_equal(got: QueryPred, exp: QueryPred):
    for f in QueryPred._fields:
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(exp, f)), err_msg=f)


@settings(deadline=None, max_examples=50)
@given(st.data())
def test_builder_matches_make_pred(data):
    """Property: any clause set the builder accepts compiles to exactly the
    QueryPred a hand-rolled make_pred call builds."""
    has_sp = data.draw(st.integers(0, 1), label="has_spatial")
    has_t = data.draw(st.integers(0, 1), label="has_temporal")
    has_sid = data.draw(st.integers(0, 1), label="has_sid")
    if not (has_sp or has_t or has_sid):
        has_t = 1
    n_clauses = has_sp + has_t + has_sid
    use_or = n_clauses >= 2 and data.draw(st.integers(0, 1), label="or")

    parts, kw = [], {}
    if has_sp:
        lats = sorted([data.draw(st.floats(-90, 90)) for _ in range(2)])
        lons = sorted([data.draw(st.floats(-180, 180)) for _ in range(2)])
        parts.append(Query().bbox(lats[0], lats[1], lons[0], lons[1]))
        kw.update(lat0=lats[0], lat1=lats[1], lon0=lons[0], lon1=lons[1],
                  has_spatial=True)
    if has_t:
        ts = sorted([data.draw(st.floats(0, 1e6)) for _ in range(2)])
        parts.append(Query().time(ts[0], ts[1]))
        kw.update(t0=ts[0], t1=ts[1], has_temporal=True)
    if has_sid:
        hi = data.draw(st.integers(0, 1 << 20))
        lo = data.draw(st.integers(0, 1 << 20))
        parts.append(Query().shard(hi, lo))
        kw.update(sid_hi=hi, sid_lo=lo, has_sid=True)

    combined = Query.any_of(*parts) if use_or else Query.all_of(*parts)
    got, spec = combined.build()
    exp = make_pred(q=1, is_and=not use_or, **kw)
    assert_preds_equal(got, exp)
    assert spec == AggSpec()

    # Chaining compiles identically to AND-combining.
    if not use_or:
        chained = parts[0]
        for p in parts[1:]:
            for kind in ("spatial", "temporal", "sid"):
                v = getattr(p, kind)
                if v is not None:
                    chained = chained._with_clause(kind, v)
        assert_preds_equal(chained.build()[0], exp)


def test_inverted_ranges_raise():
    """Regression: inverted ranges used to be silently accepted (empty
    results); the builder AND make_pred now raise with a clear message."""
    with pytest.raises(ValueError, match="inverted latitude"):
        Query().bbox(13.0, 12.9, 77.5, 77.6)
    with pytest.raises(ValueError, match="inverted longitude"):
        Query().bbox(12.9, 13.0, 77.6, 77.5)
    with pytest.raises(ValueError, match="inverted time"):
        Query().time(100.0, 0.0)
    with pytest.raises(ValueError, match="inverted lat range"):
        make_pred(q=1, lat0=13.0, lat1=12.9, has_spatial=True)
    with pytest.raises(ValueError, match="inverted t range"):
        make_pred(q=2, t0=[0.0, 50.0], t1=[10.0, 40.0], has_temporal=True)
    # Disabled clauses are not validated (their bounds are dead fields) ...
    make_pred(q=1, lat0=13.0, lat1=12.9, has_spatial=False)
    # ... OR predicates are exempt (an inverted clause contributes nothing
    # but the other clauses still match — the result is well-defined) ...
    make_pred(q=1, lat0=5.0, lat1=0.0, t0=0.0, t1=100.0,
              has_spatial=True, has_temporal=True, is_and=False)
    # ... and equal bounds are a valid (point) range.
    Query().time(5.0, 5.0)
    Query().bbox(12.9, 12.9, 77.5, 77.5)


def test_builder_rejects_inexpressible_shapes():
    a = Query().bbox(12.9, 13.0, 77.5, 77.6)
    b = Query().time(0.0, 60.0)
    c = Query().shard(1, 2)
    with pytest.raises(ValueError, match="already has a spatial clause"):
        a.bbox(12.0, 12.5, 77.0, 77.2)
    with pytest.raises(ValueError, match="both sides of & carry"):
        a & Query().bbox(12.0, 12.5, 77.0, 77.2)
    with pytest.raises(ValueError, match="cannot \\|-combine"):
        (a & b) | c
    with pytest.raises(ValueError, match="cannot &-combine"):
        (a | b) & c
    with pytest.raises(ValueError, match="empty query"):
        Query().build()
    with pytest.raises(TypeError, match="not a scalar"):
        Query().time([0.0, 1.0], 5.0)


def test_or_and_combinators_compile():
    a = Query().bbox(12.9, 13.0, 77.5, 77.6)
    b = Query().time(0.0, 60.0)
    p_or, _ = (a | b).build()
    assert not bool(p_or.is_and[0])
    assert bool(p_or.has_spatial[0]) and bool(p_or.has_temporal[0])
    p_and, _ = (a & b).build()
    assert bool(p_and.is_and[0])
    # any_of/all_of over three single clauses
    p3, _ = Query.any_of(a, b, Query().shard(2, 1)).build()
    assert not bool(p3.is_and[0]) and bool(p3.has_sid[0])


def test_agg_accumulates_and_validates():
    q = Query().time(0, 1).agg("count", channel=2).agg("mean", channel=2)
    assert q.spec == AggSpec(channel=2, ops=("count", "mean"))
    assert Query().time(0, 1).agg(channel=1).spec.ops == AGG_OPS
    with pytest.raises(ValueError, match="channel set is fixed"):
        Query().time(0, 1).agg("count", channel=0).agg("mean", channel=1)
    with pytest.raises(ValueError, match="unknown aggregate"):
        AggSpec(ops=("median",))
    with pytest.raises(ValueError, match="empty"):
        AggSpec(ops=())
    with pytest.raises(ValueError, match="channel=-1"):
        AggSpec(channel=-1)
    with pytest.raises(ValueError, match="share one AggSpec"):
        Query.batch(Query().time(0, 1).agg("count"),
                    Query().time(0, 1).agg("mean"))


def test_agg_multi_channel_spec():
    """channels= requests one fused scan over a static channel tuple; the
    channel set is fixed once chosen and single-channel specs are equal
    whichever spelling built them."""
    q = Query().time(0, 1).agg("count", "mean", channels=(0, 2))
    assert q.spec == AggSpec(channels=(0, 2), ops=("count", "mean"))
    assert q.spec.n_channels == 2 and q.spec.channel == 0
    # later .agg calls may add ops but not change the channel set
    assert q.agg("sum").spec.ops == ("count", "mean", "sum")
    with pytest.raises(ValueError, match="channel set is fixed"):
        q.agg("sum", channels=(1,))
    with pytest.raises(ValueError, match="not both"):
        Query().time(0, 1).agg("count", channel=1, channels=(1, 2))
    with pytest.raises(ValueError, match="duplicates"):
        AggSpec(channels=(1, 1))
    with pytest.raises(ValueError, match="not both"):
        AggSpec(channel=1, channels=(1, 2))
    assert AggSpec(channel=3) == AggSpec(channels=(3,))


def test_batch_stacks_queries():
    pred, spec = Query.batch(
        Query().time(0.0, 10.0),
        Query().bbox(12.9, 13.0, 77.5, 77.6) | Query().shard(1, 2),
        Query().shard(3, 4))
    assert pred.lat0.shape == (3,)
    np.testing.assert_array_equal(np.asarray(pred.has_temporal),
                                  [True, False, False])
    np.testing.assert_array_equal(np.asarray(pred.is_and),
                                  [True, False, True])
    np.testing.assert_array_equal(np.asarray(pred.sid_hi), [-1, 1, 3])


# ---------------------------------------------------------------------------
# AggSpec pipeline: numpy oracle + engine agreement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("channel", range(4))
def test_aggregates_match_numpy_oracle(loaded_db, channel):
    """Every aggregate of every channel equals a global numpy scan (the
    deployment replicates but must not double-count)."""
    db, flat, _ = loaded_db
    t_mid = float(np.median(flat[:, 0]))
    q = Query().time(0.0, t_mid).agg(*AGG_OPS, channel=channel)
    res, _ = db.query(q)
    m = flat[:, 0] <= t_mid
    v = flat[m, 3 + channel]
    assert int(res.count[0]) == int(m.sum())
    np.testing.assert_allclose(float(res.vsum[0]), v.sum(), rtol=1e-4)
    np.testing.assert_allclose(float(res.vmin[0]), v.min(), rtol=1e-5)
    np.testing.assert_allclose(float(res.vmax[0]), v.max(), rtol=1e-5)
    np.testing.assert_allclose(float(res.vmean[0]), v.mean(), rtol=1e-4)
    view = res.view(q.spec)
    assert set(view) == set(AGG_OPS) | {"completeness_bound",
                                        "replicas_lost"}
    np.testing.assert_array_equal(np.asarray(view["count"]),
                                  np.asarray(res.count))
    # Degradation telemetry rides in every view: fully-served query here.
    np.testing.assert_array_equal(np.asarray(view["completeness_bound"]), 1.0)
    np.testing.assert_array_equal(np.asarray(view["replicas_lost"]), 0)


def test_mean_of_empty_window_is_nan(loaded_db):
    db, flat, _ = loaded_db
    t_max = float(flat[:, 0].max())
    res, _ = db.query(Query().time(t_max + 1e6, t_max + 2e6).agg("mean"))
    assert int(res.count[0]) == 0
    assert np.isnan(float(res.vmean[0]))


def test_zero_match_min_max_are_nan_not_sentinels(loaded_db):
    """Regression: zero-match queries used to leak the scan's +inf/-inf
    accumulator sentinels into vmin/vmax; they must be NaN-masked like vmean
    — including per-channel in a multi-channel spec, and per-query in a
    mixed batch."""
    db, flat, _ = loaded_db
    t_max = float(flat[:, 0].max())
    empty = Query().time(t_max + 1e6, t_max + 2e6)
    res, _ = db.query(empty.agg("min", "max"))
    assert int(res.count[0]) == 0
    assert np.isnan(float(res.vmin[0])) and np.isnan(float(res.vmax[0]))
    assert not np.isinf(np.asarray(res.vmin)).any()
    # multi-channel: every channel column masked
    res_mc, _ = db.query(empty.agg("min", "max", channels=(0, 3)))
    assert np.isnan(np.asarray(res_mc.vmin)).all()
    assert np.isnan(np.asarray(res_mc.vmax)).all()
    # mixed batch: only the empty query's lanes are masked
    pred, spec = Query.batch(empty, Query().time(0.0, t_max))
    res_b, _ = db.query((pred, spec))
    assert np.isnan(float(res_b.vmin[0])) and np.isnan(float(res_b.vmax[0]))
    assert np.isfinite(float(res_b.vmin[1])) and int(res_b.count[1]) > 0
    # kernel engine path behaves identically
    db_k = AerialDB(db.cfg, db.state, db.alive, jax.random.key(0),
                    use_kernel=True, interpret=True)
    res_k, _ = db_k.query((pred, spec))
    assert np.isnan(float(res_k.vmin[0])) and np.isnan(float(res_k.vmax[0]))


def test_multi_channel_query_equals_k_single_channel_queries(loaded_db):
    """Tentpole acceptance: a K-channel AggSpec scans the log ONCE and its
    (Q, K) aggregates are identical to K independent single-channel queries
    — on both engines."""
    db, flat, _ = loaded_db
    channels = (0, 2, 3)
    pred, _ = Query.batch(
        Query().bbox(12.85, 13.10, 77.45, 77.75).time(0.0, 1e9),
        Query().time(0.0, float(np.median(flat[:, 0]))))
    key = jax.random.key(11)
    dbs = [db, AerialDB(db.cfg, db.state, db.alive, jax.random.key(0),
                        use_kernel=True, interpret=True)]
    for session in dbs:
        multi, _ = session.query(pred, agg=AggSpec(channels=channels),
                                 key=key)
        assert multi.vsum.shape == (2, len(channels))
        for k, ch in enumerate(channels):
            single, _ = session.query(pred, agg=AggSpec(channel=ch), key=key)
            np.testing.assert_array_equal(np.asarray(multi.count),
                                          np.asarray(single.count))
            for f in ("vsum", "vmin", "vmax", "vmean"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(multi, f)[:, k]),
                    np.asarray(getattr(single, f)), err_msg=f)
    # view projects per-op (Q, K) arrays
    spec = AggSpec(channels=channels, ops=("count", "mean"))
    res, _ = db.query(pred, agg=spec, key=key)
    view = res.view(spec)
    assert set(view) == {"count", "mean",
                         "completeness_bound", "replicas_lost"}
    assert view["mean"].shape == (2, len(channels))


@pytest.mark.parametrize("channel", [0, 2, 3])
def test_agg_channels_agree_ref_vs_kernel(loaded_db, channel):
    """jnp-ref and Pallas-kernel engines agree per AggSpec: counts bitwise,
    float aggregates to accumulation order (the kernel reduces in block_c
    tiles). The federated path is covered by test_federation.py."""
    db, flat, _ = loaded_db
    spec = AggSpec(channel=channel)
    pred, _ = Query.batch(
        Query().bbox(12.85, 13.10, 77.45, 77.75).time(0.0, 1e9),
        Query().time(0.0, float(np.median(flat[:, 0]))))
    key = jax.random.key(3)
    r_ref, i_ref = db.query((pred, spec), key=key)
    db_k = AerialDB(db.cfg, db.state, db.alive, jax.random.key(0),
                    use_kernel=True, interpret=True)
    r_ker, i_ker = db_k.query((pred, spec), key=key)
    np.testing.assert_array_equal(np.asarray(r_ref.count),
                                  np.asarray(r_ker.count))
    for f in ("vsum", "vmin", "vmax", "vmean"):
        np.testing.assert_allclose(np.asarray(getattr(r_ref, f)),
                                   np.asarray(getattr(r_ker, f)), rtol=1e-5,
                                   err_msg=f)
    for f in i_ref._fields:
        np.testing.assert_array_equal(np.asarray(getattr(i_ref, f)),
                                      np.asarray(getattr(i_ker, f)), err_msg=f)


def test_channel_out_of_range_raises(loaded_db):
    db, _, _ = loaded_db
    with pytest.raises(ValueError, match="channel=7 out of range"):
        db.query(Query().time(0, 1).agg("count", channel=7))


# ---------------------------------------------------------------------------
# AerialDB facade: dispatch + custody + shim equivalence
# ---------------------------------------------------------------------------

def test_facade_matches_deprecated_shims():
    """Adopting the facade is observationally free: per-round states and
    query results are identical to the insert_step/query_step shims (whose
    return values are themselves pinned by the PR-2 differential harness)."""
    cfg = small_cfg()
    db = AerialDB.open(cfg)
    state = init_store(cfg)
    alive = jnp.ones(E, bool)
    fleet = DroneFleet(10, records_per_shard=12, seed=9)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for _ in range(3):
            payload, meta = fleet.next_shards()
            db.insert(payload, meta)
            state, _ = insert_step(cfg, state, jnp.asarray(payload),
                                   ShardMeta(*[jnp.asarray(f) for f in meta]),
                                   alive)
        for a, b in zip(jax.tree.leaves(db.state), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        q = Query().bbox(12.85, 13.10, 77.45, 77.75).time(0.0, 1e9)
        pred, spec = q.build()
        key = jax.random.key(1)
        r1, i1 = db.query(q, key=key)
        r2, i2 = query_step(cfg, state, pred, alive, key)
    for f in r1._fields:
        np.testing.assert_array_equal(np.asarray(getattr(r1, f)),
                                      np.asarray(getattr(r2, f)), err_msg=f)
    for f in i1._fields:
        np.testing.assert_array_equal(np.asarray(getattr(i1, f)),
                                      np.asarray(getattr(i2, f)), err_msg=f)


def test_shims_emit_deprecation_warning():
    from repro.core import datastore
    datastore._warn_deprecated.cache_clear()
    cfg = small_cfg()
    state = init_store(cfg)
    with pytest.warns(DeprecationWarning, match="AerialDB.query"):
        query_step(cfg, state, make_pred(q=1, has_temporal=True, t1=1.0),
                   jnp.ones(E, bool), jax.random.key(0))


def test_facade_owns_key_custody(loaded_db):
    """Without an explicit key, the session splits its own: the random
    planner gets fresh keys per call, but results stay identical (replica
    choice never changes result content — only which edges answer)."""
    db, flat, _ = loaded_db
    db_rand = AerialDB(dataclasses.replace(db.cfg, planner="random"),
                       db.state, db.alive, jax.random.key(42))
    q = Query().bbox(12.85, 13.10, 77.45, 77.75).time(0.0, 1e9).agg("count")
    r1, _ = db_rand.query(q)
    r2, _ = db_rand.query(q)
    assert int(r1.count[0]) == int(r2.count[0]) == len(flat)


def test_fail_and_recover_edges():
    cfg = small_cfg()
    db = AerialDB.open(cfg)
    payloads, metas = DroneFleet(10, records_per_shard=12, seed=3).next_rounds(3)
    db.ingest_rounds(payloads, metas)
    q = Query().time(0.0, 1e9).agg("count")
    full = int(db.query(q)[0].count[0])
    assert full == payloads.shape[0] * payloads.shape[1] * payloads.shape[2]

    db.fail_edges(2, 6)
    np.testing.assert_array_equal(
        np.asarray(db.alive),
        [True, True, False, True, True, True, False, True])
    degraded, info = db.query(q)
    assert int(degraded.count[0]) <= full  # replication may or may not cover

    db.recover_edges([2, 6])               # list form also accepted
    assert bool(np.asarray(db.alive).all())
    assert int(db.query(q)[0].count[0]) == full


def test_facade_open_overrides_and_bad_query_type():
    db = AerialDB.open(small_cfg(), tuple_capacity=1024)
    assert db.cfg.tuple_capacity == 1024
    with pytest.raises(TypeError, match="cannot query with"):
        db.query({"not": "a query"})
    with pytest.raises(ValueError, match="not both"):
        db.query(Query().time(0, 1).agg("count"), agg=AggSpec())
