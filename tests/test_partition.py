"""Fleet partition tolerance (PR 9): unreachable-but-intact edges.

The contract under test: ``AerialDB.partition(edge_groups)`` models a
network split — the far side is excluded from placement, query planning and
repair via ``effective_alive`` but its state is never mutated (distinct
from dead) — and ``heal()`` closes an epoch window on the SAME outage
ledger a recovery uses, so the incremental repair sweeps only shards
ingested during the partition and stays bitwise identical to the full
sweep. Plus the satellite ledger edge cases: ``fail_edges`` on an
already-dead edge merges into its original epoch record, and
``recover_edges`` on an alive edge is a bitwise no-op — regression-tested
on both mesh layouts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import AerialDB
from repro.chaos import assert_content_equal, canonical_content
from repro.core.datastore import StoreConfig, make_pred
from repro.core.repair import repair_state
from repro.data.synthetic import CityConfig, DroneFleet, make_sites
from repro.launch.mesh import make_edge_mesh, make_fleet_mesh

E = 8
N_DEV = 4
CAP = 256
CATCH_ALL = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True, is_and=True)


def _cfg(**overrides):
    sites = make_sites(E, CityConfig(), seed=3)
    kw = dict(n_edges=E, sites=tuple(map(tuple, sites.tolist())),
              tuple_capacity=CAP, index_capacity=512,
              max_shards_per_query=64, records_per_shard=8,
              retention_every=2, n_failure_domains=4)
    kw.update(overrides)
    return StoreConfig(**kw)


CFG = _cfg()


def _assert_states_identical(ref, fed, msg=""):
    names = [jax.tree_util.keystr(p) for p, _
             in jax.tree_util.tree_flatten_with_path(ref)[0]]
    for name, a, b in zip(names, jax.tree.leaves(ref), jax.tree.leaves(fed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{msg}{name}")


def _ingest(db, fleet, rounds=1):
    for _ in range(rounds):
        p, m = fleet.next_shards()
        db.insert(p, m)
    return p, m


def _total_count(db):
    res, _ = db.query(CATCH_ALL, key=jax.random.key(0))
    return int(res.count[0])


# ---------------------------------------------------------------------------
# Partition semantics: re-route, degrade, frozen far side
# ---------------------------------------------------------------------------


def test_partition_reroutes_inserts_and_freezes_far_side():
    """Inserts during a partition land only on reachable edges; the far
    side's state is bitwise frozen (unreachable != dead: nothing is
    reclaimed or backfilled over it while the split is open)."""
    db = AerialDB.open(CFG, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=5)
    _ingest(db, fleet, 2)
    far = [4, 5, 6, 7]
    far_tup = np.asarray(db.state.tup_f)[far].copy()
    far_idx = np.asarray(db.state.index.valid)[far].copy()
    db.partition([[0, 1, 2, 3], far])
    np.testing.assert_array_equal(np.asarray(db.effective_alive),
                                  [1, 1, 1, 1, 0, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(db.alive), True)  # not dead
    info = _ingest(db, fleet, 2) and db.last_repair    # noqa: F841
    np.testing.assert_array_equal(np.asarray(db.state.tup_f)[far], far_tup)
    np.testing.assert_array_equal(np.asarray(db.state.index.valid)[far],
                                  far_idx)
    # replicas of partition-time shards name only reachable edges
    ent_i = np.asarray(db.state.index.ent_i)
    valid = np.asarray(db.state.index.valid)
    steps0 = 2
    ent_step = np.asarray(db.state.index.ent_step)
    for v, c in zip(*np.nonzero(valid)):
        if ent_step[v, c] > steps0:                    # written mid-split
            reps = {int(r) for r in ent_i[v, c, 2:5] if r >= 0}
            assert reps <= {0, 1, 2, 3}, (v, c, reps)


def test_partition_degrades_queries_and_heal_restores():
    """Strand a shard's whole replica set on the far side (index entry
    surviving on a reachable slice owner): its sid query reports the loss
    through the EXISTING degraded accounting — count 0, bound 0, all
    replicas lost — exactly like a crash would; heal restores it without
    any repair work (the far-side data never died)."""
    db = AerialDB.open(_cfg(records_per_shard=12), seed=0)
    rng = np.random.default_rng(24)
    r = 12
    t = np.linspace(0.0, 1100.0, r, dtype=np.float32)
    lat = np.linspace(12.90, 13.00, r, dtype=np.float32)   # wide: entries
    lon = np.linspace(77.50, 77.62, r, dtype=np.float32)   # beyond replicas
    payload = np.concatenate(
        [t[:, None], lat[:, None], lon[:, None],
         rng.normal(size=(r, 4)).astype(np.float32)], axis=1)[None]
    from repro.core.placement import ShardMeta
    meta = ShardMeta(
        sid_hi=np.asarray([77], np.int32), sid_lo=np.asarray([9], np.int32),
        lat0=lat.min(keepdims=True), lat1=lat.max(keepdims=True),
        lon0=lon.min(keepdims=True), lon1=lon.max(keepdims=True),
        t0=t.min(keepdims=True), t1=t.max(keepdims=True))
    info = db.insert(payload, meta)
    reps = sorted({int(x) for x in np.asarray(info["replicas"])[0]})
    holders = set(np.nonzero(
        np.asarray(info["index_writes_per_edge"]) > 0)[0].tolist())
    assert holders - set(reps), (holders, reps)    # a reachable lookup edge
    keep = [e for e in range(E) if e not in reps]
    db.partition([keep, reps])                     # replicas unreachable
    pred = make_pred(q=1, sid_hi=77, sid_lo=9, has_sid=True)
    res, qi = db.query(pred, key=jax.random.key(1))
    assert int(res.count[0]) == 0
    assert float(np.asarray(qi.completeness_bound)[0]) == 0.0
    assert int(np.asarray(qi.replicas_lost)[0]) == 3
    db.heal()
    assert db.last_repair["shards_replaced"] == 0  # data never died
    res, qi = db.query(pred, key=jax.random.key(2))
    assert int(res.count[0]) == r
    assert float(np.asarray(qi.completeness_bound)[0]) == 1.0
    assert int(np.asarray(qi.replicas_lost)[0]) == 0


def test_partition_validation_and_ledger():
    db = AerialDB.open(CFG, seed=0)
    with pytest.raises(ValueError, match="separates nothing"):
        db.partition([list(range(E))])
    with pytest.raises(ValueError, match="no reachable"):
        db.partition([[], [0, 1, 2, 3, 4, 5, 6, 7]])
    with pytest.raises(ValueError, match="disjoint"):
        db.partition([[0, 1], [1, 2]])
    with pytest.raises(ValueError, match="out of range"):
        db.partition([[0], [E]])
    db.partition([0, 1, 2])              # flat list = coordinator group
    np.testing.assert_array_equal(np.asarray(db.reachable),
                                  [1, 1, 1, 0, 0, 0, 0, 0])
    assert db.ledger()["partition"] == {"unreachable": [3, 4, 5, 6, 7],
                                        "step": 0}
    with pytest.raises(ValueError, match="already open"):
        db.partition([[0], [1]])
    db.heal(repair=False)
    assert db.ledger()["partition"] is None
    assert db.ledger()["closed_windows"] == [([3, 4, 5, 6, 7], 0, 0)]
    assert bool(np.asarray(db.reachable).all())
    before = db.ledger()
    db.heal()                            # double heal: no-op, repair skipped
    assert db.last_repair is None
    assert db.ledger() == before


def test_heal_without_ingest_is_bitwise_noop():
    """Nothing ingested while split: the incremental repair after heal has
    nothing to sweep and the state is bitwise unchanged."""
    db = AerialDB.open(CFG, seed=0)
    _ingest(db, DroneFleet(12, records_per_shard=8, seed=11), 2)
    before = db.state
    db.partition([[0, 1], [2, 3], [4, 5, 6, 7]])
    db.heal()
    assert db.last_repair["shards_swept"] == 0
    _assert_states_identical(before, db.state)


# ---------------------------------------------------------------------------
# Tentpole: heal's incremental repair == full sweep, O(partition), and
# cross-history convergence to the never-faulted reference
# ---------------------------------------------------------------------------


def test_heal_incremental_repair_matches_full_sweep():
    """Both repair points — mid-partition (degraded mask) and post-heal —
    must land bitwise on the full sweep's state from the same pre-state
    under the same effective mask."""
    db = AerialDB.open(CFG, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=13)
    _ingest(db, fleet, 2)
    db.partition([[0, 1, 2, 3, 4], [5, 6, 7]])
    _ingest(db, fleet, 2)
    # mid-partition repair: runs under the effective (degraded) mask
    full_state, full_info = repair_state(CFG, db.state, db.effective_alive,
                                         outage=None)
    inc = db.repair()
    assert inc["mode"] == "incremental"
    assert inc["shards_swept"] <= full_info["shards_swept"]
    _assert_states_identical(full_state, db.state, msg="mid-partition: ")
    _ingest(db, fleet, 1)
    db.heal(repair=False)
    full_state, full_info = repair_state(CFG, db.state, db.effective_alive,
                                         outage=None)
    inc = db.repair()
    assert inc["shards_swept"] <= full_info["shards_swept"]
    _assert_states_identical(full_state, db.state, msg="post-heal: ")


def test_heal_sweeps_partition_not_store():
    """A brief split in a long-lived store: heal's sweep is O(shards
    ingested during the partition), not O(everything tracked)."""
    db = AerialDB.open(CFG, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=17)
    _ingest(db, fleet, 8)                # long all-connected history
    db.partition([[0, 1, 2, 3], [4, 5, 6, 7]])
    _ingest(db, fleet, 1)                # one round mid-split
    db.heal()
    rep = db.last_repair
    assert rep["shards_swept"] > 0
    assert rep["shards_tracked"] >= 3 * rep["shards_swept"], rep
    assert rep["entries_reclaimed"] > 0  # partition-time lookup rows retired


def test_partition_heal_converges_to_never_faulted_content():
    """After heal + repair the store holds bit-identical canonical content
    to a never-partitioned twin fed the same stream — including with a
    real edge death composed on the reachable side mid-split. (Large rings:
    content equivalence presumes no retention eviction — a split
    concentrates load on the reachable side, so small rings wrap earlier
    there than in the reference, legitimately aging out different tuples.)"""
    cfg = _cfg(tuple_capacity=2048)
    db = AerialDB.open(cfg, seed=0)
    ref = AerialDB.open(cfg, seed=0)
    fleets = [DroneFleet(12, records_per_shard=8, seed=19) for _ in range(2)]
    for d, f in ((db, fleets[0]), (ref, fleets[1])):
        _ingest(d, f, 2)
    db.partition([[0, 1, 2, 3], [4, 5, 6, 7]])
    _ingest(db, fleets[0], 1)
    _ingest(ref, fleets[1], 1)
    db.fail_edges(1)                     # death composes with the split
    _ingest(db, fleets[0], 1)
    _ingest(ref, fleets[1], 1)
    db.heal()                            # edge 1 still dead: repair degraded
    assert db.ledger()["pending_sids"] > 0     # re-sweep debt recorded
    db.recover_edges(1)                  # final repair: all effective
    assert db.ledger()["pending_sids"] == 0
    assert_content_equal(canonical_content(db), canonical_content(ref))
    assert _total_count(db) == _total_count(ref)


# ---------------------------------------------------------------------------
# Differential: both mesh layouts run the same partition script bitwise
# ---------------------------------------------------------------------------


@pytest.fixture(params=["edge4", "fleet2x2"])
def mesh(request):
    if jax.device_count() < N_DEV:
        pytest.skip(f"needs {N_DEV} host devices")
    if request.param == "edge4":
        return make_edge_mesh(N_DEV)
    return make_fleet_mesh(2, N_DEV // 2)


def test_partition_differential_mesh(mesh):
    """The scripted partition/heal sequence through the single-device and
    sharded facades stays bitwise identical, repair telemetry included."""
    db_ref = AerialDB.open(CFG, seed=0)
    db_fed = AerialDB.open(CFG, mesh=mesh, seed=0)
    fleets = [DroneFleet(12, records_per_shard=8, seed=23) for _ in range(2)]

    def both(fn):
        for db, fleet in zip((db_ref, db_fed), fleets):
            fn(db, fleet)

    both(lambda db, f: _ingest(db, f, 2))
    both(lambda db, f: db.partition([[0, 1, 2, 5], [3, 4, 6, 7]]))
    both(lambda db, f: _ingest(db, f, 2))
    q = [db.query(CATCH_ALL, key=jax.random.key(3)) for db in
         (db_ref, db_fed)]
    assert int(q[0][0].count[0]) == int(q[1][0].count[0])
    both(lambda db, f: db.heal())
    assert db_ref.last_repair == db_fed.last_repair
    _assert_states_identical(db_ref.state, db_fed.state, msg="post-heal: ")
    assert _total_count(db_ref) == _total_count(db_fed)


# ---------------------------------------------------------------------------
# Satellite: ledger edge cases — double-fail merges, double-recover no-ops
# ---------------------------------------------------------------------------


def test_double_fail_merges_into_original_epoch(mesh):
    """Failing an already-dead edge keeps it under the epoch record its
    ORIGINAL failure opened (the window must date from the first death) —
    no duplicate record, and an all-dead call is a pure no-op."""
    db = AerialDB.open(CFG, mesh=mesh, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=29)
    db.fail_edges(2)
    step0 = db.ledger()["open_outages"][0][1]
    _ingest(db, fleet, 1)
    db.fail_edges(2, 5)                  # 2 already dead: merge, 5 fresh
    led = db.ledger()
    assert led["open_outages"] == [([2], step0), ([5], 1)]
    before = db.state
    db.fail_edges(2, 5)                  # every id already dead: pure no-op
    assert db.ledger() == led
    _assert_states_identical(before, db.state)
    db.recover_edges(2, 5)
    assert db.ledger()["open_outages"] == []
    assert_content_equal(
        canonical_content(db),
        canonical_content(db))           # self-consistent post-repair


def test_recover_alive_edge_is_bitwise_noop(mesh):
    """Recovering an alive edge closes nothing, repairs nothing, and must
    not consume windows deferred by an earlier repair=False recovery."""
    db = AerialDB.open(CFG, mesh=mesh, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=31)
    _ingest(db, fleet, 1)
    db.fail_edges(3)
    _ingest(db, fleet, 1)
    db.recover_edges(3, repair=False)    # window deferred on the ledger
    led = db.ledger()
    assert led["closed_windows"] == [([3], 1, 2)]
    before = db.state
    db.recover_edges(0)                  # 0 is alive: bitwise no-op
    assert db.last_repair is None        # implicit repair skipped
    assert db.ledger() == led            # deferred window untouched
    _assert_states_identical(before, db.state)
    info = db.repair()                   # explicit repair still sees it
    assert info["shards_swept"] > 0
    assert db.ledger()["closed_windows"] == []
