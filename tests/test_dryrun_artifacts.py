"""Integrity checks over the committed dry-run artifacts (experiments/):
the multi-pod deliverable is 'every cell lowers+compiles' — this test keeps
the claim checkable without re-running the 14-minute sweep. Skips cleanly
when the artifacts have not been generated yet."""

import glob
import json
from pathlib import Path

import pytest

DRYRUN = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"

pytestmark = pytest.mark.skipif(not DRYRUN.exists(),
                                reason="dry-run artifacts not generated")

ARCHS = {"internlm2-1.8b", "qwen3-14b", "deepseek-7b", "stablelm-12b",
         "grok-1-314b", "deepseek-v2-236b", "seamless-m4t-large-v2",
         "zamba2-1.2b", "qwen2-vl-72b", "falcon-mamba-7b"}
SHAPES = {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
SSM_LIKE = {"zamba2-1.2b", "falcon-mamba-7b"}


def load_all():
    return [json.loads(Path(f).read_text())
            for f in glob.glob(str(DRYRUN / "*.json"))]


def test_full_matrix_present():
    cells = {(r["arch"], r["shape"], r["mesh"]) for r in load_all()}
    assert len(cells) == 80  # 10 archs x 4 shapes x 2 meshes
    archs = {a for a, _, _ in cells}
    assert archs == ARCHS


def test_no_failures_and_correct_skips():
    for r in load_all():
        if r["shape"] == "long_500k" and r["arch"] not in SSM_LIKE:
            assert r["status"] == "skipped", r["arch"]
        else:
            assert r["status"] == "ok", (r["arch"], r["shape"], r["mesh"],
                                         r.get("error", "")[:100])


def test_ok_cells_have_analysis():
    for r in load_all():
        if r["status"] != "ok":
            continue
        ha = r["hlo_analysis_per_device"]
        assert ha["flops"] > 0, (r["arch"], r["shape"])
        assert ha["bytes_accessed"] > 0
        assert "memory_analysis" in r and "temp_size_in_bytes" in r["memory_analysis"]
        # multi-pod cells must actually shard the pod axis: a 512-way module
        # compiled from the same model should not exceed ~1.2x the single-pod
        # per-device flops (pure-DP pod axis halves per-device work for
        # batch-bound steps; decode B=1 replicates)
        assert r["param_bytes_global"] > 0


def test_multi_pod_shards_batch():
    """train cells: per-device FLOPs on 512 chips ~ half of 256 chips."""
    by = {(r["arch"], r["shape"], r["mesh"]): r for r in load_all()}
    for arch in ARCHS:
        single = by.get((arch, "train_4k", "16x16"))
        multi = by.get((arch, "train_4k", "2x16x16"))
        if not single or not multi or single["status"] != "ok":
            continue
        f1 = single["hlo_analysis_per_device"]["flops"]
        f2 = multi["hlo_analysis_per_device"]["flops"]
        assert f2 < 0.75 * f1, (arch, f1, f2)
