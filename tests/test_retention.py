"""Sustained-ingest proof (ring-buffer retention, ISSUE 1 acceptance):

Drive >= 4x tuple_capacity tuples through every edge and show that
  (a) insert_step keeps accepting writes — no saturation, nothing lost;
  (b) a spatio-temporal query over the retained window is exact vs a
      replication-free oracle, identically for the jnp reference engine and
      the Pallas kernel;
  (c) index retention + compaction keep `valid` occupancy and the cursor
      below capacity across many compaction cycles.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.datastore import (StoreConfig, init_store, insert_step,
                                  make_pred, query_step)
from repro.core.index import compact_index, init_index, retire_entries
from repro.core.placement import ShardMeta
from repro.data.synthetic import CityConfig, DroneFleet, make_sites

E = 8
CAP = 512
ROUNDS = 48
RETENTION_EVERY = 4

# 48-round sustained-ingest load: heavyweight end-to-end (built once, shared
# by every test here via the lru_cache below).
pytestmark = pytest.mark.slow


@functools.lru_cache(maxsize=1)   # built lazily on first test, shared after
def _sustained_store():
    sites = make_sites(E, CityConfig(), seed=3)
    cfg = StoreConfig(
        n_edges=E, sites=tuple(map(tuple, sites.tolist())),
        tuple_capacity=CAP, index_capacity=256, max_shards_per_query=128,
        records_per_shard=12, replication=3, retention_every=RETENTION_EVERY)
    fleet = DroneFleet(16, records_per_shard=12)
    state = init_store(cfg)
    alive = jnp.ones(E, bool)
    payloads, round_intake = [], []
    occupancy, cursors = [], []
    for _ in range(ROUNDS):
        payload, meta = fleet.next_shards()
        meta = ShardMeta(*[jnp.asarray(x) for x in meta])
        state, info = insert_step(cfg, state, jnp.asarray(payload), meta, alive)
        payloads.append(payload)
        round_intake.append(np.asarray(info["intake_per_edge"]))
        occupancy.append(int(np.asarray(state.index.valid.sum(axis=1)).max()))
        cursors.append(int(np.asarray(state.index.cursor).max()))
    return cfg, state, payloads, np.asarray(round_intake), occupancy, cursors


def test_insert_never_saturates():
    """(a) every edge wrote >= 4x capacity; counts stay monotonic; the ring
    overwrites instead of dropping."""
    cfg, state, payloads, round_intake, _, _ = _sustained_store()
    count = np.asarray(state.tup_count)
    assert count.min() >= 4 * CAP, count
    np.testing.assert_array_equal(count, round_intake.sum(axis=0))
    np.testing.assert_array_equal(np.asarray(state.tup_pos), count % CAP)
    assert int(np.asarray(state.tup_dropped).sum()) == 0
    # retention accounting: exactly what exceeded capacity was overwritten
    np.testing.assert_array_equal(
        np.asarray(state.tup_overwritten), count - np.minimum(count, CAP))
    assert int(np.asarray(state.index.dropped).sum()) == 0


def _recent_window(payloads, round_intake):
    """[t0, inf) covering the last K rounds, chosen so the window is fully
    retained on every edge (per-edge writes since round J stay under CAP)."""
    k = 2          # placement is skewed: the hottest edge absorbs every shard
    j = ROUNDS - k # of a round, so 2 rounds is what provably fits its ring
    assert round_intake[j:].sum(axis=0).max() <= CAP, "window outgrew the ring"
    t0 = float(min(p[..., 0].min() for p in payloads[j:]))
    t1 = float(payloads[-1][..., 0].max()) + 1.0
    return j, t0, t1


def test_query_over_retained_window_exact():
    """(b) temporal query over the retained window: exact vs oracle, and the
    Pallas kernel agrees with the jnp reference engine."""
    cfg, state, payloads, round_intake, _, _ = _sustained_store()
    j, t0, t1 = _recent_window(payloads, round_intake)
    pred = make_pred(q=1, t0=t0, t1=t1, has_temporal=True, is_and=True)
    alive = jnp.ones(E, bool)

    flat = np.concatenate([p.reshape(-1, p.shape[-1]) for p in payloads])
    m = (flat[:, 0] >= t0) & (flat[:, 0] <= t1)
    exp_count, exp_vsum = int(m.sum()), flat[m, 3].sum()
    assert exp_count > 0

    res_ref, info = query_step(cfg, state, pred, alive, jax.random.key(0),
                               use_kernel=False)
    res_ker, _ = query_step(cfg, state, pred, alive, jax.random.key(0),
                            use_kernel=True)
    assert not bool(np.asarray(res_ref.overflow).any())
    assert int(res_ref.count[0]) == exp_count
    np.testing.assert_allclose(float(res_ref.vsum[0]), exp_vsum, rtol=1e-4)
    # engine equivalence: counts exact, float aggregates to accumulation order
    assert int(res_ker.count[0]) == int(res_ref.count[0])
    np.testing.assert_allclose(np.asarray(res_ker.vsum), np.asarray(res_ref.vsum),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(res_ker.vmin), np.asarray(res_ref.vmin),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(res_ker.vmax), np.asarray(res_ref.vmax),
                               rtol=1e-6)


def test_fully_aged_out_window_is_empty():
    """Data older than the retained window is gone: a query over the first
    rounds' time range returns nothing (those tuples were overwritten)."""
    cfg, state, _, _, _, _ = _sustained_store()
    count = np.asarray(state.tup_count)
    assert count.min() > CAP  # every ring wrapped
    oldest_retained = float(np.asarray(state.tup_f[:, 0, :]).min())  # t row
    t1 = oldest_retained - 1.0
    assert t1 > 0
    pred = make_pred(q=1, t0=0.0, t1=t1, has_temporal=True, is_and=True)
    res, _ = query_step(cfg, state, pred, jnp.ones(E, bool), jax.random.key(1))
    assert int(res.count[0]) == 0


def test_index_occupancy_bounded_across_compactions():
    """(c) >= 3 compaction cycles ran; occupancy and cursor never reach
    capacity; retention actually retired entries."""
    cfg, state, _, _, occupancy, cursors = _sustained_store()
    n_sweeps = ROUNDS // RETENTION_EVERY
    assert n_sweeps >= 3
    assert max(occupancy) < cfg.index_capacity, max(occupancy)
    assert max(cursors) < cfg.index_capacity, max(cursors)
    assert int(np.asarray(state.index.retired).sum()) > 0
    # steady state: late occupancy is flat, not growing with total ingest
    assert occupancy[-1] < 2 * occupancy[ROUNDS // 2]


def test_retire_and_compact_unit():
    """Unit semantics: retire invalidates exactly the entries whose data is
    behind the watermark of EVERY replica edge; compact squashes survivors to
    a prefix and rewinds the cursor."""
    idx = init_index(2, 8)
    ent_f = np.zeros((2, 8, 6), np.float32)
    ent_f[0, :, 5] = np.arange(8)            # t1 = 0..7 on edge 0
    ent_f[1, :, 5] = 100.0
    ent_i = np.full((2, 8, 5), -1, np.int32)
    ent_i[0, :, 1] = np.arange(8)            # sid_lo marks each entry
    ent_i[0, :, 2] = 0                       # replica edge 0 ...
    ent_i[0, 2:4, 2] = 1                     # ... except entries 2,3 -> edge 1
    ent_i[1, :, 2] = 0
    valid = np.zeros((2, 8), bool)
    valid[0] = True
    valid[1, :3] = True
    idx = idx._replace(ent_f=jnp.asarray(ent_f), ent_i=jnp.asarray(ent_i),
                       valid=jnp.asarray(valid),
                       cursor=jnp.asarray([8, 3], jnp.int32))
    wm = jnp.asarray([4.0, -np.inf], jnp.float32)  # edge 1's ring never wrapped
    out = compact_index(retire_entries(idx, wm))
    # edge 0: entries 0,1 (replica edge 0, t1 < 4) retire; 2,3 survive — their
    # data lives on edge 1 whose -inf watermark retains everything; 4..7
    # survive on age. Survivors compact to the front in stable order.
    np.testing.assert_array_equal(np.asarray(out.valid[0]),
                                  [True] * 6 + [False] * 2)
    np.testing.assert_array_equal(np.asarray(out.ent_i[0, :6, 1]),
                                  [2, 3, 4, 5, 6, 7])
    np.testing.assert_array_equal(np.asarray(out.cursor), [6, 3])
    np.testing.assert_array_equal(np.asarray(out.retired), [2, 0])
    # edge 1: entries' replica (edge 0, wm=4) is ahead of t1=100 -> kept
    assert int(out.valid[1].sum()) == 3
