"""Resilience properties (paper §3.5.3, Fig 14).

The paper's guarantee: with 3 replicas over three independent content
dimensions, any <= 2 edge failures leave every shard reachable, so queries
stay exact (only latency degrades). 3+ failures may lose data gracefully.

Failures are injected AFTER insertion (data was placed while all edges were
alive, then edges die) — the paper's experiment shape.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.datastore import (StoreConfig, init_store, insert_step,
                                  make_pred, query_step)
from repro.core.placement import ShardMeta
from repro.data.synthetic import CityConfig, DroneFleet, make_sites

E = 10


def build_store(planner="min_shards"):
    sites = make_sites(E, CityConfig(), seed=3)
    cfg = StoreConfig(n_edges=E, sites=tuple(map(tuple, sites.tolist())),
                      tuple_capacity=4096, index_capacity=1024,
                      max_shards_per_query=64, records_per_shard=12,
                      planner=planner)
    fleet = DroneFleet(10, records_per_shard=12)
    state = init_store(cfg)
    alive = jnp.ones(E, bool)
    total = 0
    payloads = []
    for _ in range(3):
        payload, meta = fleet.next_shards()
        meta = ShardMeta(*[jnp.asarray(x) for x in meta])
        state, _ = insert_step(cfg, state, jnp.asarray(payload), meta, alive)
        total += payload.shape[0] * payload.shape[1]
        payloads.append(payload)
    return cfg, state, total, np.concatenate(payloads)


CFG, STATE, TOTAL, PAYLOADS = build_store()


@given(st.sets(st.integers(0, E - 1), min_size=0, max_size=2))
@settings(deadline=None, max_examples=30)
def test_exact_results_up_to_two_failures(dead):
    """<= 2 failures: the catch-all temporal query still counts every tuple."""
    alive = np.ones(E, bool)
    alive[list(dead)] = False
    pred = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True, is_and=True)
    result, info = query_step(CFG, STATE, pred, jnp.asarray(alive),
                              jax.random.key(0))
    assert int(result.count[0]) == TOTAL


@given(st.sets(st.integers(0, E - 1), min_size=3, max_size=4),
       st.integers(0, 1 << 30))
@settings(deadline=None, max_examples=20)
def test_graceful_degradation_three_plus_failures(dead, seed):
    """3-4 failures: never a crash, never an overcount; loss is bounded by the
    tuples whose 3 replicas all died."""
    alive = np.ones(E, bool)
    alive[list(dead)] = False
    pred = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True, is_and=True)
    result, info = query_step(CFG, STATE, pred, jnp.asarray(alive),
                              jax.random.key(seed))
    got = int(result.count[0])
    assert got <= TOTAL
    # Fig 14: ~1% loss at 3 failures; bound loosely here (10 edges not 20).
    assert got >= 0.5 * TOTAL


def test_query_during_partial_failure_spatial():
    alive = np.ones(E, bool)
    alive[[1, 4]] = False
    pred = make_pred(q=1, lat0=12.85, lat1=13.10, lon0=77.45, lon1=77.75,
                     t0=0.0, t1=1e9, has_spatial=True, has_temporal=True)
    result, info = query_step(CFG, STATE, pred, jnp.asarray(alive),
                              jax.random.key(1))
    assert int(result.count[0]) == TOTAL


def test_all_planners_resilient():
    """Planner choice is query-time only: reuse the module store and swap the
    planner in the (static) config instead of re-ingesting per planner."""
    import dataclasses
    for planner in ["random", "min_edges", "min_shards"]:
        cfg = dataclasses.replace(CFG, planner=planner)
        alive = np.ones(E, bool)
        alive[[0, 9]] = False
        pred = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True)
        result, _ = query_step(cfg, STATE, pred, jnp.asarray(alive),
                               jax.random.key(2))
        assert int(result.count[0]) == TOTAL, planner


def test_assignment_avoids_dead_edges():
    alive = np.ones(E, bool)
    alive[[2, 5]] = False
    pred = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True)
    _, info = query_step(CFG, STATE, pred, jnp.asarray(alive), jax.random.key(3))
    # no sub-query may target a dead edge
    assert int(np.asarray(info.subquery_edges)[0]) <= int(alive.sum())


# ---------------------------------------------------------------------------
# Failure-domain resilience engine: device failures, degraded accounting,
# recovery re-replication (the facade surface)
# ---------------------------------------------------------------------------

import pytest

from repro.api import AerialDB
from repro.data.synthetic import DroneFleet as _Fleet


def _facade_cfg(**overrides):
    sites = make_sites(8, CityConfig(), seed=3)
    kw = dict(n_edges=8, sites=tuple(map(tuple, sites.tolist())),
              tuple_capacity=2048, index_capacity=512,
              max_shards_per_query=64, records_per_shard=12)
    kw.update(overrides)
    return StoreConfig(**kw)


CATCH_ALL = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True, is_and=True)


def _wide_shard(seed=24, sid=(77, 9)):
    """One WIDE shard spanning many slice cells/buckets, so its index entry
    lands on slice-owner edges beyond its 3 replicas (narrow drone shards
    index almost exclusively on the replicas themselves — midpoint hash
    == r0). Returns (payload (1, R, 7), ShardMeta)."""
    rng = np.random.default_rng(seed)
    r = 12
    t = np.linspace(0.0, 1100.0, r, dtype=np.float32)          # 4 tau buckets
    lat = np.linspace(12.90, 13.00, r, dtype=np.float32)       # ~10 cells
    lon = np.linspace(77.50, 77.62, r, dtype=np.float32)
    vals = rng.normal(size=(r, 4)).astype(np.float32)
    payload = np.concatenate([t[:, None], lat[:, None], lon[:, None], vals],
                             axis=1)[None]                     # (1, R, 7)
    meta = ShardMeta(
        sid_hi=np.asarray([sid[0]], np.int32),
        sid_lo=np.asarray([sid[1]], np.int32),
        lat0=lat.min(keepdims=True), lat1=lat.max(keepdims=True),
        lon0=lon.min(keepdims=True), lon1=lon.max(keepdims=True),
        t0=t.min(keepdims=True), t1=t.max(keepdims=True))
    return payload, meta


def test_mass_failure_one_alive_edge_keeps_every_tuple():
    """1 alive edge: placement degrades to (edge, -1, -1) — one real copy,
    no duplicate/dead ids — and the catch-all query still counts every
    inserted tuple exactly (the old fallback silently dropped them)."""
    db = AerialDB.open(_facade_cfg()).fail_edges(list(range(1, 8)))
    p, m = _Fleet(5, records_per_shard=12, seed=21).next_shards()
    info = db.insert(p, m)
    reps = np.asarray(info["replicas"])
    np.testing.assert_array_equal(reps, np.broadcast_to([0, -1, -1],
                                                        reps.shape))
    res, qi = db.query(CATCH_ALL, key=jax.random.key(0))
    assert int(res.count[0]) == 5 * 12
    assert float(np.asarray(qi.completeness_bound)[0]) == 1.0


def test_mass_failure_zero_alive_edges_explicit_drop():
    """0 alive edges: all replica slots are -1, nothing is written, queries
    answer 0 — and nothing crashes anywhere in the pipeline."""
    db = AerialDB.open(_facade_cfg()).fail_edges(list(range(8)))
    p, m = _Fleet(3, records_per_shard=12, seed=22).next_shards()
    info = db.insert(p, m)
    assert (np.asarray(info["replicas"]) == -1).all()
    assert int(np.asarray(info["intake_per_edge"]).sum()) == 0
    res, _ = db.query(CATCH_ALL, key=jax.random.key(0))
    assert int(res.count[0]) == 0


def test_membership_ids_validated_eagerly():
    """JAX scatter clamping must never silently retarget membership flips:
    out-of-range / negative / duplicate / empty edge ids all raise before
    any alive-mask update happens."""
    db = AerialDB.open(_facade_cfg())
    with pytest.raises(ValueError, match="out of range"):
        db.fail_edges(8)                         # == n_edges: the clamp bug
    with pytest.raises(ValueError, match="out of range"):
        db.fail_edges([0, 1000])
    with pytest.raises(ValueError, match="out of range"):
        db.recover_edges(-1)
    with pytest.raises(ValueError, match="duplicate"):
        db.fail_edges(3, 3)
    with pytest.raises(ValueError, match="no edge ids"):
        db.fail_edges([])
    assert bool(db.alive.all())                  # mask untouched throughout
    db.fail_edges(7).recover_edges(7)            # valid ids still work


def test_device_failure_requires_domains():
    db = AerialDB.open(_facade_cfg())            # n_failure_domains=1, no mesh
    with pytest.raises(ValueError, match="failure domains"):
        db.fail_device(0)
    db4 = AerialDB.open(_facade_cfg(n_failure_domains=4))
    with pytest.raises(ValueError, match="out of range"):
        db4.fail_device(4)


def test_device_failure_completeness_exact():
    """One whole failure domain down under failure-domain placement: the
    catch-all query stays bit-exactly complete (acceptance criterion), and
    the degraded accounting reports the lost replica slots."""
    db = AerialDB.open(_facade_cfg(n_failure_domains=4))
    payloads, metas = _Fleet(10, records_per_shard=12, seed=23).next_rounds(4)
    db.ingest_rounds(payloads, metas)
    total = int(np.prod(payloads.shape[:3]))
    for device in range(4):
        db.fail_device(device)
        assert int(db.alive.sum()) == 6
        res, info = db.query(CATCH_ALL, key=jax.random.key(device))
        assert int(res.count[0]) == total, f"device {device}"
        assert float(np.asarray(info.completeness_bound)[0]) == 1.0
        assert int(np.asarray(info.replicas_lost)[0]) > 0
        db.recover_device(device, repair=False)  # state never ingested while
        assert bool(db.alive.all())              # down: nothing to repair


def test_degraded_accounting_unreachable_shard():
    """Kill every replica of a shard (keeping the shard index-visible on a
    surviving slice-owner edge): its sid point-query must report the loss
    honestly — count 0, completeness_bound 0, replicas_lost == 3. The bound
    only covers shards the surviving index can still see (QueryInfo doc)."""
    db = AerialDB.open(_facade_cfg())
    payload, meta = _wide_shard()
    info = db.insert(payload, meta)
    reps = np.asarray(info["replicas"])
    holders = set(np.nonzero(
        np.asarray(info["index_writes_per_edge"]) > 0)[0].tolist())
    assert holders - {int(r) for r in reps[0]}, (holders, reps)
    db.fail_edges(sorted({int(r) for r in reps[0]}))
    pred = make_pred(q=1, sid_hi=77, sid_lo=9, has_sid=True)
    res, qi = db.query(pred, key=jax.random.key(1))
    assert int(res.count[0]) == 0
    assert int(np.asarray(qi.shards_matched)[0]) == 1
    assert float(np.asarray(qi.completeness_bound)[0]) == 0.0
    assert int(np.asarray(qi.replicas_lost)[0]) == 3


def _outage_lifecycle(repair):
    """Ingest, lose a device, keep ingesting, recover (with/without repair);
    returns (db, during-outage metas, per-shard expected count)."""
    db = AerialDB.open(_facade_cfg(n_failure_domains=4))
    fleet = _Fleet(10, records_per_shard=12, seed=25)
    pay, met = fleet.next_rounds(2)
    db.ingest_rounds(pay, met)
    db.fail_device(1)
    pay2, met2 = fleet.next_rounds(2)
    db.ingest_rounds(pay2, met2)
    db.recover_device(1, repair=repair)
    return db, met2


def test_repair_backfills_recovered_edge_lookup_hole():
    """Shards ingested during an outage never wrote index entries to the
    dead edges. A sid point-query's lookup set is the single hash edge —
    when that edge is the recovered one, only the anti-entropy repair pass
    makes it answer completely. Every during-outage shard must point-query
    exactly (matching a never-failed store); the repair=False control shows
    the silent hole actually existed."""
    db, met2 = _outage_lifecycle(repair=True)
    rep = db.last_repair
    assert rep["shards_replaced"] > 0 and rep["entries_backfilled"] > 0

    def point_counts(session):
        hi = np.asarray(met2.sid_hi).reshape(-1)
        lo = np.asarray(met2.sid_lo).reshape(-1)
        pred = make_pred(q=hi.size, sid_hi=hi, sid_lo=lo, has_sid=True)
        res, _ = session.query(pred, key=jax.random.key(2))
        return np.asarray(res.count)

    np.testing.assert_array_equal(point_counts(db), 12)  # all exact

    db_ctl, _ = _outage_lifecycle(repair=False)
    ctl = point_counts(db_ctl)
    assert (ctl < 12).any(), ctl    # the hole the repair pass plugs
    # deferred repair converges the control store too
    db_ctl.repair()
    np.testing.assert_array_equal(point_counts(db_ctl), 12)


def test_repair_never_launders_unrepairable_shards():
    """A shard whose every replica died must stay honestly lost through a
    repair pass: rewriting its entries to fresh (empty) alive replicas would
    reset replicas_lost/completeness_bound to a fabricated all-clear."""
    db = AerialDB.open(_facade_cfg())
    p, m = _Fleet(6, records_per_shard=12, seed=26).next_shards()
    info = db.insert(p, m)
    reps = sorted({int(r) for r in np.asarray(info["replicas"])[0]})
    other = next(e for e in range(8) if e not in reps)
    db.fail_edges(reps + [other])
    db.recover_edges(other)                     # triggers repair
    assert db.last_repair["shards_unrepairable"] > 0
    pred = make_pred(q=1, sid_hi=int(np.asarray(m.sid_hi)[0]),
                     sid_lo=int(np.asarray(m.sid_lo)[0]), has_sid=True)
    res, qi = db.query(pred, key=jax.random.key(4))
    assert int(res.count[0]) == 0
    # the loss stays visible wherever the surviving index still sees the
    # shard (entries keep naming the dead replicas, never empty fresh ones)
    if int(np.asarray(qi.shards_matched)[0]) == 1:
        assert float(np.asarray(qi.completeness_bound)[0]) == 0.0
        assert int(np.asarray(qi.replicas_lost)[0]) == 3
    # ...and the copies are still recoverable once a replica returns:
    db.recover_edges(reps)
    res, _ = db.query(pred, key=jax.random.key(5))
    assert int(res.count[0]) == 12


def test_repair_backfills_entries_for_unrepairable_shards():
    """A recovered lookup edge must learn about LOST shards too: repair
    backfills their missing index entries (naming the dead replicas), so a
    query routed to the recovered edge reports the loss honestly instead of
    matching nothing and fabricating completeness_bound == 1.0."""
    db = AerialDB.open(_facade_cfg())
    db.fail_edges(0)                            # edge 0 misses the entry
    payload, meta = _wide_shard(seed=28, sid=(55, 4))
    info = db.insert(payload, meta)
    holders = sorted(np.nonzero(
        np.asarray(info["index_writes_per_edge"]) > 0)[0].tolist())
    assert 0 not in holders
    db.fail_edges(holders)                      # every holder + replica dies
    db.recover_edges(0)                         # repair: shard is lost, but
    assert db.last_repair["shards_unrepairable"] > 0
    ent_i = np.asarray(db.state.index.ent_i)
    on0 = (np.asarray(db.state.index.valid)[0]
           & (ent_i[0, :, 0] == 55) & (ent_i[0, :, 1] == 4))
    assert on0.any()                            # ...edge 0 now has the entry
    reps = ent_i[0][on0][0, 2:5]
    assert not np.asarray(db.alive)[reps[reps >= 0]].any()  # naming dead ones
    res, qi = db.query(make_pred(q=1, sid_hi=55, sid_lo=4, has_sid=True),
                       key=jax.random.key(7))
    assert int(res.count[0]) == 0
    assert int(np.asarray(qi.shards_matched)[0]) == 1       # loss is visible
    assert float(np.asarray(qi.completeness_bound)[0]) == 0.0
    assert int(np.asarray(qi.replicas_lost)[0]) == 3


def test_repair_skips_sources_that_lost_their_copy():
    """Tuple backfill must take the first surviving source that still HOLDS
    the shard (a faster-wrapping ring may have overwritten its copy), not
    blindly the lowest edge id."""
    from repro.core.repair import repair_state
    db = AerialDB.open(_facade_cfg())
    p, m = _Fleet(6, records_per_shard=12, seed=27).next_shards()
    info = db.insert(p, m)
    hi, lo = int(np.asarray(m.sid_hi)[0]), int(np.asarray(m.sid_lo)[0])
    reps = sorted({int(r) for r in np.asarray(info["replicas"])[0]})
    # Simulate retention on the lowest-id replica: its copy is gone.
    tup_sid = np.asarray(db.state.tup_sid).copy()
    wiped = reps[0]
    gone = (tup_sid[wiped, 0] == hi) & (tup_sid[wiped, 1] == lo)
    assert gone.any()
    tup_sid[wiped, :, gone.nonzero()[0]] = -2
    state = db.state._replace(tup_sid=jnp.asarray(tup_sid))
    # Kill another replica and repair mid-outage: the moved replica must be
    # backfilled from the copy-holding source, not the wiped one.
    alive = np.ones(8, bool)
    alive[reps[1]] = False
    new_state, rinfo = repair_state(db.cfg, state, jnp.asarray(alive))
    assert rinfo["shards_unrepairable"] == 0
    assert rinfo["tuples_copied"] >= 12
    db2 = AerialDB(db.cfg, new_state, jnp.asarray(alive), jax.random.key(0))
    pred = make_pred(q=1, sid_hi=hi, sid_lo=lo, has_sid=True)
    res, _ = db2.query(pred, key=jax.random.key(6))
    assert int(res.count[0]) == 12


def test_repair_prefers_fullest_surviving_copy():
    """Rings wrap independently: when the lowest-id surviving replica holds
    only a partial remnant of a shard, repair must source the backfill from
    the replica with the MOST tuples, not the first one with any."""
    from repro.core.repair import repair_state
    db = AerialDB.open(_facade_cfg())
    p, m = _Fleet(6, records_per_shard=12, seed=29).next_shards()
    info = db.insert(p, m)
    hi, lo = int(np.asarray(m.sid_hi)[0]), int(np.asarray(m.sid_lo)[0])
    reps = sorted({int(r) for r in np.asarray(info["replicas"])[0]})
    # Simulate partial retention on the lowest-id replica: 6 of 12 remain.
    tup_sid = np.asarray(db.state.tup_sid).copy()
    part = reps[0]
    slots = ((tup_sid[part, 0] == hi) & (tup_sid[part, 1] == lo)).nonzero()[0]
    assert slots.size == 12
    tup_sid[part, :, slots[:6]] = -2
    state = db.state._replace(tup_sid=jnp.asarray(tup_sid))
    alive = np.ones(8, bool)
    alive[reps[1]] = False                       # force a re-place
    new_state, rinfo = repair_state(db.cfg, state, jnp.asarray(alive))
    db2 = AerialDB(db.cfg, new_state, jnp.asarray(alive), jax.random.key(0))
    pred = make_pred(q=1, sid_hi=hi, sid_lo=lo, has_sid=True)
    # whichever replica the planner picks, the moved copy must be FULL —
    # run with several planner keys to cover the replica choices
    import dataclasses
    cfg_r = dataclasses.replace(db.cfg, planner="random")
    db2 = AerialDB(cfg_r, new_state, jnp.asarray(alive), jax.random.key(0))
    counts = {int(db2.query(pred, key=jax.random.key(k))[0].count[0])
              for k in range(8)}
    assert 12 in counts and 0 not in counts, counts
    # the partial remnant (6) may legitimately surface — retention skew —
    # but the backfilled replica must never have been seeded from it
    assert counts <= {6, 12}, counts


def test_mesh_incompatible_failure_domains_rejected():
    """Failure domains finer than the mesh's device blocks void the
    whole-device durability guarantee — the session must refuse them."""
    from repro.launch.mesh import make_edge_mesh
    import jax as _jax
    if _jax.device_count() < 2:
        pytest.skip("needs >= 2 host devices")
    mesh = make_edge_mesh(2)
    with pytest.raises(ValueError, match="n_failure_domains"):
        AerialDB.open(_facade_cfg(n_failure_domains=4), mesh=mesh)
    AerialDB.open(_facade_cfg(n_failure_domains=2), mesh=mesh)  # one per dev
    AerialDB.open(_facade_cfg(n_failure_domains=1), mesh=mesh)  # disabled


def test_repair_matches_never_failed_store():
    """After recovery + repair, catch-all and windowed queries over the
    outage window equal a store that never failed (acceptance criterion)."""
    db_ok = AerialDB.open(_facade_cfg(n_failure_domains=4))
    fleet = _Fleet(10, records_per_shard=12, seed=25)
    pay, met = fleet.next_rounds(4)
    db_ok.ingest_rounds(pay, met)

    db, _ = _outage_lifecycle(repair=True)      # same seed => same fleet
    t = np.asarray(pay)[2:, :, :, 0]            # outage-window timestamps
    preds = [CATCH_ALL,
             make_pred(q=1, t0=float(t.min()), t1=float(t.max()),
                       has_temporal=True, is_and=True)]
    for pred in preds:
        r1, _ = db_ok.query(pred, key=jax.random.key(3))
        r2, _ = db.query(pred, key=jax.random.key(3))
        np.testing.assert_array_equal(np.asarray(r1.count),
                                      np.asarray(r2.count))
        np.testing.assert_allclose(np.asarray(r1.vsum), np.asarray(r2.vsum),
                                   rtol=1e-6)
