"""Resilience properties (paper §3.5.3, Fig 14).

The paper's guarantee: with 3 replicas over three independent content
dimensions, any <= 2 edge failures leave every shard reachable, so queries
stay exact (only latency degrades). 3+ failures may lose data gracefully.

Failures are injected AFTER insertion (data was placed while all edges were
alive, then edges die) — the paper's experiment shape.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.datastore import (StoreConfig, init_store, insert_step,
                                  make_pred, query_step)
from repro.core.placement import ShardMeta
from repro.data.synthetic import CityConfig, DroneFleet, make_sites

E = 10


def build_store(planner="min_shards"):
    sites = make_sites(E, CityConfig(), seed=3)
    cfg = StoreConfig(n_edges=E, sites=tuple(map(tuple, sites.tolist())),
                      tuple_capacity=4096, index_capacity=1024,
                      max_shards_per_query=64, records_per_shard=12,
                      planner=planner)
    fleet = DroneFleet(10, records_per_shard=12)
    state = init_store(cfg)
    alive = jnp.ones(E, bool)
    total = 0
    payloads = []
    for _ in range(3):
        payload, meta = fleet.next_shards()
        meta = ShardMeta(*[jnp.asarray(x) for x in meta])
        state, _ = insert_step(cfg, state, jnp.asarray(payload), meta, alive)
        total += payload.shape[0] * payload.shape[1]
        payloads.append(payload)
    return cfg, state, total, np.concatenate(payloads)


CFG, STATE, TOTAL, PAYLOADS = build_store()


@given(st.sets(st.integers(0, E - 1), min_size=0, max_size=2))
@settings(deadline=None, max_examples=30)
def test_exact_results_up_to_two_failures(dead):
    """<= 2 failures: the catch-all temporal query still counts every tuple."""
    alive = np.ones(E, bool)
    alive[list(dead)] = False
    pred = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True, is_and=True)
    result, info = query_step(CFG, STATE, pred, jnp.asarray(alive),
                              jax.random.key(0))
    assert int(result.count[0]) == TOTAL


@given(st.sets(st.integers(0, E - 1), min_size=3, max_size=4),
       st.integers(0, 1 << 30))
@settings(deadline=None, max_examples=20)
def test_graceful_degradation_three_plus_failures(dead, seed):
    """3-4 failures: never a crash, never an overcount; loss is bounded by the
    tuples whose 3 replicas all died."""
    alive = np.ones(E, bool)
    alive[list(dead)] = False
    pred = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True, is_and=True)
    result, info = query_step(CFG, STATE, pred, jnp.asarray(alive),
                              jax.random.key(seed))
    got = int(result.count[0])
    assert got <= TOTAL
    # Fig 14: ~1% loss at 3 failures; bound loosely here (10 edges not 20).
    assert got >= 0.5 * TOTAL


def test_query_during_partial_failure_spatial():
    alive = np.ones(E, bool)
    alive[[1, 4]] = False
    pred = make_pred(q=1, lat0=12.85, lat1=13.10, lon0=77.45, lon1=77.75,
                     t0=0.0, t1=1e9, has_spatial=True, has_temporal=True)
    result, info = query_step(CFG, STATE, pred, jnp.asarray(alive),
                              jax.random.key(1))
    assert int(result.count[0]) == TOTAL


def test_all_planners_resilient():
    """Planner choice is query-time only: reuse the module store and swap the
    planner in the (static) config instead of re-ingesting per planner."""
    import dataclasses
    for planner in ["random", "min_edges", "min_shards"]:
        cfg = dataclasses.replace(CFG, planner=planner)
        alive = np.ones(E, bool)
        alive[[0, 9]] = False
        pred = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True)
        result, _ = query_step(cfg, STATE, pred, jnp.asarray(alive),
                               jax.random.key(2))
        assert int(result.count[0]) == TOTAL, planner


def test_assignment_avoids_dead_edges():
    alive = np.ones(E, bool)
    alive[[2, 5]] = False
    pred = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True)
    _, info = query_step(CFG, STATE, pred, jnp.asarray(alive), jax.random.key(3))
    # no sub-query may target a dead edge
    assert int(np.asarray(info.subquery_edges)[0]) <= int(alive.sum())
