"""Chaos engine (PR 9 tentpole): seeded fault plans, the runner, retry /
give-up / crash-recovery paths, and the replay-determinism + convergence
properties.

The load-bearing contracts: ``FaultPlan.random`` is pure in its seed (same
seed, bitwise-same plan); applying a plan to identically-seeded deployments
is fully deterministic (identical machine-readable logs, bitwise-identical
stores); a transient dispatch burst within the retry budget leaves the
store bitwise identical to a never-faulted run; an exhausted budget returns
the chunk to pending without breaking ``accepted == flushed + pending``; a
mid-flush crash with a write-ahead journal loses zero acknowledged records;
and — the property test — after a random plan's final heal/recover +
repair, the store's canonical content is bit-identical to the never-faulted
reference fed the same stream.
"""

import json

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import AerialDB
from repro.chaos import (EVENT_KINDS, ChaosRunner, FaultEvent, FaultPlan,
                         assert_content_equal, canonical_content)
from repro.core.datastore import StoreConfig, make_pred
from repro.data.synthetic import CityConfig, make_sites
from repro.ingest import IngestPipeline, PipelineCrash

E = 8
CATCH_ALL = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True, is_and=True)


def _cfg(**overrides):
    sites = make_sites(E, CityConfig(), seed=3)
    kw = dict(n_edges=E, sites=tuple(map(tuple, sites.tolist())),
              tuple_capacity=2048, index_capacity=512,
              max_shards_per_query=64, records_per_shard=8,
              retention_every=2, n_failure_domains=4)
    kw.update(overrides)
    return StoreConfig(**kw)


CFG = _cfg()
_NOSLEEP = lambda s: None     # noqa: E731 — deterministic, instant backoff


def _pipe(db, **kw):
    kw.setdefault("sleep", _NOSLEEP)
    return IngestPipeline(db, **kw)


def _tick_records(step, n_drones=12, per_drone=8, seed=0):
    """Deterministic telemetry for one tick: every drone contributes one
    full shard's worth of in-order records (identical across runs)."""
    rng = np.random.default_rng((seed, step))
    n = n_drones * per_drone
    drone = np.repeat(np.arange(n_drones, dtype=np.int64), per_drone)
    seq = np.tile(np.arange(per_drone, dtype=np.int64), n_drones) \
        + step * per_drone
    t = seq.astype(np.float64) + step * 0.25
    lat = rng.uniform(12.90, 13.00, n)
    lon = rng.uniform(77.50, 77.62, n)
    vals = rng.normal(size=(n, 4))
    return drone, seq, t, lat, lon, vals


def _feed(pipe, step, seed=0):
    pipe.submit_arrays(*_tick_records(step, seed=seed))
    return pipe.flush()


def _total_count(db):
    res, _ = db.query(CATCH_ALL, key=jax.random.key(0))
    return int(res.count[0])


# ---------------------------------------------------------------------------
# FaultPlan: seeded determinism + well-formedness
# ---------------------------------------------------------------------------


def test_fault_plan_replays_from_seed():
    kw = dict(n_edges=E, n_steps=10, n_domains=4, min_alive=4,
              require=("partition", "flush_fail"))
    a = FaultPlan.random(7, **kw)
    assert a == FaultPlan.random(7, **kw)            # pure in the seed
    assert a.seed == 7
    assert {"partition", "flush_fail"} <= set(a.kinds())
    assert a != FaultPlan.random(8, **kw)
    rows = a.to_rows()
    assert json.loads(json.dumps(rows)) == rows      # machine-readable


@given(st.integers(0, 1 << 30))
@settings(deadline=None, max_examples=20)
def test_fault_plan_is_well_formed(seed):
    """Every generated plan keeps >= min_alive edges alive AND reachable at
    every point, nests no partitions, and closes every fault by the
    horizon."""
    plan = FaultPlan.random(seed, n_edges=E, n_steps=10, n_domains=4,
                            min_alive=4, allow_crash=True)
    dead, unreachable = set(), set()
    block = E // 4
    for ev in plan.events:
        assert ev.kind in EVENT_KINDS
        if ev.kind == "fail_edges":
            assert not (set(ev.args[0]) & dead)
            dead |= set(ev.args[0])
        elif ev.kind == "recover_edges":
            assert set(ev.args[0]) <= dead
            dead -= set(ev.args[0])
        elif ev.kind == "fail_device":
            dead |= set(range(ev.args[0] * block, (ev.args[0] + 1) * block))
        elif ev.kind == "recover_device":
            dead -= set(range(ev.args[0] * block, (ev.args[0] + 1) * block))
        elif ev.kind == "partition":
            assert not unreachable                   # one split at a time
            keep, cut = ev.args[0]
            assert not (set(keep) & set(cut))
            assert set(keep) | set(cut) == set(range(E))
            assert not (set(cut) & dead)             # cut from effective
            unreachable = set(cut)
        elif ev.kind == "heal":
            unreachable = set()
        elif ev.kind == "flush_fail":
            assert 1 <= ev.args[0] <= 2              # default max_transient
        assert len(set(range(E)) - dead - unreachable) >= 4, ev
    assert not dead and not unreachable              # closed by the horizon


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="step-sorted"):
        FaultPlan(events=(FaultEvent(3, "heal"), FaultEvent(1, "heal")),
                  n_steps=4)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan(events=(FaultEvent(0, "meteor_strike"),), n_steps=4)
    with pytest.raises(ValueError, match="could not generate"):
        FaultPlan.random(0, n_edges=E, n_steps=2, p_fault=0.0,
                         require=("partition",))


# ---------------------------------------------------------------------------
# ChaosRunner: deterministic application, machine-readable log
# ---------------------------------------------------------------------------


def _run_once(plan, seed=0):
    db = AerialDB.open(CFG, seed=0)
    pipe = _pipe(db)
    runner = ChaosRunner(plan, db, pipe)
    runner.run(lambda step: _feed(pipe, step, seed=seed))
    return db, pipe, runner


def test_runner_is_deterministic():
    """Same plan + same seeds + same workload: the two runs' stores are
    bitwise identical and their event logs byte-identical."""
    plan = FaultPlan.random(11, n_edges=E, n_steps=6, n_domains=4,
                            min_alive=4, require=("partition", "flush_fail"))
    (db1, p1, r1), (db2, p2, r2) = _run_once(plan), _run_once(plan)
    assert r1.to_json() == r2.to_json()
    assert p1.counters == p2.counters
    for a, b in zip(jax.tree.leaves(db1.state), jax.tree.leaves(db2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_runner_log_is_machine_readable():
    plan = FaultPlan.random(11, n_edges=E, n_steps=6, n_domains=4,
                            min_alive=4, require=("partition", "flush_fail"))
    _db, _pipe_, runner = _run_once(plan)
    assert runner.done
    log = json.loads(runner.to_json())
    assert [(ev["step"], ev["kind"]) for ev in log] == \
        [(e.step, e.kind) for e in plan.events]
    for ev in log:
        if ev["kind"] in ("recover_edges", "recover_device", "heal"):
            assert ev["repair"]["mode"] == "incremental"
            assert "ledger" in ev
        if ev["kind"] in ("fail_edges", "fail_device", "partition"):
            assert "ledger" in ev


def test_runner_without_pipeline_rejects_ingest_faults():
    db = AerialDB.open(CFG, seed=0)
    plan = FaultPlan(events=(FaultEvent(0, "flush_fail", (1,)),), n_steps=2)
    runner = ChaosRunner(plan, db)                   # no pipeline
    with pytest.raises(ValueError, match="no pipeline"):
        runner.advance(0)


# ---------------------------------------------------------------------------
# Transient flush failure: retry absorbs, give-up returns to pending
# ---------------------------------------------------------------------------


def test_transient_burst_within_budget_is_bitwise_invisible():
    """A burst <= max_retries is fully absorbed by the retry loop: same
    dispatches, same sids, bitwise-identical store to a never-faulted run —
    only the retries counter differs."""
    db_f, db_r = AerialDB.open(CFG, seed=0), AerialDB.open(CFG, seed=0)
    pipe_f, pipe_r = _pipe(db_f, max_retries=4), _pipe(db_r)
    runner = ChaosRunner(
        FaultPlan(events=(FaultEvent(1, "flush_fail", (2,)),), n_steps=3),
        db_f, pipe_f)
    for step in range(3):
        runner.advance(step)
        _feed(pipe_f, step)
        _feed(pipe_r, step)
    assert pipe_f.counters["retries"] == 2
    assert pipe_f.counters["gave_up"] == 0
    c_f = {k: v for k, v in pipe_f.counters.items() if k != "retries"}
    c_r = {k: v for k, v in pipe_r.counters.items() if k != "retries"}
    assert c_f == c_r
    for a, b in zip(jax.tree.leaves(db_f.state), jax.tree.leaves(db_r.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_exhausted_retry_budget_returns_chunk_to_pending():
    """Past the budget the chunk gives up: its records return to pending
    (``accepted == flushed + pending`` still holds), nothing half-lands,
    and the next healthy flush delivers them."""
    db = AerialDB.open(CFG, seed=0)
    pipe = _pipe(db, max_retries=1)

    def always_fail(pipeline, attempt):
        from repro.ingest import TransientDispatchError
        raise TransientDispatchError("link down")
    pipe.fault_hook = always_fail
    pipe.submit_arrays(*_tick_records(0))
    out = pipe.flush()
    assert out["flushed_records"] == 0
    # 12 full shards -> plan_chunks gives an [8, 4] split: two dispatches,
    # each burning its 1-retry budget then giving up.
    assert out["gave_up"] == 2 and out["retries"] == 2
    assert out["returned_records"] == 96 == pipe.pending
    assert int(np.asarray(db.state.tup_count).sum()) == 0   # nothing landed
    rec = pipe.reconcile()
    assert rec["counters_ok"], rec                   # invariant survives
    pipe.fault_hook = None                           # link back up
    out = pipe.flush()
    assert out["flushed_records"] == 96
    rec = pipe.reconcile()
    assert rec["ok"], rec
    assert _total_count(db) == 96


# ---------------------------------------------------------------------------
# Mid-flush crash + journal replay: zero acknowledged records lost
# ---------------------------------------------------------------------------


def test_pipeline_crash_recovery_via_journal(tmp_path):
    """The chaos crash tears a flush mid-flight; a fresh session + fresh
    pipeline + ``replay_journal`` must recover every acknowledged record —
    the rebuilt store's canonical content equals the never-crashed
    reference's."""
    path = tmp_path / "wal.bin"
    db = AerialDB.open(CFG, seed=0)
    pipe = _pipe(db, journal=path)
    runner = ChaosRunner(
        FaultPlan(events=(FaultEvent(1, "pipeline_crash"),), n_steps=3),
        db, pipe)
    runner.advance(0)
    _feed(pipe, 0)
    runner.advance(1)                                # arms the crash
    accepted_pre = None
    with pytest.raises(PipelineCrash):
        pipe.submit_arrays(*_tick_records(1))
        accepted_pre = pipe.counters["accepted"]
        pipe.flush()
    assert accepted_pre == 192                       # both ticks acked
    pipe.close()

    # Process death: session + pipeline state gone. Rebuild and replay.
    db2 = AerialDB.open(CFG, seed=0)
    pipe2 = _pipe(db2, journal=path)
    rep = pipe2.replay_journal()
    assert rep["journal_records"] == rep["accepted"] == 192
    pipe2.flush(drain=True)
    rec = pipe2.reconcile()
    assert rec["ok"], rec
    assert rec["flushed_records"] == 192             # zero lost

    db_ref = AerialDB.open(CFG, seed=0)
    pipe_ref = _pipe(db_ref)
    for step in range(2):
        _feed(pipe_ref, step)
    assert_content_equal(canonical_content(db2), canonical_content(db_ref),
                         msg="crash-recovered vs reference: ")


# ---------------------------------------------------------------------------
# The property: random plans converge to the never-faulted reference
# ---------------------------------------------------------------------------


@given(st.integers(0, 1 << 30))
@settings(deadline=None, max_examples=5)
def test_chaos_plan_converges_to_reference_property(seed):
    """For random seeded plans mixing edge/device loss, partitions, and
    transient flush failures: ``accepted == flushed + pending`` holds at
    every step; after the plan's closing heal/recover (+ inline repairs)
    the store's canonical content is bit-identical to the never-faulted
    reference fed the same stream, and the full reconcile passes."""
    plan = FaultPlan.random(seed, n_edges=E, n_steps=6, n_domains=4,
                            min_alive=4, max_transient=2)
    db = AerialDB.open(CFG, seed=0)
    pipe = _pipe(db, max_retries=4)
    runner = ChaosRunner(plan, db, pipe)
    db_ref = AerialDB.open(CFG, seed=0)
    pipe_ref = _pipe(db_ref)

    def tick(step):
        _feed(pipe, step, seed=seed)
        _feed(pipe_ref, step, seed=seed)
        assert pipe.reconcile()["counters_ok"], (seed, step)

    runner.run(tick)
    assert pipe.counters["gave_up"] == 0             # bursts <= budget
    # wrap-free precondition for content equality (audit module docstring)
    assert int(np.asarray(db.state.tup_count).max()) <= CFG.tuple_capacity
    rec = pipe.reconcile()
    assert rec["ok"], (seed, rec)
    assert_content_equal(canonical_content(db), canonical_content(db_ref),
                         msg=f"seed={seed}: ")
    assert _total_count(db) == _total_count(db_ref)


def test_chaos_smoke():
    """Tier-1 fast path (also the CI smoke): one fixed mixed plan, end to
    end — deterministic log, full recovery, reference-equal content."""
    plan = FaultPlan(events=(
        FaultEvent(0, "fail_edges", ((6,),)),
        FaultEvent(1, "partition", (((0, 1, 2, 3, 6), (4, 5, 7)),)),
        FaultEvent(1, "flush_fail", (2,)),
        FaultEvent(2, "heal"),
        FaultEvent(3, "recover_edges", ((6,),)),
    ), n_steps=4)
    db = AerialDB.open(CFG, seed=0)
    pipe = _pipe(db, max_retries=4)
    runner = ChaosRunner(plan, db, pipe)
    runner.run(lambda step: _feed(pipe, step))
    assert runner.done and len(runner.log) == len(plan.events)
    assert pipe.counters["retries"] == 2 and pipe.counters["gave_up"] == 0
    assert pipe.reconcile()["ok"]
    db_ref = AerialDB.open(CFG, seed=0)
    pipe_ref = _pipe(db_ref)
    for step in range(4):
        _feed(pipe_ref, step)
    assert_content_equal(canonical_content(db), canonical_content(db_ref))
