"""Streaming ingest subsystem (PR 8): pipeline + coalescer + latest cache.

Three contracts under test:

1. **Adversarial-stream equivalence** (property): duplicate, out-of-order,
   partial, and burst-interleaved record streams through ``IngestPipeline``
   leave the store in the SAME state as the sorted/deduped synchronous
   insert path — bitwise when the flush boundaries coincide (the coalescer
   sorts by ``(drone, seq)``, so arrival order is irrelevant), content-level
   (catch-all counts + latest cache) across arbitrary flush interleavings.
2. **Latest-cache-vs-oracle**: ``AerialDB.latest()`` equals the brute-force
   numpy oracle over everything inserted, and ``IngestPipeline.latest()``
   equals it over everything *submitted* (store ∪ in-flight) — on the
   single-device runtime and differentially on the ``(4,)`` and ``(2, 2)``
   meshes.
3. **Epoch-aware retention** (PR 7 follow-up regression): after repair's
   ring reclamation rewinds ``tup_count`` below capacity, the retention
   watermark must stay finite (``tup_overwritten > 0`` marks the loss
   epoch) and aged index entries must still retire on the next sweep —
   pre-fix the watermark read ``-inf`` and retention silently paused until
   the ring re-wrapped.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import AerialDB, Query
from repro.core.datastore import StoreConfig, make_pred
from repro.data.synthetic import CityConfig, DroneFleet, make_sites
from repro.ingest import (IngestPipeline, group_shards, latest_oracle,
                          plan_chunks)
from repro.launch.mesh import make_edge_mesh, make_fleet_mesh

E = 8
N_DEV = 4
D_MAX = 16
R = 4
CATCH_ALL = make_pred(q=1, t0=-1e9, t1=1e9, has_temporal=True, is_and=True)


def _cfg(**overrides):
    sites = make_sites(E, CityConfig(), seed=3)
    kw = dict(n_edges=E, sites=tuple(map(tuple, sites.tolist())),
              tuple_capacity=2048, index_capacity=512,
              max_shards_per_query=64, records_per_shard=R,
              retention_every=2, max_drones=D_MAX)
    kw.update(overrides)
    return StoreConfig(**kw)


def _assert_states_identical(a, b, msg=""):
    names = [jax.tree_util.keystr(p) for p, _
             in jax.tree_util.tree_flatten_with_path(a)[0]]
    for name, x, y in zip(names, jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{msg}{name}")


def _stream(seed, n_drones=10, max_seq=12):
    """An adversarial telemetry stream + its clean reference.

    Returns ``(stream, clean)`` — both ``(drone, seq, rows (N, 3+V))``
    triples. The stream is shuffled, re-sends ~10% of records verbatim
    (duplicates), skips ~10% of seqs (drops, i.e. seq gaps), and NaNs out
    some value channels (partial payloads). ``clean`` is the deduped
    ``(drone, seq)``-sorted record set the stream must be equivalent to.
    """
    rng = np.random.default_rng(seed)
    drone, seq, rows = [], [], []
    for d in range(n_drones):
        n = int(rng.integers(1, max_seq + 1))
        seqs = np.arange(n)[rng.random(n) > 0.1]          # ~10% dropped
        for s in seqs:
            t = 1000.0 * s + d                            # unique t per record
            row = np.empty(7, np.float32)
            row[:3] = (t, 12.9 + 0.001 * d, 77.5 + 0.0005 * s)
            row[3:] = rng.normal(25, 5, 4)
            if rng.random() < 0.1:                        # partial payload
                row[3 + int(rng.integers(0, 4)):] = np.nan
            drone.append(d), seq.append(s), rows.append(row)
    drone, seq = np.asarray(drone), np.asarray(seq)
    rows = np.stack(rows)
    dup = rng.integers(0, len(drone), max(len(drone) // 10, 1))
    order = rng.permutation(np.r_[np.arange(len(drone)), dup])
    stream = (drone[order], seq[order], rows[order])
    srt = np.lexsort((seq, drone))
    clean = (drone[srt], seq[srt], rows[srt])
    return stream, clean


def _submit_stream(pipe, stream, rng, n_chunks):
    d, s, rows = stream
    for part in np.array_split(np.arange(d.shape[0]), n_chunks):
        pipe.submit_arrays(d[part], s[part], rows[part, 0], rows[part, 1],
                           rows[part, 2], rows[part, 3:])


# ---------------------------------------------------------------------------
# Tentpole: adversarial streams == sorted/deduped synchronous path
# ---------------------------------------------------------------------------


@given(st.integers(0, 1 << 30))
@settings(deadline=None, max_examples=8)
def test_adversarial_stream_state_bitwise_equivalent(seed):
    """Shuffled + duplicated + partial + gappy stream, submitted in several
    bursts, then ONE drain flush == the clean sorted/deduped stream through
    an identical pipeline — bitwise identical StoreState (same shards, same
    sids, same dispatch order), and counters reconcile exactly."""
    rng = np.random.default_rng(seed + 1)
    stream, clean = _stream(seed)
    cfg = _cfg()
    adv, ref = (IngestPipeline(AerialDB.open(cfg, seed=0))
                for _ in range(2))
    _submit_stream(adv, stream, rng, n_chunks=int(rng.integers(1, 5)))
    _submit_stream(ref, clean, rng, n_chunks=1)
    assert adv.counters["accepted"] == clean[0].shape[0]
    assert adv.counters["duplicate"] == stream[0].shape[0] - clean[0].shape[0]
    adv.flush(drain=True)
    ref.flush(drain=True)
    _assert_states_identical(adv.db.state, ref.db.state)
    rec = adv.reconcile()
    assert rec["ok"], rec
    assert rec["pending"] == 0


@given(st.integers(0, 1 << 30))
@settings(deadline=None, max_examples=6)
def test_burst_interleaved_flushes_content_equivalent(seed):
    """Flush boundaries interleaved with submit bursts (the streaming shape):
    step counters and batch shapes legitimately differ from the synchronous
    path, but the CONTENT must not — catch-all count equals the deduped
    record total, per-shard queries answer, and the latest cache equals the
    oracle over everything submitted."""
    rng = np.random.default_rng(seed + 2)
    stream, clean = _stream(seed)
    cfg = _cfg()
    pipe = IngestPipeline(AerialDB.open(cfg, seed=0))
    d, s, rows = stream
    cuts = np.array_split(np.arange(d.shape[0]), int(rng.integers(2, 5)))
    for part in cuts:
        pipe.submit_arrays(d[part], s[part], rows[part, 0], rows[part, 1],
                           rows[part, 2], rows[part, 3:])
        pipe.flush()                       # full shards only; tails pend
    pipe.flush(drain=True)
    rec = pipe.reconcile()
    assert rec["ok"] and rec["pending"] == 0, rec
    res, _ = pipe.db.query(CATCH_ALL, key=jax.random.key(0))
    assert int(np.asarray(res.count)[0]) == clean[0].shape[0]
    # Latest cache == oracle over the deduped submitted set.
    o_rec, o_val = latest_oracle(clean[0], clean[2][:, 0], clean[2], D_MAX)
    got = pipe.db.latest()
    np.testing.assert_array_equal(np.asarray(got.valid), o_val)
    np.testing.assert_array_equal(np.asarray(got.record), o_rec)


def test_pipeline_latest_overlays_pending():
    """In-flight (unflushed) records are part of the latest answer: the
    pipeline overlay equals the oracle over everything SUBMITTED, while the
    store cache alone only covers what was flushed."""
    pipe = IngestPipeline(AerialDB.open(_cfg(), seed=0))
    stream, clean = _stream(7)
    _submit_stream(pipe, stream, np.random.default_rng(0), 1)
    pipe.flush()                           # leaves sub-shard tails pending
    assert pipe.pending > 0
    o_rec, o_val = latest_oracle(clean[0], clean[2][:, 0], clean[2], D_MAX)
    rec, val = pipe.latest()
    np.testing.assert_array_equal(val, o_val)
    np.testing.assert_array_equal(rec, o_rec)
    # The store alone is stale exactly on the drones with pending tails.
    store_val = np.asarray(pipe.db.latest().valid)
    assert store_val.sum() <= o_val.sum()


# ---------------------------------------------------------------------------
# Latest cache differential on both mesh layouts
# ---------------------------------------------------------------------------


@pytest.mark.skipif(jax.device_count() < N_DEV,
                    reason=f"needs {N_DEV} host devices")
@pytest.mark.parametrize("mesh_name", ["edge4", "fleet2x2"])
def test_latest_cache_identical_on_meshes(mesh_name):
    """The same pipeline traffic against the single-device and sharded
    runtimes: full StoreState (including the replicated latest cache)
    bitwise identical, and both equal the oracle."""
    mesh = (make_edge_mesh(N_DEV) if mesh_name == "edge4"
            else make_fleet_mesh(2, N_DEV // 2))
    cfg = _cfg()
    stream, clean = _stream(23)
    pipes = [IngestPipeline(AerialDB.open(cfg, seed=0)),
             IngestPipeline(AerialDB.open(cfg, mesh=mesh, seed=0))]
    for pipe in pipes:
        _submit_stream(pipe, stream, np.random.default_rng(3), 2)
        pipe.flush(drain=True)
    _assert_states_identical(pipes[0].db.state, pipes[1].db.state,
                             msg=mesh_name)
    o_rec, o_val = latest_oracle(clean[0], clean[2][:, 0], clean[2], D_MAX)
    for pipe in pipes:
        got = pipe.db.latest()
        np.testing.assert_array_equal(np.asarray(got.valid), o_val)
        np.testing.assert_array_equal(np.asarray(got.record), o_rec)


# ---------------------------------------------------------------------------
# Pipeline mechanics: dedup, holes, backpressure, chunk planning
# ---------------------------------------------------------------------------


def test_out_of_order_and_gap_refill():
    """A seq gap leaves holes late arrivals may fill exactly once."""
    pipe = IngestPipeline(AerialDB.open(_cfg(), seed=0))
    sub = lambda pairs: pipe.submit([(d, s, 10.0 * s + d, 12.9, 77.5, 1, 2, 3, 4)
                                     for d, s in pairs])
    c = sub([(0, 0), (0, 5)])              # gap: seqs 1..4 become holes
    assert c["accepted"] == 2
    c = sub([(0, 3)])                      # late arrival fills a hole
    assert c["accepted"] == 3 and c["duplicate"] == 0
    c = sub([(0, 3), (0, 5), (0, 0)])      # all re-sends now
    assert c["accepted"] == 3 and c["duplicate"] == 3


def test_malformed_and_partial_records():
    pipe = IngestPipeline(AerialDB.open(_cfg(), seed=0))
    c = pipe.submit([
        (0, 0, 1.0, 12.9, 77.5, 1.0, 2.0, 3.0, 4.0),   # complete
        (1, 0, np.nan, 12.9, 77.5, 1.0),               # malformed t
        (-3, 0, 1.0, 12.9, 77.5),                      # malformed id
        (2, 0, 2.0, 12.9, 77.5, 1.0),                  # partial (1 of 4)
        (3, 0, 3.0, 12.9, 77.5),                       # partial (0 of 4)
    ])
    assert c["accepted"] == 3 and c["partial"] == 2
    assert c["dropped"] == 2 and c["dropped_malformed"] == 2
    with pytest.raises(ValueError, match="n_values"):
        pipe.submit([(0, 1, 1.0, 12.9, 77.5, 1, 2, 3, 4, 5)])


def test_backpressure_bounds_pending():
    pipe = IngestPipeline(AerialDB.open(_cfg(), seed=0), max_pending=10)
    d = np.zeros(25, np.int64)
    s = np.arange(25)
    c = pipe.submit_arrays(d, s, s * 1.0, d + 12.9, d + 77.5)
    assert c["accepted"] == 10 and pipe.pending == 10
    assert c["dropped_backpressure"] == 15
    pipe.flush(drain=True)                 # draining frees the buffer
    c = pipe.submit_arrays(d[:5], s[:5] + 100, s[:5] + 100.0, d[:5] + 12.9,
                           d[:5] + 77.5)
    assert c["accepted"] == 15 and pipe.pending == 5
    rec = pipe.reconcile()
    assert rec["accepted"] == rec["flushed_records"] + rec["pending"]


@given(st.integers(0, 4096), st.integers(1, 256))
@settings(deadline=None, max_examples=50)
def test_plan_chunks_partition_property(n, b_max):
    sizes = plan_chunks(n, b_max)
    assert sum(sizes) == n
    assert all(s == b_max or (s & (s - 1)) == 0 for s in sizes)
    # Bounded compile cache: at most one batch per power of two in the tail.
    tail = [s for s in sizes if s != b_max]
    assert len(tail) == len(set(tail))


def test_group_shards_sid_continuity():
    """sid_lo keeps counting across flushes, per drone, so (drone, lo) is
    unique for the session and groups follow seq order."""
    shard_seq = {}
    rows = np.arange(24, dtype=np.float32).reshape(8, 3)
    rows = np.repeat(rows, 1, axis=0)
    d = np.array([0, 0, 0, 0, 1, 1, 1, 1])
    s = np.array([3, 2, 1, 0, 0, 1, 2, 3])
    batches, left = group_shards(d, s, rows, 4, shard_seq, drain=False)
    assert left.size == 0 and list(batches) == [4]
    pay, meta, _ = batches[4]
    np.testing.assert_array_equal(meta.sid_hi, [0, 1])
    np.testing.assert_array_equal(meta.sid_lo, [0, 0])
    batches, _ = group_shards(d, s + 4, rows, 4, shard_seq, drain=False)
    np.testing.assert_array_equal(batches[4][1].sid_lo, [1, 1])


def test_query_latest_builder_surface():
    """Query().latest() is terminal and dispatches through AerialDB.query."""
    db = AerialDB.open(_cfg(), seed=0)
    p, m = DroneFleet(6, records_per_shard=R, seed=5).next_shards()
    db.insert(p, m)
    via_query = db.query(Query().latest())
    direct = db.latest()
    for f in direct._fields:
        np.testing.assert_array_equal(np.asarray(getattr(via_query, f)),
                                      np.asarray(getattr(direct, f)))
    with pytest.raises(ValueError, match="latest"):
        Query().latest().time(0, 1)
    with pytest.raises(ValueError, match="latest"):
        Query().latest().agg("mean", channel=1)
    with pytest.raises(ValueError, match="latest"):
        Query().time(0, 1).latest()
    with pytest.raises(ValueError, match="latest"):
        Query().latest() & Query().time(0, 1)
    with pytest.raises(ValueError, match="QueryPred"):
        Query().latest().build()


# ---------------------------------------------------------------------------
# Satellite: epoch-aware retention on a reclaimed-then-refilled ring
# ---------------------------------------------------------------------------


def test_retention_watermark_survives_ring_reclamation():
    """PR 7 follow-up regression: repair's ring reclamation rewinds
    ``tup_count`` below capacity; the retention watermark on that edge must
    stay FINITE on the next sweep (``tup_overwritten > 0`` marks the loss
    epoch) and equal the oldest retained timestamp — pre-fix it read
    ``-inf`` and an aged index entry lingered until the ring re-wrapped."""
    cap = 128
    cfg = _cfg(replication=1, tuple_capacity=cap, index_capacity=512,
               records_per_shard=8, retention_every=1, n_failure_domains=4)
    db = AerialDB.open(cfg, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=17)
    p, m = fleet.next_shards()
    db.insert(p, m)                        # pre-outage placement
    db.fail_device(1)
    for _ in range(2):                     # placed around the dead block
        p, m = fleet.next_shards()
        db.insert(p, m)
    db.recover_device(1)                   # repair re-places + RECLAIMS
    assert db.last_repair["slots_reclaimed"] > 0
    count = np.asarray(db.state.tup_count)
    over = np.asarray(db.state.tup_overwritten)
    reclaimed = np.nonzero((count > 0) & (count < cap) & (over > 0))[0]
    assert reclaimed.size, (count, over)

    # Inject the wrap-during-outage corner directly: a still-valid entry on
    # a reclaimed edge whose data aged out entirely (t1 far below anything
    # retained) but whose retirement sweep had not run yet.
    e = int(reclaimed[0])
    idx = db.state.index
    slot = int(np.nonzero(np.asarray(idx.valid)[e])[0][0])
    idx = idx._replace(
        ent_f=idx.ent_f.at[e, slot, 4].set(-1e9).at[e, slot, 5].set(-1e9),
        ent_i=idx.ent_i.at[e, slot, 2].set(e).at[e, slot, 3].set(-1)
                       .at[e, slot, 4].set(-1))
    db = AerialDB(cfg, db.state._replace(index=idx), db.alive,
                  jax.random.key(1))

    p, m = fleet.next_shards()
    info = db.insert(p, m)                 # retention_every=1 -> sweep
    wm = np.asarray(info["retention_watermark"])
    count2 = np.asarray(db.state.tup_count)
    still_rewound = reclaimed[count2[reclaimed] <= cap]
    assert still_rewound.size             # the rewound regime is exercised
    # THE regression: finite watermark on every reclaimed-not-rewrapped edge.
    assert np.isfinite(wm[still_rewound]).all(), wm
    # And it equals the oldest retained timestamp (the re-packed ring is
    # chronological, so retention semantics are exact).
    tup_f = np.asarray(db.state.tup_f)
    for ee in still_rewound:
        w = min(int(count2[ee]), cap)
        assert wm[ee] == tup_f[ee, 0, :w].min(), ee
    # The aged entry retired on this sweep instead of lingering to re-wrap
    # (compaction moves entries, so check by content, not slot).
    valid_e = np.asarray(db.state.index.valid)[e]
    t1_e = np.asarray(db.state.index.ent_f)[e, :, 5]
    assert not np.any(valid_e & (t1_e == -1e9))
    assert int(np.asarray(info["index_entries_retired"])[e]) >= 1


# ---------------------------------------------------------------------------
# PR 9 satellites: wall-clock flush scheduler + post-flush fan-out
# ---------------------------------------------------------------------------


def _submit_full_shards(pipe, n_drones=4, step=0):
    n = n_drones * R
    drone = np.repeat(np.arange(n_drones, dtype=np.int64), R)
    seq = np.tile(np.arange(R, dtype=np.int64), n_drones) + step * R
    t = seq.astype(np.float64)
    pipe.submit_arrays(drone, seq, t, np.full(n, 12.95), np.full(n, 77.55))
    return n


def test_maybe_flush_deadline_scheduler():
    """maybe_flush fires iff the synthetic clock passes the armed deadline,
    re-arms interval-ahead, and stamps deadline/late_s telemetry."""
    db = AerialDB.open(_cfg(), seed=0)
    pipe = IngestPipeline(db, flush_interval_s=5.0)
    _submit_full_shards(pipe)
    assert pipe.maybe_flush(now=100.0) is None       # arms at 105, no flush
    assert pipe.maybe_flush(now=104.9) is None
    out = pipe.maybe_flush(now=106.0)
    assert out is not None and out["flushed_records"] == 4 * R
    assert out["deadline"] == 105.0
    assert out["late_s"] == pytest.approx(1.0)
    assert pipe.last_flush is out
    assert pipe.maybe_flush(now=110.9) is None       # re-armed at 111
    _submit_full_shards(pipe, step=1)
    out = pipe.maybe_flush(now=111.0)
    assert out is not None and out["flushed_records"] == 4 * R
    assert out["late_s"] == pytest.approx(0.0)
    # Manual-mode pipelines reject the scheduler loudly.
    manual = IngestPipeline(db)
    with pytest.raises(ValueError, match="flush interval"):
        manual.maybe_flush(now=0.0)


def test_on_flush_fanout_is_error_isolated():
    """on_flush fires once per record-shipping flush with the summary dict;
    a raising subscriber is counted, never propagated, and never poisons
    the flush's own bookkeeping."""
    db = AerialDB.open(_cfg(), seed=0)
    seen = []

    def cb(summary):
        seen.append(summary["flushed_records"])
        raise RuntimeError("subscriber exploded")

    pipe = IngestPipeline(db, on_flush=cb)
    _submit_full_shards(pipe)
    out = pipe.flush()                               # ships -> cb fires
    assert out["flushed_records"] == 4 * R
    assert seen == [4 * R]
    assert pipe.counters["on_flush_errors"] == 1
    pipe.flush()                                     # empty -> cb silent
    assert seen == [4 * R]
    assert pipe.reconcile()["ok"]
