"""Placement + Voronoi tests (paper §3.4.1–3.4.2)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.placement import ShardMeta, place_replicas, successor_resolve
from repro.core.voronoi import voronoi_assign
from repro.data.synthetic import CityConfig, DroneFleet, make_sites
from repro.kernels.voronoi_assign import ref as vref


def _meta(n, rng, city=CityConfig()):
    lat = rng.uniform(city.lat_min, city.lat_max, (n, 2)).astype(np.float32)
    lon = rng.uniform(city.lon_min, city.lon_max, (n, 2)).astype(np.float32)
    t = rng.uniform(0, 86400, (n, 2)).astype(np.float32)
    return ShardMeta(
        sid_hi=rng.integers(0, 100, n).astype(np.int32),
        sid_lo=rng.integers(0, 1 << 30, n).astype(np.int32),
        lat0=lat.min(1), lat1=lat.max(1),
        lon0=lon.min(1), lon1=lon.max(1),
        t0=t.min(1), t1=t.max(1))


def test_voronoi_matches_bruteforce():
    rng = np.random.default_rng(0)
    sites = make_sites(20, CityConfig(), seed=3)
    pts = rng.uniform([12.85, 77.45], [13.10, 77.75], (500, 2)).astype(np.float32)
    got = np.asarray(voronoi_assign(jnp.asarray(pts), jnp.asarray(sites)))
    exp = vref.voronoi_assign_ref(pts, sites)
    # fp32 matmul-form distance can flip genuinely equidistant points; allow
    # disagreement only where the two distances are almost equal.
    diff = got != exp
    if diff.any():
        d = ((pts[diff, None, :] - sites[None]) ** 2).sum(-1)
        best2 = np.sort(d, axis=1)[:, :2]
        assert np.all((best2[:, 1] - best2[:, 0]) < 1e-4)


def test_replicas_distinct_and_alive():
    rng = np.random.default_rng(1)
    sites = jnp.asarray(make_sites(20, CityConfig(), seed=3))
    meta = _meta(256, rng)
    alive = jnp.ones(20, bool).at[jnp.asarray([3, 7])].set(False)
    reps = np.asarray(place_replicas(meta, sites, alive, 300.0))
    assert reps.shape == (256, 3)
    for row in reps:
        assert len(set(row.tolist())) == 3, row
        assert 3 not in row and 7 not in row


@given(st.integers(min_value=3, max_value=20), st.data())
@settings(deadline=None, max_examples=25)
def test_replicas_property(n_alive, data):
    """With >= 3 alive edges, placement always returns 3 distinct alive edges
    (the precondition for the paper's 2-failure durability guarantee)."""
    e = 20
    alive_idx = data.draw(st.sets(st.integers(0, e - 1), min_size=n_alive,
                                  max_size=n_alive))
    alive = np.zeros(e, bool)
    alive[list(alive_idx)] = True
    rng = np.random.default_rng(data.draw(st.integers(0, 1 << 30)))
    meta = _meta(16, rng)
    sites = jnp.asarray(make_sites(e, CityConfig(), seed=3))
    reps = np.asarray(place_replicas(meta, sites, jnp.asarray(alive), 300.0))
    for row in reps:
        assert len(set(row.tolist())) == 3
        assert all(alive[r] for r in row)


def test_successor_resolve_wraps():
    forbidden = jnp.asarray([[True, True, False, True]])
    got = successor_resolve(jnp.asarray([3], jnp.int32), forbidden)
    assert int(got[0]) == 2  # wraps 3 -> 0 -> 1 -> 2


def test_fleet_generates_valid_shards():
    fleet = DroneFleet(8, records_per_shard=12)
    payload, meta = fleet.next_shards()
    assert payload.shape == (8, 12, 7)
    assert np.all(meta.lat0 <= meta.lat1) and np.all(meta.t0 <= meta.t1)
    payload2, meta2 = fleet.next_shards()
    assert np.all(meta2.t0 >= meta.t1)  # rounds advance in time
    assert meta2.sid_lo[0] == 1
