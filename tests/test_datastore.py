"""End-to-end datastore tests: insert + query vs a global-scan oracle
(paper §3.4–3.5), including AND/OR predicates, planners, and baselines.

The default-config store is loaded once per module (module-scoped fixture);
tests that only differ in *query-time* config (planner choice) reuse it via
``dataclasses.replace`` — the state layout is identical and re-ingesting
would only re-measure the same insert path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.datastore import (StoreConfig, init_store, insert_step,
                                  make_pred, query_step)
from repro.core.placement import ShardMeta
from repro.data.synthetic import CityConfig, DroneFleet, make_sites
from repro.distributed.federation import ingest_rounds


def small_store(n_edges=8, planner="min_shards", replication=3, use_index=True):
    sites = make_sites(n_edges, CityConfig(), seed=3)
    return StoreConfig(
        n_edges=n_edges, sites=tuple(map(tuple, sites.tolist())),
        tuple_capacity=4096, index_capacity=512, max_shards_per_query=64,
        records_per_shard=12, n_values=4, planner=planner,
        replication=replication, use_index=use_index)


def load_fleet(cfg, n_drones=12, rounds=4, alive=None):
    """Ingest through the fused lax.scan driver (one dispatch for all
    rounds). Returns the same tuple shape as the old Python-loop version."""
    fleet = DroneFleet(n_drones, records_per_shard=cfg.records_per_shard)
    if alive is None:
        alive = jnp.ones(cfg.n_edges, bool)
    payloads, metas = fleet.next_rounds(rounds)
    state, _ = ingest_rounds(cfg, init_store(cfg), payloads, metas, alive)
    all_meta = [ShardMeta(*[np.asarray(f)[i] for f in metas])
                for i in range(rounds)]
    return (state, fleet, payloads.reshape(-1, *payloads.shape[2:]), all_meta)


@pytest.fixture(scope="module")
def default_loaded():
    """(cfg, state, fleet, payloads, metas) for the default small store —
    shared by every test that doesn't mutate it (queries are read-only)."""
    cfg = small_store()
    state, fleet, payloads, metas = load_fleet(cfg)
    return cfg, state, fleet, payloads, metas


def oracle(payloads, pred, qi):
    """Global scan over every inserted tuple (replication-free semantics)."""
    t, lat, lon, v0 = (payloads[..., 0].ravel(), payloads[..., 1].ravel(),
                       payloads[..., 2].ravel(), payloads[..., 3].ravel())
    p = jax.tree.map(lambda x: np.asarray(x)[qi], pred)
    sp = (p.lat0 <= lat) & (lat <= p.lat1) & (p.lon0 <= lon) & (lon <= p.lon1)
    tp = (p.t0 <= t) & (t <= p.t1)
    # sid of each tuple: payloads are (rounds*D, R, W) in drone-major order
    n_shards, r = payloads.shape[0], payloads.shape[1]
    m_and = (sp | ~p.has_spatial) & (tp | ~p.has_temporal)
    m_or = (sp & p.has_spatial) | (tp & p.has_temporal)
    m = m_and if p.is_and else m_or
    return m, v0


def check_result(result, qi, m, v0):
    cnt = int(np.asarray(result.count)[qi])
    assert cnt == int(m.sum()), (cnt, int(m.sum()))
    if cnt:
        np.testing.assert_allclose(np.asarray(result.vsum)[qi], v0[m].sum(), rtol=1e-4)
        np.testing.assert_allclose(np.asarray(result.vmin)[qi], v0[m].min(), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(result.vmax)[qi], v0[m].max(), rtol=1e-5)


@pytest.mark.parametrize("planner", ["random", "min_shards", "min_edges"])
def test_query_matches_oracle(default_loaded, planner):
    cfg, state, fleet, payloads, _ = default_loaded
    cfg = dataclasses.replace(cfg, planner=planner)
    alive = jnp.ones(cfg.n_edges, bool)
    city = CityConfig()
    pred = make_pred(
        q=4,
        lat0=[city.lat_min, 12.9, 12.95, city.lat_min],
        lat1=[city.lat_max, 13.0, 13.05, city.lat_max],
        lon0=[city.lon_min, 77.5, 77.55, city.lon_min],
        lon1=[city.lon_max, 77.6, 77.65, city.lon_max],
        t0=[0.0, 0.0, 60.0, 100.0],
        t1=[1e9, 120.0, 180.0, 150.0],
        has_spatial=True, has_temporal=True, is_and=True)
    result, info = query_step(cfg, state, pred, alive, jax.random.key(0))
    assert not bool(np.asarray(result.overflow).any())
    for qi in range(4):
        m, v0 = oracle(payloads, pred, qi)
        check_result(result, qi, m, v0)


def test_or_query_matches_oracle(default_loaded):
    cfg, state, fleet, payloads, _ = default_loaded
    alive = jnp.ones(cfg.n_edges, bool)
    pred = make_pred(q=2, lat0=12.9, lat1=12.95, lon0=77.5, lon1=77.6,
                     t0=[0.0, 30.0], t1=[60.0, 90.0],
                     has_spatial=True, has_temporal=True, is_and=False)
    result, info = query_step(cfg, state, pred, alive, jax.random.key(1))
    for qi in range(2):
        m, v0 = oracle(payloads, pred, qi)
        check_result(result, qi, m, v0)


def test_sid_query(default_loaded):
    """shardID point query (H_i path): returns exactly that shard's tuples."""
    cfg, state, fleet, payloads, metas = default_loaded
    alive = jnp.ones(cfg.n_edges, bool)
    pred = make_pred(q=1, sid_hi=3, sid_lo=1, has_sid=True, is_and=True)
    result, info = query_step(cfg, state, pred, alive, jax.random.key(2))
    assert int(result.count[0]) == cfg.records_per_shard
    # drone 3, round 1 lives at payload row 1*12+3
    v0 = payloads[1 * 12 + 3, :, 3]
    np.testing.assert_allclose(float(result.vsum[0]), v0.sum(), rtol=1e-4)


def test_no_duplicates_despite_replication(default_loaded):
    """3x replication must not triple-count: each shard is queried on exactly
    one replica edge (paper §3.5.2). (Default config is replication=3.)"""
    cfg, state, fleet, payloads, _ = default_loaded
    alive = jnp.ones(cfg.n_edges, bool)
    pred = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True, is_and=True)
    result, _ = query_step(cfg, state, pred, alive, jax.random.key(3))
    assert int(result.count[0]) == payloads.shape[0] * payloads.shape[1]


def test_broadcast_baseline_matches_oracle():
    """Feather-like config (no index, replication=1) still answers exactly."""
    cfg = small_store(replication=1, use_index=False)
    state, fleet, payloads, _ = load_fleet(cfg)
    alive = jnp.ones(cfg.n_edges, bool)
    pred = make_pred(q=1, lat0=12.9, lat1=13.0, lon0=77.5, lon1=77.65,
                     t0=0.0, t1=200.0, has_spatial=True, has_temporal=True)
    result, info = query_step(cfg, state, pred, alive, jax.random.key(4))
    m, v0 = oracle(payloads, pred, 0)
    check_result(result, 0, m, v0)
    assert bool(np.asarray(info.broadcast)[0])


def test_centralized_baseline():
    """Cloud baseline: E=1 stores everything on one edge."""
    cfg = small_store(n_edges=1, replication=1)
    state, fleet, payloads, _ = load_fleet(cfg, n_drones=6, rounds=2)
    alive = jnp.ones(1, bool)
    pred = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True)
    result, _ = query_step(cfg, state, pred, alive, jax.random.key(5))
    assert int(result.count[0]) == payloads.shape[0] * payloads.shape[1]


@pytest.mark.slow
def test_insert_telemetry_and_balance():
    cfg = small_store()
    state, fleet, payloads, _ = load_fleet(cfg, n_drones=32, rounds=3)
    per_edge = np.asarray(state.tup_count)
    # every shard lands on exactly 3 edges
    assert per_edge.sum() == 32 * 3 * cfg.records_per_shard * 3
    assert int(np.asarray(state.tup_dropped).sum()) == 0
    # §4.4.2-style balance: no edge holds a wildly disproportionate share
    assert per_edge.max() < 4 * per_edge.mean()
