"""Churn-safe incremental repair (paper §4.5.3 sustained churn).

The contract under test: the outage-epoch ledger the session keeps (one
record per failure event, closed at recovery) lets ``repair_state`` sweep
ONLY the shards an outage could have touched, and that incremental sweep is
**bitwise identical** to the classic full sweep — property-tested under
random fail/ingest/recover interleavings (including retention wrap during
the outage) and differentially on both mesh layouts. Plus the satellite
regressions: the backfill clamp corners (``hit == cap`` / ``hit > cap``),
the empty-ledger telemetry-only no-op, the multi-process repair guard, ring
reclamation of stale copies, and the O(outage)-not-O(store) sweep scaling.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import AerialDB, AggSpec
from repro.core.datastore import StoreConfig, make_pred
from repro.core.repair import (OutageLog, _backfill_copy, _chrono_order,
                               repair_state, sid_key)
from repro.data.synthetic import CityConfig, DroneFleet, make_sites
from repro.launch.mesh import make_edge_mesh, make_fleet_mesh

E = 8
N_DEV = 4
CAP = 256          # small ring: sustained ingest wraps it mid-outage
CATCH_ALL = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True, is_and=True)


def _cfg(**overrides):
    sites = make_sites(E, CityConfig(), seed=3)
    kw = dict(n_edges=E, sites=tuple(map(tuple, sites.tolist())),
              tuple_capacity=CAP, index_capacity=512,
              max_shards_per_query=64, records_per_shard=8,
              retention_every=2, n_failure_domains=4)
    kw.update(overrides)
    return StoreConfig(**kw)


CFG = _cfg()


def _assert_states_identical(ref, fed, msg=""):
    names = [jax.tree_util.keystr(p) for p, _
             in jax.tree_util.tree_flatten_with_path(ref)[0]]
    for name, a, b in zip(names, jax.tree.leaves(ref), jax.tree.leaves(fed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{msg}{name}")


def _ingest(db, fleet, rounds=1):
    for _ in range(rounds):
        p, m = fleet.next_shards()
        db.insert(p, m)
    return p, m


def _total_count(db):
    res, _ = db.query(CATCH_ALL, key=jax.random.key(0))
    return int(res.count[0])


# ---------------------------------------------------------------------------
# Tentpole: incremental sweep == full sweep, bitwise
# ---------------------------------------------------------------------------


@given(st.integers(0, 1 << 30))
@settings(deadline=None, max_examples=8)
def test_incremental_repair_matches_full_sweep_property(seed):
    """Random fail/ingest/recover interleavings: at every repair point the
    ledger-driven incremental sweep must land on the bitwise-identical state
    the full sweep produces from the same pre-state, while sweeping no more
    shards than it. Small rings (CAP tuples) make sustained schedules wrap
    retention mid-outage; partial recoveries exercise the pending-sweep
    bookkeeping."""
    rng = np.random.default_rng(seed)
    db = AerialDB.open(CFG, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=int(rng.integers(1 << 20)))
    dead = set()
    _ingest(db, fleet, 2)
    repairs = 0
    for _ in range(int(rng.integers(8, 14))):
        op = rng.choice(["ingest", "fail", "recover"], p=[0.5, 0.25, 0.25])
        if op == "ingest":
            _ingest(db, fleet, int(rng.integers(1, 3)))
        elif op == "fail":
            candidates = sorted(set(range(E)) - dead)
            if len(candidates) <= 3:
                continue
            k = min(int(rng.integers(1, 3)), len(candidates) - 3)
            edges = [int(e) for e in rng.choice(candidates, size=k,
                                                replace=False)]
            db.fail_edges(edges)
            dead |= set(edges)
        else:
            if not dead:
                continue
            k = int(rng.integers(1, len(dead) + 1))
            edges = [int(e) for e in rng.choice(sorted(dead), size=k,
                                                replace=False)]
            db.recover_edges(edges, repair=False)
            dead -= set(edges)
            pre = db.state
            full_state, full_info = repair_state(CFG, pre, db.alive,
                                                 outage=None)
            inc_info = db.repair()          # incremental, consumes the ledger
            _assert_states_identical(full_state, db.state,
                                     msg=f"seed={seed}: ")
            assert inc_info["mode"] == "incremental"
            assert inc_info["shards_swept"] <= full_info["shards_swept"]
            repairs += 1
    # Drain: recover everything and repair once more against the oracle.
    if dead:
        db.recover_edges(sorted(dead), repair=False)
        full_state, _ = repair_state(CFG, db.state, db.alive, outage=None)
        db.repair()
        _assert_states_identical(full_state, db.state, msg=f"seed={seed}: ")
        repairs += 1
    assert repairs > 0 or not dead


def test_incremental_repair_reattempts_ingest_time_index_drops():
    """PR 7's documented divergence, closed: entries dropped at ingest by a
    momentarily-full index table are re-attempted by the INCREMENTAL sweep
    too — the session watches per-insert ``index_entries_dropped`` telemetry
    and folds the affected batches' sids into the ledger's pending set, so
    ``repair()`` with an otherwise-empty ledger (no outage ever) lands on
    the bitwise-identical state of ``repair(full=True)`` instead of being a
    no-op that leaves the dropped entries missing."""
    cfg = _cfg(index_capacity=32, retention_every=1 << 20)  # drops, no sweeps
    db_inc = AerialDB.open(cfg, seed=0)
    db_full = AerialDB.open(cfg, seed=0)
    fleets = [DroneFleet(12, records_per_shard=8, seed=23) for _ in range(2)]
    for db, fleet in zip((db_inc, db_full), fleets):
        _ingest(db, fleet, 6)
    assert int(np.asarray(db_inc.state.index.dropped).sum()) > 0
    inc = db_inc.repair()                  # incremental, NO outage on ledger
    full = db_full.repair(full=True)
    assert inc["mode"] == "incremental"
    assert inc["shards_swept"] > 0         # the gap: pre-change this was 0
    assert inc["shards_swept"] <= full["shards_swept"]
    _assert_states_identical(db_inc.state, db_full.state)


def test_incremental_repair_retention_wrap_during_outage():
    """Deterministic wrap coverage: enough sustained ingest during the
    outage to wrap rings (tup_count > CAP) and run retention sweeps, then
    recover — incremental must still equal the full sweep bitwise and the
    catch-all query must return to full completeness."""
    db = AerialDB.open(CFG, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=7)
    _ingest(db, fleet, 2)
    db.fail_device(1)
    _, m_last = _ingest(db, fleet, 8)
    assert int(np.asarray(db.state.tup_count).max()) > CAP   # wrapped
    db.recover_device(1, repair=False)
    full_state, _ = repair_state(CFG, db.state, db.alive, outage=None)
    info = db.repair()
    _assert_states_identical(full_state, db.state)
    assert info["shards_replaced"] > 0
    # The freshest (retention-safe) shards answer completely after repair,
    # and the degradation keys ride in the result view (tentpole c).
    hi = np.asarray(m_last.sid_hi).reshape(-1)
    lo = np.asarray(m_last.sid_lo).reshape(-1)
    pred = make_pred(q=hi.size, sid_hi=hi, sid_lo=lo, has_sid=True)
    res, qi = db.query(pred, key=jax.random.key(1))
    np.testing.assert_array_equal(np.asarray(res.count), 8)
    view = res.view(AggSpec())
    np.testing.assert_array_equal(np.asarray(view["completeness_bound"]), 1.0)
    np.testing.assert_array_equal(np.asarray(view["replicas_lost"]), 0)


@pytest.fixture(params=["edge4", "fleet2x2"])
def mesh(request):
    if jax.device_count() < N_DEV:
        pytest.skip(f"needs {N_DEV} host devices")
    if request.param == "edge4":
        return make_edge_mesh(N_DEV)
    return make_fleet_mesh(2, N_DEV // 2)


def test_incremental_repair_differential_mesh(mesh):
    """The existing differential harness shape, with churn: the same scripted
    fail/ingest/recover/repair sequence through the single-device facade and
    the sharded facade must keep states bitwise identical and report the
    same (incremental) repair telemetry — and both must equal the full-sweep
    oracle at every repair point."""
    db_ref = AerialDB.open(CFG, seed=0)
    db_fed = AerialDB.open(CFG, mesh=mesh, seed=0)
    fleets = [DroneFleet(12, records_per_shard=8, seed=11) for _ in range(2)]

    def both(fn):
        for db, fleet in zip((db_ref, db_fed), fleets):
            fn(db, fleet)

    def repair_and_check():
        full_state, _ = repair_state(CFG, db_ref.state, db_ref.alive,
                                     outage=None)
        i_ref = db_ref.repair()
        i_fed = db_fed.repair()
        assert i_ref == i_fed
        assert i_ref["mode"] == "incremental"
        _assert_states_identical(full_state, db_ref.state, msg="ref vs full: ")
        _assert_states_identical(db_ref.state, db_fed.state,
                                 msg="ref vs fed: ")

    both(lambda db, f: _ingest(db, f, 2))
    both(lambda db, f: db.fail_device(1))
    both(lambda db, f: _ingest(db, f, 2))
    both(lambda db, f: db.recover_device(1, repair=False))
    repair_and_check()
    # Overlapping outages with a partial recovery: pending-sweep path.
    both(lambda db, f: db.fail_edges(0))
    both(lambda db, f: _ingest(db, f, 1))
    both(lambda db, f: db.fail_edges(5))
    both(lambda db, f: _ingest(db, f, 1))
    both(lambda db, f: db.recover_edges(0, repair=False))
    repair_and_check()                       # edge 5 still dead: pending set
    both(lambda db, f: _ingest(db, f, 1))
    both(lambda db, f: db.recover_edges(5, repair=False))
    repair_and_check()
    assert _total_count(db_ref) == _total_count(db_fed)


# ---------------------------------------------------------------------------
# O(outage) scaling + ring reclamation
# ---------------------------------------------------------------------------


def test_sweep_scales_with_outage_not_store():
    """A short outage in a long-lived store: the sweep must select roughly
    the outage window's shards, not every tracked shard."""
    db = AerialDB.open(CFG, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=13)
    _ingest(db, fleet, 8)                    # long history, all-alive
    db.fail_edges(1)
    _ingest(db, fleet, 1)                    # one round during the outage
    db.recover_edges(1)                      # incremental repair
    rep = db.last_repair
    assert rep["shards_swept"] > 0
    assert rep["shards_tracked"] >= 3 * rep["shards_swept"], rep


def test_repair_reclaims_stale_copies_on_dropped_edges():
    """Shards placed around an outage move back onto the recovered edges at
    repair; the edges dropped by that re-placement must have their stale
    slots retired eagerly — every tracked shard's tuple holders equal its
    index replica set afterwards, with no count lost."""
    db = AerialDB.open(CFG, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=17)
    _ingest(db, fleet, 1)
    db.fail_device(1)
    _ingest(db, fleet, 2)
    before = _total_count(db)
    db.recover_device(1)                     # repair w/ reclamation
    rep = db.last_repair
    assert rep["shards_replaced"] > 0
    assert rep["slots_reclaimed"] > 0, rep
    assert _total_count(db) == before        # reclamation lost no data
    # Holder sets now match the (rewritten) index exactly: no stale copies.
    ent_i = np.asarray(db.state.index.ent_i)
    valid = np.asarray(db.state.index.valid)
    tup_sid = np.asarray(db.state.tup_sid)
    windows = np.minimum(np.asarray(db.state.tup_count), CAP)
    ev, ec = np.nonzero(valid)
    shard_reps = {}
    for v, c in zip(ev, ec):
        k = sid_key(ent_i[v, c, 0], ent_i[v, c, 1])
        shard_reps[k] = {int(r) for r in ent_i[v, c, 2:5] if r >= 0}
    for k, reps in shard_reps.items():
        hi, lo = np.int32(k >> 32), np.int32(k & 0xFFFFFFFF)
        holders = {int(e) for e in range(E)
                   if np.any((tup_sid[e, 0, :windows[e]] == hi)
                             & (tup_sid[e, 1, :windows[e]] == lo))}
        assert holders == reps, (k, holders, reps)


def test_reclaimed_ring_slots_are_reset():
    """Freed slots read as never-written (sid -1, zero payload) and the ring
    cursor/count rewind consistently (count == live tuples, pos == count %
    cap) so subsequent ingest through the normal cursor stays sound."""
    db = AerialDB.open(CFG, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=19)
    db.fail_device(1)
    _ingest(db, fleet, 2)
    db.recover_device(1)
    assert db.last_repair["slots_reclaimed"] > 0
    tup_sid = np.asarray(db.state.tup_sid)
    tup_f = np.asarray(db.state.tup_f)
    count = np.asarray(db.state.tup_count)
    pos = np.asarray(db.state.tup_pos)
    for e in range(E):
        w = min(int(count[e]), CAP)
        assert (tup_sid[e, 0, w:] == -1).all(), e
        assert (tup_f[e, :, w:] == 0).all(), e
        if int(count[e]) <= CAP:
            assert int(pos[e]) == int(count[e]) % CAP, e
    # The store keeps ingesting and answering exactly after reclamation.
    before = _total_count(db)
    p, m = fleet.next_shards()
    db.insert(p, m)
    assert _total_count(db) == before + 12 * 8


# ---------------------------------------------------------------------------
# Satellite: backfill clamp corners
# ---------------------------------------------------------------------------


def _ring_fixture(cap, width=4, n_edges=2):
    tup_f = np.zeros((n_edges, width, cap * 2), np.float32)
    tup_sid = np.full((n_edges, 2, cap * 2), -1, np.int32)
    tup_count = np.zeros(n_edges, np.int64)
    tup_pos = np.zeros(n_edges, np.int64)
    tup_over = np.zeros(n_edges, np.int64)
    return tup_f, tup_sid, tup_count, tup_pos, tup_over


def test_backfill_full_ring_hit_exact_telemetry():
    """hit == cap: the copy fills the destination ring exactly once (no slot
    written twice) and the overwrite telemetry counts exactly the slots that
    held prior data."""
    cap = 8
    tup_f, tup_sid, tup_count, tup_pos, tup_over = _ring_fixture(cap)
    src, dst, hi, lo = 0, 1, 7, 1
    tup_f[src, :, :cap] = np.arange(cap, dtype=np.float32)[None, :]
    tup_sid[src, 0, :cap] = hi
    tup_sid[src, 1, :cap] = lo
    tup_count[src] = cap
    tup_count[dst], tup_pos[dst] = 3, 3          # 3 pre-existing tuples
    hit = np.arange(cap, dtype=np.int64)
    n = _backfill_copy(tup_f, tup_sid, tup_count, tup_pos, tup_over,
                       src, dst, hit, hi, lo, cap)
    assert n == cap
    assert int(tup_count[dst]) == 3 + cap
    assert int(tup_over[dst]) == 3               # exactly the prior tuples
    assert int(tup_pos[dst]) == (3 + cap) % cap
    # every ring slot written exactly once, in chronological order
    want = np.roll(np.arange(cap, dtype=np.float32), 3)
    np.testing.assert_array_equal(tup_f[dst, 0, :cap], want)
    assert (tup_sid[dst, 0, :cap] == hi).all()


def test_backfill_oversized_hit_clamps_to_newest():
    """hit > cap (a self-overwriting scatter in the old code): the copy is
    clamped to the NEWEST cap tuples, tup_count grows by at most cap, and
    tup_overwritten never exceeds what the ring physically recycled."""
    cap = 8
    tup_f, tup_sid, tup_count, tup_pos, tup_over = _ring_fixture(cap)
    src, dst, hi, lo = 0, 1, 9, 2
    n_hit = 12
    tup_f[src, :, :n_hit] = np.arange(n_hit, dtype=np.float32)[None, :]
    tup_sid[src, 0, :n_hit] = hi
    tup_sid[src, 1, :n_hit] = lo
    tup_count[src] = n_hit                        # chronological == slot order
    hit = np.arange(n_hit, dtype=np.int64)
    n = _backfill_copy(tup_f, tup_sid, tup_count, tup_pos, tup_over,
                       src, dst, hit, hi, lo, cap)
    assert n == cap                               # clamped
    assert int(tup_count[dst]) == cap             # not inflated to 12
    assert int(tup_over[dst]) == 0                # ring was empty: recycled 0
    assert int(tup_pos[dst]) == 0
    # the NEWEST cap tuples survive (4..11), oldest 4 dropped
    np.testing.assert_array_equal(tup_f[dst, 0, :cap],
                                  np.arange(n_hit - cap, n_hit,
                                            dtype=np.float32))


def test_chrono_order_wrapped_ring():
    """Wrapped rings order slots oldest-first starting at tup_pos."""
    cap = 8
    slots = np.array([0, 1, 5, 7], np.int64)
    # unwrapped: ascending slots
    np.testing.assert_array_equal(_chrono_order(slots, 6, 6, cap),
                                  [0, 1, 5, 7])
    # wrapped at pos=6: chronological = 7, 0, 1, 5
    np.testing.assert_array_equal(_chrono_order(slots, 20, 6, cap),
                                  [7, 0, 1, 5])


# ---------------------------------------------------------------------------
# Satellite: no-op repair, multi-process guard, ledger honesty
# ---------------------------------------------------------------------------


def test_empty_ledger_repair_is_telemetry_only_noop():
    """No recorded outages: repair() must not sweep anything, and
    last_repair still reports honestly (tracked count, zeroed work)."""
    db = AerialDB.open(CFG, seed=0)
    _ingest(db, DroneFleet(12, records_per_shard=8, seed=23), 2)
    before = db.state
    info = db.repair()
    assert info["mode"] == "incremental"
    assert info["shards_tracked"] > 0
    assert info["shards_swept"] == 0
    for k in ("shards_replaced", "shards_unrepairable", "tuples_copied",
              "slots_reclaimed", "entries_rewritten", "entries_backfilled",
              "entries_dropped"):
        assert info[k] == 0, k
    assert db.last_repair == info
    assert "_swept_keys" not in info             # facade-internal, popped
    _assert_states_identical(before, db.state)   # literally untouched


def test_fail_recover_without_ingest_repairs_nothing():
    """An outage with no ingest during it closes an EMPTY epoch window
    (fail_step == recover_step) and leaves no still-dead edges — nothing
    could have changed, so recovery's repair is the telemetry-only no-op
    and the state is bitwise unchanged."""
    db = AerialDB.open(CFG, seed=0)
    _ingest(db, DroneFleet(12, records_per_shard=8, seed=29), 2)
    before = db.state
    db.fail_edges(2, 6)
    db.recover_edges(2, 6)                       # repair=True default
    info = db.last_repair
    assert info["shards_swept"] == 0             # empty window: no suspects
    assert info["shards_tracked"] > 0            # ...reported honestly
    for k in ("shards_replaced", "tuples_copied", "slots_reclaimed",
              "entries_rewritten", "entries_backfilled"):
        assert info[k] == 0, k
    _assert_states_identical(before, db.state)
    # the full sweep agrees there was nothing to do
    full_state, _ = repair_state(CFG, before, db.alive, outage=None)
    _assert_states_identical(full_state, db.state)


def test_repair_full_flag_sweeps_everything():
    db = AerialDB.open(CFG, seed=0)
    _ingest(db, DroneFleet(12, records_per_shard=8, seed=31), 2)
    info = db.repair(full=True)
    assert info["mode"] == "full"
    assert info["shards_swept"] == info["shards_tracked"] > 0


def test_repair_multiprocess_guard(monkeypatch):
    """repair() host-gathers the full store — single-process only (ROADMAP
    cross-host contract). Under process_count > 1 it must refuse loudly
    instead of silently repairing divergent per-process slices."""
    db = AerialDB.open(CFG, seed=0)
    _ingest(db, DroneFleet(12, records_per_shard=8, seed=37), 1)
    monkeypatch.setattr(jax, "process_count", lambda: 2)
    with pytest.raises(NotImplementedError, match="single-process"):
        db.repair()
    db.fail_edges(1)
    with pytest.raises(NotImplementedError, match="single-process"):
        db.recover_edges(1)                      # default repair path too
    # the documented escape hatch stays available
    db.recover_edges(1, repair=False)
    assert bool(db.alive.all())


def test_adopted_degraded_state_gets_conservative_ledger():
    """A session adopting a state with dead edges has no outage history:
    its first repair after recovery must cover every entry (fail_step -1
    window) rather than assuming the mask was always whole."""
    db = AerialDB.open(CFG, seed=0)
    fleet = DroneFleet(12, records_per_shard=8, seed=41)
    db.fail_edges(3)
    _ingest(db, fleet, 2)                        # placed around edge 3
    # Adopt the raw parts into a fresh session: ledger knowledge is lost.
    db2 = AerialDB(db.cfg, db.state, db.alive, jax.random.key(0))
    db2.recover_edges(3, repair=False)
    full_state, _ = repair_state(CFG, db2.state, db2.alive, outage=None)
    info = db2.repair()
    assert info["shards_swept"] > 0
    _assert_states_identical(full_state, db2.state)
