"""Unit + property tests for the lane-split xxHash64 and hash functions."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import hashing
from repro.kernels.hash64 import ref as href

U32 = st.integers(min_value=0, max_value=(1 << 32) - 1)


@given(st.lists(st.tuples(U32, U32), min_size=1, max_size=32))
@settings(deadline=None, max_examples=50)
def test_xxh64_matches_reference(pairs):
    hi = np.array([p[0] for p in pairs], np.uint32)
    lo = np.array([p[1] for p in pairs], np.uint32)
    got_hi, got_lo = hashing.xxh64_u64((jnp.asarray(hi), jnp.asarray(lo)))
    exp_hi, exp_lo = href.xxh64_batch_py(hi, lo)
    np.testing.assert_array_equal(np.asarray(got_hi), exp_hi)
    np.testing.assert_array_equal(np.asarray(got_lo), exp_lo)


@given(U32, U32, st.integers(min_value=1, max_value=65535))
@settings(deadline=None, max_examples=100)
def test_mod_u64(hi, lo, n):
    got = hashing.mod_u64((jnp.uint32(hi), jnp.uint32(lo)), n)
    assert int(got) == ((hi << 32) | lo) % n


def test_mul64_random():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1 << 64, 256, dtype=np.uint64)
    b = rng.integers(0, 1 << 64, 256, dtype=np.uint64)
    ah = jnp.asarray((a >> 32).astype(np.uint32))
    al = jnp.asarray((a & 0xFFFFFFFF).astype(np.uint32))
    bh = jnp.asarray((b >> 32).astype(np.uint32))
    bl = jnp.asarray((b & 0xFFFFFFFF).astype(np.uint32))
    hi, lo = hashing.mul64((ah, al), (bh, bl))
    exp = (a.astype(object) * b.astype(object)) % (1 << 64)
    exp_hi = np.array([int(x) >> 32 for x in exp], np.uint32)
    exp_lo = np.array([int(x) & 0xFFFFFFFF for x in exp], np.uint32)
    np.testing.assert_array_equal(np.asarray(hi), exp_hi)
    np.testing.assert_array_equal(np.asarray(lo), exp_lo)


def test_hash_shard_id_uniformity():
    """Placement balance: xxh64 mod E over sequential ids must be near-uniform
    (underpins the paper's §4.4.2 load-balance observation)."""
    n, e = 20000, 20
    sid_hi = jnp.zeros(n, jnp.int32)
    sid_lo = jnp.arange(n, dtype=jnp.int32)
    edges = np.asarray(hashing.hash_shard_id(sid_hi, sid_lo, e))
    counts = np.bincount(edges, minlength=e)
    assert counts.min() > 0.85 * n / e
    assert counts.max() < 1.15 * n / e


def test_hash_time_debunches_periodicity():
    """Shards collected every tau seconds must not hit one edge repeatedly."""
    e, tau = 20, 300.0
    t = jnp.arange(0, 600) * tau  # exactly one per bucket
    edges = np.asarray(hashing.hash_time(t.astype(jnp.float32), tau, e))
    counts = np.bincount(edges, minlength=e)
    assert counts.max() < 3.0 * len(t) / e


def test_time_bucket_widths():
    t = jnp.asarray([0.0, 299.9, 300.0, 599.9, 600.0])
    np.testing.assert_array_equal(
        np.asarray(hashing.time_bucket(t, 300.0)), [0, 0, 1, 1, 2])
