"""Per-kernel allclose tests against the pure oracles, swept over shapes and
dtypes, executed in Pallas interpret mode (CPU validation of the TPU target).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.datastore import make_pred
from repro.data.synthetic import CityConfig, make_sites
from repro.kernels.hash64 import ref as href
from repro.kernels.hash64.hash64 import xxh64
from repro.kernels.st_scan import ops as st_ops
from repro.kernels.st_scan import ref as st_ref
from repro.kernels.voronoi_assign import ref as vref
from repro.kernels.voronoi_assign.voronoi_assign import voronoi_assign


# ---------------------------------------------------------------------------
# hash64
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 1024, 3000])
def test_hash64_kernel_vs_oracle(n):
    rng = np.random.default_rng(n)
    hi = rng.integers(0, 1 << 32, n, dtype=np.uint32)
    lo = rng.integers(0, 1 << 32, n, dtype=np.uint32)
    got_hi, got_lo = xxh64(jnp.asarray(hi), jnp.asarray(lo), interpret=True)
    exp_hi, exp_lo = href.xxh64_batch_py(hi, lo)
    np.testing.assert_array_equal(np.asarray(got_hi), exp_hi)
    np.testing.assert_array_equal(np.asarray(got_lo), exp_lo)


# ---------------------------------------------------------------------------
# voronoi_assign
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,e,block", [(64, 8, 64), (1000, 20, 256), (4096, 80, 1024)])
def test_voronoi_kernel_vs_oracle(n, e, block):
    rng = np.random.default_rng(e)
    sites = make_sites(e, CityConfig(), seed=3)
    pts = rng.uniform([12.85, 77.45], [13.10, 77.75], (n, 2)).astype(np.float32)
    got = np.asarray(voronoi_assign(jnp.asarray(pts), jnp.asarray(sites),
                                    block_p=block, interpret=True))
    exp = vref.voronoi_assign_ref(pts, sites)
    diff = got != exp
    if diff.any():  # only near-equidistant points may disagree (fp32)
        d = ((pts[diff, None, :] - sites[None]) ** 2).sum(-1)
        best2 = np.sort(d, axis=1)[:, :2]
        assert np.all((best2[:, 1] - best2[:, 0]) < 1e-7)


# ---------------------------------------------------------------------------
# st_scan
# ---------------------------------------------------------------------------

def random_scan_problem(rng, e=4, c=1024, q=3, l=8, w=7):
    """Random column-major scan problem: (E, W, C) log, (E, 2, C) sids."""
    tup_f = rng.uniform(0, 100, (e, w, c)).astype(np.float32)
    tup_sid = rng.integers(0, 6, (e, 2, c)).astype(np.int32)
    tup_count = rng.integers(0, c + 1, (e,)).astype(np.int32)
    sublists = rng.integers(0, 6, (q, e, l, 2)).astype(np.int32)
    sublist_len = rng.integers(-1, l + 1, (q, e)).astype(np.int32)
    pred = make_pred(
        q=q,
        lat0=rng.uniform(0, 50, q).astype(np.float32),
        lat1=rng.uniform(50, 100, q).astype(np.float32),
        lon0=rng.uniform(0, 50, q).astype(np.float32),
        lon1=rng.uniform(50, 100, q).astype(np.float32),
        t0=rng.uniform(0, 50, q).astype(np.float32),
        t1=rng.uniform(50, 100, q).astype(np.float32),
        sid_hi=rng.integers(0, 6, q).astype(np.int32),
        sid_lo=rng.integers(0, 6, q).astype(np.int32),
        has_spatial=rng.random(q) < 0.7,
        has_temporal=rng.random(q) < 0.7,
        has_sid=rng.random(q) < 0.3,
        is_and=rng.random(q) < 0.7)
    return (jnp.asarray(tup_f), jnp.asarray(tup_sid), jnp.asarray(tup_count),
            pred, jnp.asarray(sublists), jnp.asarray(sublist_len))


@pytest.mark.parametrize("seed,c,block", [(0, 512, 128), (1, 1024, 256),
                                          (2, 1536, 512), (3, 640, 128)])
def test_st_scan_kernel_vs_ref(seed, c, block):
    rng = np.random.default_rng(seed)
    args = random_scan_problem(rng, c=c)
    exp = st_ref.st_scan_ref(*args)
    got = st_ops.st_scan(*args, block_c=block, interpret=True)
    for g, x, name in zip(got, exp, ["count", "vsum", "vmin", "vmax"]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(x), rtol=1e-5,
                                   err_msg=name)


def test_st_scan_scan_all_sentinel():
    """sublist_len < 0 must scan without shard scoping (broadcast mode)."""
    rng = np.random.default_rng(7)
    tup_f, tup_sid, tup_count, pred, sublists, _ = random_scan_problem(rng)
    q, e = sublists.shape[:2]
    slen = jnp.full((q, e), -1, jnp.int32)
    exp = st_ref.st_scan_ref(tup_f, tup_sid, tup_count, pred, sublists, slen)
    got = st_ops.st_scan(tup_f, tup_sid, tup_count, pred, sublists, slen,
                         block_c=256, interpret=True)
    for g, x in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(x), rtol=1e-5)


def test_st_scan_ring_count_clamp():
    """Ring-buffer validity: tup_count above capacity (monotonic total-written
    counter) must behave exactly like a full log — min(count, cap) — in both
    engines."""
    rng = np.random.default_rng(11)
    tup_f, tup_sid, _, pred, sublists, slen = random_scan_problem(rng)
    c = tup_f.shape[2]            # column-major: the tuple axis is last
    over = jnp.asarray(rng.integers(c + 1, 5 * c, tup_f.shape[0]), jnp.int32)
    full = jnp.full(tup_f.shape[0], c, jnp.int32)
    exp = st_ref.st_scan_ref(tup_f, tup_sid, full, pred, sublists, slen)
    got_ref = st_ref.st_scan_ref(tup_f, tup_sid, over, pred, sublists, slen)
    got_ker = st_ops.st_scan(tup_f, tup_sid, over, pred, sublists, slen,
                             block_c=256, interpret=True)
    for g, x in zip(got_ref, exp):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(x))
    for g, x in zip(got_ker, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(x), rtol=1e-5)


def test_st_scan_empty_edges():
    rng = np.random.default_rng(9)
    tup_f, tup_sid, _, pred, sublists, slen = random_scan_problem(rng)
    zero = jnp.zeros(tup_f.shape[0], jnp.int32)
    got = st_ops.st_scan(tup_f, tup_sid, zero, pred, sublists, slen,
                         block_c=256, interpret=True)
    assert int(np.asarray(got[0]).sum()) == 0


def _assert_kernel_matches_ref(args, block_c, interpret):
    """Pallas vs ref: counts bitwise, float aggregates to accumulation order.
    ``interpret=None`` exercises the auto dispatch (compiled on TPU,
    interpreted elsewhere)."""
    exp = st_ref.st_scan_ref(*args)
    got = st_ops.st_scan(*args, block_c=block_c, interpret=interpret)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]),
                                  err_msg="count")
    for g, x, name in zip(got[1:], exp[1:], ["vsum", "vmin", "vmax"]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(x), rtol=1e-5,
                                   err_msg=name)


@pytest.mark.parametrize("interpret", [True, None])
@pytest.mark.parametrize("c", [100, 129, 384])
def test_st_scan_non_lane_multiple_capacity(c, interpret):
    """Capacities that are not lane (128) or block multiples force the
    wrapper's C padding; padded lanes must never be admitted."""
    rng = np.random.default_rng(c)
    args = random_scan_problem(rng, c=c)
    _assert_kernel_matches_ref(args, block_c=128, interpret=interpret)


@pytest.mark.parametrize("interpret", [True, None])
def test_st_scan_zero_count_everywhere(interpret):
    """tup_count == 0 on every edge: both engines agree on all-zero counts
    even though the tuple arrays hold (stale) data."""
    rng = np.random.default_rng(21)
    tup_f, tup_sid, _, pred, sublists, slen = random_scan_problem(rng)
    zero = jnp.zeros(tup_f.shape[0], jnp.int32)
    _assert_kernel_matches_ref(
        (tup_f, tup_sid, zero, pred, sublists, slen), 256, interpret)
    exp = st_ref.st_scan_ref(tup_f, tup_sid, zero, pred, sublists, slen)
    assert int(np.asarray(exp[0]).sum()) == 0


@pytest.mark.parametrize("interpret", [True, None])
def test_st_scan_exactly_at_capacity(interpret):
    """tup_count == capacity: the whole ring is live, nothing more (the
    validity rule min(count, cap) sits exactly on its boundary)."""
    rng = np.random.default_rng(23)
    tup_f, tup_sid, _, pred, sublists, slen = random_scan_problem(rng, c=512)
    full = jnp.full(tup_f.shape[0], 512, jnp.int32)
    _assert_kernel_matches_ref(
        (tup_f, tup_sid, full, pred, sublists, slen), 128, interpret)


@pytest.mark.parametrize("channel", [1, 3])
@pytest.mark.parametrize("interpret", [True, None])
def test_st_scan_channel_selection(channel, interpret):
    """AggSpec channel generalization: both engines aggregate the selected
    value row (3 + channel), counts bitwise, floats to accumulation
    order; and selecting a channel must equal slicing it out by hand."""
    rng = np.random.default_rng(31 + channel)
    tup_f, tup_sid, cnt, pred, sublists, slen = random_scan_problem(rng)
    args = (tup_f, tup_sid, cnt, pred, sublists, slen)
    exp = st_ref.st_scan_ref(*args, channels=(channel,))
    got = st_ops.st_scan(*args, block_c=256, interpret=interpret,
                         channels=(channel,))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]),
                                  err_msg="count")
    for g, x, name in zip(got[1:], exp[1:], ["vsum", "vmin", "vmax"]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(x), rtol=1e-5,
                                   err_msg=name)
    # Independent oracle: move the channel into row v0 and scan channel 0.
    swapped = tup_f.at[:, 3, :].set(tup_f[:, 3 + channel, :])
    exp0 = st_ref.st_scan_ref(swapped, tup_sid, cnt, pred, sublists, slen)
    for g, x in zip(exp, exp0):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(x))


def test_st_scan_multi_channel_fused_vs_oracles():
    """Fused multi-channel aggregation: a K-channel scan must equal (a) a
    plain numpy oracle over the live window and (b) K independent
    single-channel scans stacked — for both engines, in one pass."""
    rng = np.random.default_rng(41)
    channels = (0, 2, 3)
    tup_f, tup_sid, cnt, pred, sublists, slen = random_scan_problem(rng, c=640)
    args = (tup_f, tup_sid, cnt, pred, sublists, slen)
    got_ref = st_ref.st_scan_ref(*args, channels=channels)
    got_ker = st_ops.st_scan(*args, block_c=128, interpret=True,
                             channels=channels)
    assert got_ref[1].shape == (3, len(channels), 4)
    # (a) numpy oracle: recompute the mask and every aggregate per channel.
    e, w, c = tup_f.shape
    q = sublists.shape[0]
    npf, nps = np.asarray(tup_f), np.asarray(tup_sid)
    p = {f: np.asarray(getattr(pred, f)) for f in pred._fields}
    for qi in range(q):
        for ei in range(e):
            sp = ((p["lat0"][qi] <= npf[ei, 1]) & (npf[ei, 1] <= p["lat1"][qi])
                  & (p["lon0"][qi] <= npf[ei, 2]) & (npf[ei, 2] <= p["lon1"][qi]))
            tp = (p["t0"][qi] <= npf[ei, 0]) & (npf[ei, 0] <= p["t1"][qi])
            ip = ((nps[ei, 0] == p["sid_hi"][qi])
                  & (nps[ei, 1] == p["sid_lo"][qi]))
            if p["is_and"][qi]:
                m = ((sp | ~p["has_spatial"][qi]) & (tp | ~p["has_temporal"][qi])
                     & (ip | ~p["has_sid"][qi]))
            else:
                m = ((sp & p["has_spatial"][qi]) | (tp & p["has_temporal"][qi])
                     | (ip & p["has_sid"][qi]))
            sl = int(np.asarray(slen)[qi, ei])
            if sl == 0:
                m &= False
            elif sl > 0:
                entries = np.asarray(sublists)[qi, ei, :sl]
                m &= np.array([(entries == nps[ei, :, t]).all(1).any()
                               for t in range(c)])
            m &= np.arange(c) < int(np.asarray(cnt)[ei])
            assert int(got_ref[0][qi, ei]) == int(m.sum())
            for k, ch in enumerate(channels):
                v = npf[ei, 3 + ch][m]
                np.testing.assert_allclose(float(got_ref[1][qi, k, ei]),
                                           v.sum() if len(v) else 0.0,
                                           rtol=1e-4, atol=1e-4)
    # (b) K single-channel scans, both engines.
    for k, ch in enumerate(channels):
        one_ref = st_ref.st_scan_ref(*args, channels=(ch,))
        one_ker = st_ops.st_scan(*args, block_c=128, interpret=True,
                                 channels=(ch,))
        for got, one in ((got_ref, one_ref), (got_ker, one_ker)):
            np.testing.assert_array_equal(np.asarray(got[0]),
                                          np.asarray(one[0]))
            for agg_i in (1, 2, 3):
                np.testing.assert_array_equal(
                    np.asarray(got[agg_i][:, k]),
                    np.asarray(one[agg_i][:, 0]))


def test_st_scan_channel_out_of_range():
    rng = np.random.default_rng(5)
    args = random_scan_problem(rng, w=7)
    with pytest.raises(ValueError, match="channel=4"):
        st_ref.st_scan_ref(*args, channels=(4,))
    with pytest.raises(ValueError, match="channel=4"):
        st_ops.st_scan(*args, channels=(4,))
    # Negative channels must not alias the t/lat/lon metadata rows.
    with pytest.raises(ValueError, match="channel=-1"):
        st_ref.st_scan_ref(*args, channels=(-1,))
    with pytest.raises(ValueError, match="channel=-1"):
        st_ops.st_scan(*args, channels=(-1,))
    with pytest.raises(ValueError, match="duplicates"):
        st_ref.st_scan_ref(*args, channels=(1, 1))


@pytest.mark.parametrize("q,block_q", [(1, 8), (3, 4), (5, 8), (9, 4)])
def test_st_scan_non_multiple_query_tiles(q, block_q):
    """Query batches that are not block_q multiples force the wrapper's Q
    padding; padding-query lanes must be inert and sliced off — kernel ==
    ref bitwise on counts at every (q, block_q)."""
    rng = np.random.default_rng(q * 10 + block_q)
    args = random_scan_problem(rng, q=q, c=512)
    exp = st_ref.st_scan_ref(*args)
    got = st_ops.st_scan(*args, block_c=128, block_q=block_q, interpret=True)
    assert got[0].shape == (q, 4) and got[1].shape == (q, 1, 4)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]),
                                  err_msg="count")
    for g, x, name in zip(got[1:], exp[1:], ["vsum", "vmin", "vmax"]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(x), rtol=1e-5,
                                   err_msg=name)


@pytest.mark.parametrize("interpret", [True, None])
def test_st_scan_lane_padded_capacity_post_wrap(interpret):
    """The store lane-pads the tuple axis above the logical capacity: with a
    post-wrap ring count (count >> capacity) neither engine may ever admit
    the padding slots — fill them with garbage and compare against an oracle
    scan of the unpadded log."""
    rng = np.random.default_rng(55)
    cap, pad = 500, 140                       # stored C = 640, logical = 500
    tup_f, tup_sid, _, pred, sublists, slen = random_scan_problem(rng, c=cap)
    garbage_f = rng.uniform(0, 100, (4, 7, pad)).astype(np.float32)
    garbage_s = rng.integers(0, 6, (4, 2, pad)).astype(np.int32)
    padded_f = jnp.concatenate([tup_f, jnp.asarray(garbage_f)], axis=2)
    padded_s = jnp.concatenate([tup_sid, jnp.asarray(garbage_s)], axis=2)
    over = jnp.asarray(rng.integers(cap + 1, 7 * cap, (4,)), jnp.int32)
    exp = st_ref.st_scan_ref(tup_f, tup_sid, jnp.full((4,), cap, jnp.int32),
                             pred, sublists, slen)
    got_ref = st_ref.st_scan_ref(padded_f, padded_s, over, pred, sublists,
                                 slen, valid_c=cap)
    got_ker = st_ops.st_scan(padded_f, padded_s, over, pred, sublists, slen,
                             block_c=128, interpret=interpret, valid_c=cap)
    for got in (got_ref, got_ker):
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]),
                                      err_msg="count")
        for g, x, name in zip(got[1:], exp[1:], ["vsum", "vmin", "vmax"]):
            np.testing.assert_allclose(np.asarray(g), np.asarray(x),
                                       rtol=1e-5, err_msg=name)


@settings(deadline=None, max_examples=25)
@given(st.data())
def test_st_scan_random_query_tiles_property(data):
    """Hypothesis property: for random problem shapes and random (block_q,
    block_c) tilings, the query-tiled kernel agrees with the reference —
    counts bitwise, float aggregates to accumulation order."""
    q = data.draw(st.integers(1, 12), label="q")
    e = data.draw(st.integers(1, 5), label="e")
    c = data.draw(st.integers(1, 5), label="c128") * 128
    block_q = 2 ** data.draw(st.integers(0, 3), label="log2_block_q")
    block_c = 128 * data.draw(st.integers(1, 2), label="block_c128")
    n_ch = data.draw(st.integers(1, 3), label="n_ch")
    seed = data.draw(st.integers(0, 2 ** 16), label="seed")
    rng = np.random.default_rng(seed)
    channels = tuple(rng.choice(4, n_ch, replace=False).tolist())
    args = random_scan_problem(rng, e=e, c=c, q=q)
    exp = st_ref.st_scan_ref(*args, channels=channels)
    got = st_ops.st_scan(*args, block_c=block_c, block_q=block_q,
                         interpret=True, channels=channels)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]),
                                  err_msg="count")
    for g, x, name in zip(got[1:], exp[1:], ["vsum", "vmin", "vmax"]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(x), rtol=1e-5,
                                   atol=1e-5, err_msg=name)


@pytest.fixture(scope="module")
def wrapped_ring_state():
    """A ring grown through the real insert path to well past capacity
    (every edge wrapped several times). Built once; the scan tests below are
    read-only."""
    from repro.core.datastore import StoreConfig, init_store
    from repro.data.synthetic import DroneFleet, make_sites
    from repro.distributed.federation import ingest_rounds

    e, cap = 4, 256
    sites = make_sites(e, CityConfig(), seed=3)
    cfg = StoreConfig(n_edges=e, sites=tuple(map(tuple, sites.tolist())),
                      tuple_capacity=cap, index_capacity=256,
                      max_shards_per_query=32, records_per_shard=8)
    fleet = DroneFleet(8, records_per_shard=8)
    payloads, metas = fleet.next_rounds(16)
    state, _ = ingest_rounds(cfg, init_store(cfg), payloads, metas,
                             jnp.ones(e, bool))
    assert int(np.asarray(state.tup_count).min()) > cap  # every ring wrapped
    return state


@pytest.mark.parametrize("interpret", [True, None])
def test_st_scan_post_wrap_ring(wrapped_ring_state, interpret):
    """Both engines must scan the whole wrapped ring and agree bitwise on
    counts."""
    state = wrapped_ring_state
    e = state.tup_f.shape[0]
    pred = make_pred(q=2, t0=[0.0, 200.0], t1=[1e9, 400.0], has_temporal=True,
                     is_and=True)
    slen = jnp.full((2, e), -1, jnp.int32)             # scan-all sentinel
    sublists = jnp.zeros((2, e, 1, 2), jnp.int32)
    _assert_kernel_matches_ref(
        (state.tup_f, state.tup_sid, state.tup_count, pred, sublists, slen),
        128, interpret)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

from repro.kernels.flash_attention.ops import flash_attention_pallas
from repro.models.attention import naive_attention


@pytest.mark.parametrize("b,s,h,kv,dh,bq,bk,causal", [
    (1, 256, 4, 4, 64, 128, 128, True),
    (2, 256, 8, 2, 32, 64, 128, True),     # GQA group=4
    (1, 384, 4, 1, 64, 128, 128, False),   # MQA, bidirectional
    (1, 128, 2, 2, 128, 64, 64, True),
])
def test_flash_pallas_vs_naive(b, s, h, kv, dh, bq, bk, causal):
    key = jax.random.key(s + h)
    q = jax.random.normal(key, (b, s, h, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, dh), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    exp = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=2e-5,
                               atol=2e-5)


def test_flash_pallas_bf16():
    key = jax.random.key(9)
    q = jax.random.normal(key, (1, 256, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 4, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 4, 64), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    exp = naive_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(exp),
                               rtol=3e-2, atol=3e-2)


def test_flash_pallas_q_offset_decode_window():
    """Chunked decode: q block at offset p attends only to k[:p+block]."""
    key = jax.random.key(11)
    b, s, h, dh, p = 1, 256, 2, 32, 128
    q = jax.random.normal(key, (b, 128, h, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, q_offset=p,
                                 interpret=True)
    from repro.models.attention import flash_attention as flash_jnp
    exp = flash_jnp(q, k, v, causal=True, q_offset=p, chunk_kv=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=2e-5,
                               atol=2e-5)
