"""Write-ahead journal (PR 9 crash durability): the ingest pipeline's
append-before-ack record log.

Contracts under test: fixed-width binary roundtrips are bit-exact
(including NaN payload channels), a torn tail (partial trailing record
after a crash mid-append) is self-describing and truncated on reopen
without touching whole records, width/magic mismatches refuse loudly, the
pipeline journals exactly the ACCEPTED record set (duplicates, malformed
and backpressure-dropped records never hit disk), and replay is idempotent
through the (drone, seq) dedup — a double replay accepts nothing twice.
"""

import numpy as np
import pytest

from repro.api import AerialDB
from repro.core.datastore import StoreConfig
from repro.data.synthetic import CityConfig, make_sites
from repro.ingest import IngestPipeline, WriteAheadJournal

E = 8
WIDTH = 7      # t, lat, lon + 4 value channels


def _cfg(**overrides):
    sites = make_sites(E, CityConfig(), seed=3)
    kw = dict(n_edges=E, sites=tuple(map(tuple, sites.tolist())),
              tuple_capacity=2048, index_capacity=512,
              max_shards_per_query=64, records_per_shard=8,
              retention_every=1 << 20, n_failure_domains=4)
    kw.update(overrides)
    return StoreConfig(**kw)


def _rows(n, seed=0):
    rng = np.random.default_rng(seed)
    rows = rng.standard_normal((n, WIDTH)).astype(np.float32)
    rows[:, 0] = np.arange(n, dtype=np.float32)          # finite t
    return rows


# ---------------------------------------------------------------------------
# Raw journal file format
# ---------------------------------------------------------------------------


def test_journal_roundtrip_bit_exact(tmp_path):
    """Append/replay roundtrips ids and float32 rows bit-for-bit — NaN
    payload channels included (partial records are first-class)."""
    path = tmp_path / "wal.bin"
    rows = _rows(50, seed=1)
    rows[7, 4] = np.nan
    rows[12, 3:] = np.nan
    drone = np.arange(50, dtype=np.int64) % 5
    seq = np.arange(50, dtype=np.int64)
    with WriteAheadJournal(path, WIDTH) as j:
        assert j.append(drone[:30], seq[:30], rows[:30]) == 30
        assert j.append(drone[30:], seq[30:], rows[30:]) == 20
        assert j.n_records == 50
    with WriteAheadJournal(path, WIDTH) as j:
        d, s, r, info = j.replay()
    assert info["records"] == 50 and info["torn_bytes"] == 0
    np.testing.assert_array_equal(d, drone)
    np.testing.assert_array_equal(s, seq)
    # bit-level comparison: NaN != NaN under ==, so compare the patterns
    np.testing.assert_array_equal(r.view(np.int32), rows.view(np.int32))


def test_journal_truncates_torn_tail(tmp_path):
    """A crash mid-append leaves a partial trailing record; reopen reports
    and truncates it, keeping every whole record byte-identical."""
    path = tmp_path / "wal.bin"
    rows = _rows(10)
    with WriteAheadJournal(path, WIDTH) as j:
        j.append(np.arange(10, dtype=np.int64),
                 np.arange(10, dtype=np.int64), rows)
        rec_size = j.itemsize
    full = path.read_bytes()
    torn = rec_size // 2
    path.write_bytes(full[:len(full) - rec_size + torn])   # tear record 9
    with WriteAheadJournal(path, WIDTH) as j:
        assert j.n_records == 9
        d, s, r, info = j.replay()
    assert d.shape[0] == 9
    assert info["torn_bytes"] == 0          # reopen already truncated it
    np.testing.assert_array_equal(r.view(np.int32),
                                  rows[:9].view(np.int32))
    # the file itself is frame-aligned again: appends keep working
    with WriteAheadJournal(path, WIDTH) as j:
        j.append(np.array([99]), np.array([0]), _rows(1))
        assert j.n_records == 10


def test_journal_width_mismatch_raises(tmp_path):
    path = tmp_path / "wal.bin"
    with WriteAheadJournal(path, WIDTH) as j:
        j.append(np.array([1]), np.array([0]), _rows(1))
    with pytest.raises(ValueError, match="width"):
        WriteAheadJournal(path, WIDTH + 2)


def test_journal_rejects_foreign_file(tmp_path):
    path = tmp_path / "not_a_wal.bin"
    path.write_bytes(b"definitely not a journal header" * 4)
    with pytest.raises(ValueError, match="magic"):
        WriteAheadJournal(path, WIDTH)


def test_journal_fresh_and_empty_files(tmp_path):
    """A fresh path and a zero-record journal both replay to empty."""
    for name in ("fresh.bin", "empty.bin"):
        with WriteAheadJournal(tmp_path / name, WIDTH) as j:
            d, s, r, info = j.replay()
        assert d.size == s.size == 0 and r.shape == (0, WIDTH)
        assert info["records"] == 0


# ---------------------------------------------------------------------------
# Pipeline integration: journal == the accepted set, replay is idempotent
# ---------------------------------------------------------------------------


def test_pipeline_journals_exactly_the_accepted_set(tmp_path):
    """Duplicates, malformed records and backpressure drops are acked as
    rejected — none of them may reach the journal (the journal is the ack's
    durability receipt, not a raw intake tape)."""
    db = AerialDB.open(_cfg(), seed=0)
    pipe = IngestPipeline(db, max_pending=40,
                          journal=tmp_path / "wal.bin")
    n = 30
    drone = np.zeros(n, np.int64)
    seq = np.arange(n, dtype=np.int64)
    rows = _rows(n)
    pipe.submit_arrays(drone, seq, rows[:, 0], rows[:, 1], rows[:, 2],
                       rows[:, 3:])
    # duplicates (re-sent seqs), one malformed (NaN t), and a batch big
    # enough to overflow the max_pending=40 budget
    dup = pipe.submit_arrays(drone[:5], seq[:5], rows[:5, 0], rows[:5, 1],
                             rows[:5, 2], rows[:5, 3:])
    assert dup["duplicate"] == 5
    bad_t = np.array([np.nan])
    pipe.submit_arrays(np.array([3]), np.array([0]), bad_t,
                       np.array([1.0]), np.array([2.0]))
    big = 30
    pipe.submit_arrays(np.full(big, 1, np.int64),
                       np.arange(big, dtype=np.int64),
                       np.arange(big, dtype=np.float64),
                       np.zeros(big), np.zeros(big))
    c = pipe.counters
    assert c["dropped_malformed"] == 1 and c["dropped_backpressure"] > 0
    assert pipe.journal.n_records == c["accepted"]
    d, s, r, _ = pipe.journal.replay()
    # journaled (drone, seq) pairs are exactly the accepted, deduped set
    pairs = set(zip(d.tolist(), s.tolist()))
    assert len(pairs) == c["accepted"]
    pipe.close()


def test_journal_replay_is_idempotent(tmp_path):
    """Replay into a fresh pipeline recovers every accepted record once;
    a second replay (and a replay after partial delivery) accepts zero —
    the (drone, seq) dedup is the idempotence mechanism, so `replayed`
    over-delivery can never double-store."""
    cfg = _cfg()
    path = tmp_path / "wal.bin"
    db = AerialDB.open(cfg, seed=0)
    pipe = IngestPipeline(db, journal=path)
    n = 64
    rows = _rows(n, seed=4)
    pipe.submit_arrays(np.arange(n, dtype=np.int64) % 4,
                       np.arange(n, dtype=np.int64) // 4,
                       rows[:, 0], rows[:, 1], rows[:, 2], rows[:, 3:])
    pipe.flush(drain=True)
    accepted = pipe.counters["accepted"]
    assert accepted == n
    pipe.close()

    # crash: session and pipeline are gone; rebuild both and replay
    db2 = AerialDB.open(cfg, seed=0)
    pipe2 = IngestPipeline(db2, journal=path)
    rep = pipe2.replay_journal()
    assert rep == {"journal_records": n, "torn_bytes": 0,
                   "accepted": n, "already_seen": 0}
    assert pipe2.counters["replayed"] == n
    # replaying does NOT re-journal (no doubling on disk)
    assert pipe2.journal.n_records == n
    again = pipe2.replay_journal()
    assert again["accepted"] == 0 and again["already_seen"] == n
    pipe2.flush(drain=True)
    rec = pipe2.reconcile()
    assert rec["ok"], rec
    assert rec["flushed_records"] == n      # zero lost accepted records
    pipe2.close()
