"""aeriallint (PR 10): the three-layer static-analysis subsystem.

Layer 1 (AST rules): per-rule positive/negative fixtures over synthetic
sources, the pragma/allowlist reason policy, and the repo self-audit gate
(zero non-allowlisted findings — the bootstrap contract).
Layer 2 (jit-retrace budgets): the compile counter catches a weak-hash
static config, and the canonical facade workload meets its exact budgets
on the single-device, (4,) and (2, 2) legs with a compile-free warm rerun.
Layer 3 (HLO collective contract): the verifier proves the compiled
federated entry points move only contracted, tuple-capacity-independent
collectives with real donation aliases — and flags an injected contraband
collective.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import lint as lint_mod
from repro.analysis.config import AeriallintConfig, AllowEntry, load_config
from repro.analysis.lint import config_policy_findings, run_lint
from repro.analysis.retrace import CompileCounter, run_retrace
from repro.analysis.rules import lint_source
from repro.analysis import hlo_contract as hc
from repro.api import StoreConfig
from repro.launch.hlo_analysis import (collective_shapes, io_alias_pairs)


def _rules(src, path, cfg=None, status="open"):
    return [f.rule for f in lint_source(src, path, cfg)
            if f.status == status]


# ---------------------------------------------------------------------------
# Layer 1: rule fixtures
# ---------------------------------------------------------------------------

class TestR1Layering:
    def test_runtime_importing_facade_flagged(self):
        src = "from repro.api import AerialDB\n"
        assert "R1" in _rules(src, "src/repro/core/datastore.py")
        assert "R1" in _rules(src.replace("repro.api", "repro.chaos"),
                              "src/repro/distributed/federation.py")
        assert "R1" in _rules("import repro.ingest.pipeline\n",
                              "src/repro/kernels/st_scan/ops.py")

    def test_facade_importing_runtime_ok(self):
        src = "from repro.core.datastore import StoreConfig\nStoreConfig\n"
        assert _rules(src, "src/repro/api/session.py") == []

    def test_ingest_reaching_runtime_flagged(self):
        src = "from repro.core.index import QueryPred\nQueryPred\n"
        assert "R1" in _rules(src, "src/repro/ingest/coalesce.py")

    def test_ingest_over_facade_ok(self):
        src = "from repro.api import ShardMeta\nShardMeta\n"
        assert _rules(src, "src/repro/ingest/coalesce.py") == []

    def test_rule_scoped_to_layered_paths(self):
        # benchmarks may import anything — R1 keys off the file's layer.
        src = "from repro.api import AerialDB\nAerialDB\n"
        assert _rules(src, "benchmarks/common.py") == []


class TestR2Deprecation:
    SRC = ("from repro.core.datastore import insert_step\n"
           "s, i = insert_step(cfg, state, p, m, alive)\n")

    def test_shim_import_and_call_flagged(self):
        rules = _rules(self.SRC, "src/repro/data/pipeline.py")
        assert rules.count("R2") == 2   # the import AND the call site

    def test_defining_module_exempt(self):
        assert _rules("def insert_step(*a):\n    pass\n"
                      "insert_step()\n", "src/repro/core/datastore.py") == []

    def test_method_call_spelling_flagged(self):
        assert "R2" in _rules("import repro.core.datastore as ds\n"
                              "ds.query_step(cfg)\n",
                              "examples/query_api_tour.py")


class TestR3Determinism:
    def test_wall_clock_in_src_flagged(self):
        assert "R3" in _rules("import time\nt = time.time()\n",
                              "src/repro/ingest/pipeline.py")
        assert "R3" in _rules("import time\ntime.sleep(1)\n",
                              "src/repro/api/session.py")

    def test_wall_clock_in_benchmarks_ok(self):
        # benchmarks legitimately time; the clock rule is src/repro-scoped.
        assert _rules("import time\nt = time.time()\n",
                      "benchmarks/common.py") == []

    def test_unseeded_np_random_flagged_everywhere(self):
        src = "import numpy as np\nx = np.random.rand(3)\n"
        assert "R3" in _rules(src, "benchmarks/fig5_membership.py")
        assert "R3" in _rules(src, "src/repro/data/synthetic.py")

    def test_seeded_constructs_ok(self):
        src = ("import numpy as np\n"
               "rng = np.random.default_rng(0)\n"
               "ss = np.random.SeedSequence(7)\n")
        assert _rules(src, "src/repro/chaos/plan.py") == []

    def test_bare_stdlib_random_flagged(self):
        assert "R3" in _rules("import random\nx = random.random()\n",
                              "examples/fleet_tour.py")

    def test_jax_random_attribute_not_confused(self):
        src = ("import jax\nkey = jax.random.key(0)\n"
               "a, b = jax.random.split(key)\n")
        assert _rules(src, "src/repro/api/session.py") == []


class TestR4HostSync:
    def test_item_inside_jit_flagged(self):
        src = ("import jax\n"
               "@jax.jit\n"
               "def f(x):\n"
               "    return x.sum().item()\n")
        assert "R4" in _rules(src, "src/repro/core/datastore.py")

    def test_np_asarray_inside_traced_arg_flagged(self):
        src = ("import jax\nimport numpy as np\n"
               "def body(c, x):\n"
               "    return c, np.asarray(x)\n"
               "jax.lax.scan(body, 0, xs)\n")
        assert "R4" in _rules(src, "src/repro/distributed/federation.py")

    def test_host_side_item_ok(self):
        src = ("def telemetry(info):\n"
               "    return info['drops'].item()\n")
        assert _rules(src, "src/repro/api/session.py") == []

    def test_hot_function_config_traces_plain_def(self):
        cfg = AeriallintConfig(
            hot_functions=("src/repro/core/datastore.py::insert_local",))
        src = ("import numpy as np\n"
               "def insert_local(cfg, state):\n"
               "    return np.asarray(state)\n")
        assert "R4" in _rules(src, "src/repro/core/datastore.py", cfg)
        # same source, path not matching the hot-function glob -> clean
        assert _rules(src, "src/repro/core/index.py", cfg) == []


class TestR5TracedBranch:
    def test_branch_on_jnp_flagged(self):
        src = ("import jax\nimport jax.numpy as jnp\n"
               "@jax.jit\n"
               "def f(x):\n"
               "    if jnp.any(x > 0):\n"
               "        return x\n"
               "    return -x\n")
        assert "R5" in _rules(src, "src/repro/core/planner.py")

    def test_static_config_branch_ok(self):
        src = ("import jax\n"
               "@jax.jit\n"
               "def f(x, use_index=True):\n"
               "    if use_index:\n"
               "        return x\n"
               "    return -x\n")
        assert _rules(src, "src/repro/core/planner.py") == []


class TestR6DeadImports:
    def test_dead_import_flagged(self):
        assert "R6" in _rules("import numpy as np\nx = 1\n",
                              "src/repro/models/model.py")

    def test_future_and_all_exempt(self):
        src = ("from __future__ import annotations\n"
               "from repro.models.attention import naive_attention\n"
               "__all__ = ['naive_attention']\n")
        assert _rules(src, "src/repro/kernels/flash_attention/ref.py") == []

    def test_init_py_exempt(self):
        assert _rules("from repro.api.session import AerialDB\n",
                      "src/repro/api/__init__.py") == []


class TestSuppressionPolicy:
    SRC = "import time\nt = time.time()  # aeriallint: disable=R3{suffix}\n"

    def test_reasoned_pragma_disables(self):
        out = lint_source(self.SRC.format(suffix=" -- timing telemetry only"),
                          "src/repro/launch/dryrun.py")
        assert [f.status for f in out if f.rule == "R3"] == ["disabled"]
        assert all(f.status != "open" for f in out)

    def test_reasonless_pragma_is_a_finding(self):
        out = lint_source(self.SRC.format(suffix=""),
                          "src/repro/launch/dryrun.py")
        assert {f.rule for f in out if f.status == "open"} == {"R0", "R3"}

    def test_pragma_on_line_above(self):
        src = ("import time\n"
               "# aeriallint: disable=R3 -- measured, not stored\n"
               "t = time.time()\n")
        out = lint_source(src, "src/repro/launch/dryrun.py")
        assert [f.status for f in out if f.rule == "R3"] == ["disabled"]

    def test_reasoned_allowlist_entry_applies(self):
        cfg = AeriallintConfig(allow=(AllowEntry(
            rule="R3", path="src/repro/launch/*.py", match="time.time",
            reason="the dry-run reports wall durations"),))
        out = lint_source("import time\nt = time.time()\n",
                          "src/repro/launch/dryrun.py", cfg)
        assert [f.status for f in out if f.rule == "R3"] == ["allowlisted"]

    def test_reasonless_allowlist_entry_ignored_and_reported(self):
        cfg = AeriallintConfig(allow=(AllowEntry(
            rule="R3", path="src/repro/launch/*.py", reason=""),))
        out = lint_source("import time\nt = time.time()\n",
                          "src/repro/launch/dryrun.py", cfg)
        assert [f.status for f in out if f.rule == "R3"] == ["open"]
        assert [f.rule for f in config_policy_findings(cfg)] == ["R0"]


class TestRepoSelfAudit:
    def test_repo_is_clean(self):
        """The bootstrap gate: zero non-allowlisted findings repo-wide."""
        report = run_lint()
        open_f = [f for f in report["findings"] if f["status"] == "open"]
        assert report["ok"], "\n".join(
            f"{f['path']}:{f['line']}: {f['rule']}: {f['message']}"
            for f in open_f)

    def test_every_suppression_has_a_reason(self):
        report = run_lint()
        for f in report["findings"]:
            if f["status"] in ("allowlisted", "disabled"):
                assert f["reason"].strip(), f
        for e in load_config().allow:
            assert e.reason.strip(), e

    def test_cli_json_output(self, tmp_path, capsys):
        out = tmp_path / "lint.json"
        rc = lint_mod.main(["--json", "-o", str(out)])
        assert rc == 0
        report = json.loads(out.read_text())
        assert report["tool"] == "aeriallint" and report["ok"]
        assert json.loads(capsys.readouterr().out)["ok"]

    def test_lint_files_on_tmp_fixture(self, tmp_path):
        bad = tmp_path / "src" / "repro" / "core" / "bad.py"
        bad.parent.mkdir(parents=True)
        bad.write_text("from repro.api import AerialDB\nAerialDB.open()\n")
        (tmp_path / "pyproject.toml").write_text("")   # repo-root marker
        out = lint_mod.lint_files([str(bad)], str(tmp_path),
                                  AeriallintConfig())
        assert [f.rule for f in out] == ["R1"]
        assert out[0].path == "src/repro/core/bad.py"


# ---------------------------------------------------------------------------
# Layer 2: jit-retrace budgets
# ---------------------------------------------------------------------------

class TestRetraceBudget:
    def test_counter_catches_weak_config_hash(self):
        """The regression the harness exists for: a static config whose
        equal values do NOT hash equal retraces on every call."""
        @dataclasses.dataclass(frozen=True, eq=False)   # identity hash
        class WeakCfg:
            n: int = 3

        @dataclasses.dataclass(frozen=True)             # value hash
        class StrongCfg:
            n: int = 3

        def weak_body(cfg, x):
            return x * cfg.n

        def strong_body(cfg, x):
            return x * (cfg.n + 1)

        weak = jax.jit(weak_body, static_argnums=0)
        strong = jax.jit(strong_body, static_argnums=0)
        x = jnp.arange(5.0)
        with CompileCounter() as cc:
            weak(WeakCfg(), x)
            weak(WeakCfg(), x)        # equal value, different hash: retrace
            strong(StrongCfg(), x)
            strong(StrongCfg(), x)    # value-hashed: cache hit
        assert cc.counts["weak_body"] == 2
        assert cc.counts["strong_body"] == 1

    def test_store_config_is_value_hashed(self):
        a = StoreConfig(n_edges=8, tuple_capacity=512)
        b = StoreConfig(n_edges=8, tuple_capacity=512)
        assert a is not b and a == b and hash(a) == hash(b)

    def test_canonical_workload_meets_budgets(self):
        """Exact cold budgets + compile-free warm rerun on the single-device,
        (4,) and (2, 2) legs (tier-1 gate)."""
        if jax.device_count() < 4:
            pytest.skip("needs 4 devices (conftest forces them)")
        report = run_retrace()
        assert report["ok"], "\n".join(
            v["message"] for v in report["violations"])
        legs = [r["mesh"] for r in report["runs"] if "budgets" in r]
        assert legs == ["single_device", "mesh(4,)", "mesh(2, 2)"]
        for r in report["runs"]:
            if "budgets" in r:
                # warm rerun compiled NO budgeted entry point
                assert not set(r["warm"]) & set(r["budgets"]), r


# ---------------------------------------------------------------------------
# Layer 3: HLO collective contract
# ---------------------------------------------------------------------------

_FAKE_HLO = """\
HloModule fake, is_scheduled=true

ENTRY %main.1 (p0: f32[8]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  %ag = f32[8]{0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %a2a = f32[8]{0} all-to-all(%ag), replica_groups={{0,1,2,3}}
}
"""


class TestHloVerifier:
    def test_injected_contraband_collective_flagged(self):
        v = hc.check_collective_contract(
            _FAKE_HLO, {"all-gather", "all-reduce"}, "fake")
        assert [x["kind"] for x in v] == ["all-to-all"]
        # and the contracted kind passes untouched
        assert hc.check_collective_contract(
            _FAKE_HLO, {"all-gather", "all-to-all"}, "fake") == []

    def test_exact_count_enforced(self):
        v = hc.check_collective_contract(
            _FAKE_HLO, {"all-gather", "all-to-all"}, "fake",
            exact_counts={"all-gather": 2})
        assert [x["check"] for x in v] == ["counts"]

    def test_capacity_dependence_flagged(self):
        a = {("all-gather", "f32[8]"): 1}
        b = {("all-gather", "f32[16]"): 1}
        assert hc.check_capacity_independence(a, dict(a), "x", (384, 1024)) \
            == []
        v = hc.check_capacity_independence(a, b, "x", (384, 1024))
        assert [x["check"] for x in v] == ["capacity"]

    def test_io_alias_parser(self):
        hdr = ("HloModule m, input_output_alias={ {0}: (0, {}, may-alias), "
               "{1}: (2, {}, must-alias) }, entry_computation_layout=...")
        assert io_alias_pairs(hdr) == 2
        assert io_alias_pairs(_FAKE_HLO) == 0
        assert hc.check_donation(hdr, 2, "x") == []
        assert [v["check"] for v in hc.check_donation(hdr, 16, "x")] \
            == ["donation"]

    def test_collective_shapes_strips_layout(self):
        shapes = collective_shapes(_FAKE_HLO)
        assert shapes == {("all-gather", "f32[8]"): 1,
                          ("all-to-all", "f32[8]"): 1}

    def test_federated_entry_points_meet_contract(self):
        """Lower insert/ingest/query on (4,) and (2, 2); only contracted,
        capacity-independent collectives; >= 16 donated aliases (tier-1
        gate)."""
        if jax.device_count() < 4:
            pytest.skip("needs 4 devices (conftest forces them)")
        report = hc.run_hlo_contract()
        assert report["ok"], "\n".join(
            v["message"] for v in report["violations"])
        assert [r["mesh"] for r in report["runs"]] \
            == ["mesh(4,)", "mesh(2, 2)"]
        for r in report["runs"]:
            assert r["ingest_io_aliases"] >= 16
            # query moves metadata only: no f32 log-sized tensors beyond the
            # (Q,1)/(Q) aggregate all-reduces and planner candidate sets.
            assert all(k.startswith(("all-gather", "all-reduce"))
                       for k in r["collectives"]["query"])
