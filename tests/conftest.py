"""Test-session bootstrap.

If the real `hypothesis` package is unavailable (offline containers — the
canonical dependency lives in pyproject's ``[test]`` extra), install the
deterministic fallback shim under the same module names before any test
module imports it. Test files import ``hypothesis`` unconditionally and are
identical under either implementation.
"""

import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies
