"""Test-session bootstrap.

Two pieces, both of which must run before anything imports jax or hypothesis:

1. Force a 4-device host platform (unless the caller already pinned a device
   count via XLA_FLAGS) so the sharded federated runtime is exercised by the
   tier-1 suite: tests/test_federation.py differentially tests the shard_map
   path against the single-device jit path on a real multi-device mesh. jax
   locks the device count at first backend initialization, hence here.
   Single-device jit tests are unaffected — they run on device 0.

2. If the real `hypothesis` package is unavailable (offline containers — the
   canonical dependency lives in pyproject's ``[test]`` extra), install the
   deterministic fallback shim under the same module names before any test
   module imports it. Test files import ``hypothesis`` unconditionally and
   are identical under either implementation.
"""

import os
import sys

_FORCE = "--xla_force_host_platform_device_count"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=4").strip()

# Persistent XLA compilation cache: the suite is compile-dominated, so repeat
# local runs drop well below the cold-start time. Keyed by jax/XLA version and
# flags internally; repo-local dir (gitignored) so `git clean -dfx` resets it.
os.environ.setdefault(
    "JAX_COMPILATION_CACHE_DIR",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 ".jax_cache"))
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0.1")


try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies
