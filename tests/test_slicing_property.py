"""THE index-correctness invariant (paper §3.4.3), property-tested:

If a query range overlaps a shard range, then the query's slice->edge set
must intersect the edges holding that shard's index entry — otherwise the
shard would be invisible to the query. Both sides quantize with the same
fixed grid, so any shared point lands in the same slice, which hashes to
the same edge. Overflowed (over-budget) ranges fall back to broadcast and
are exempt (handled by the datastore's broadcast path).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import assume, given, settings
from hypothesis import strategies as st

from repro.core.slicing import SliceConfig, spatial_slice_edges, temporal_slice_edges
from repro.data.synthetic import CityConfig, make_sites

E = 16
SITES = jnp.asarray(make_sites(E, CityConfig(), seed=3))
CFG = SliceConfig()

coord = st.floats(min_value=12.85, max_value=13.10, allow_nan=False)
lon_c = st.floats(min_value=77.45, max_value=77.75, allow_nan=False)
tval = st.floats(min_value=0.0, max_value=86400.0, allow_nan=False)


def _rng(a, b):
    return (min(a, b), max(a, b))


ext = st.floats(min_value=0.0, max_value=2000.0, allow_nan=False)


@given(tval, ext, ext, ext, ext)
@settings(deadline=None, max_examples=60)
def test_temporal_overlap_implies_edge_intersection(pt, e1, e2, e3, e4):
    # build both ranges AROUND a shared point => overlap by construction
    s0, s1 = pt - e1, pt + e2     # shard range
    q0, q1 = pt - e3, pt + e4     # query range
    sm, s_ovf = temporal_slice_edges(jnp.asarray([s0], jnp.float32),
                                     jnp.asarray([s1], jnp.float32), E, CFG)
    qm, q_ovf = temporal_slice_edges(jnp.asarray([q0], jnp.float32),
                                     jnp.asarray([q1], jnp.float32), E, CFG)
    assume(not bool(s_ovf[0]) and not bool(q_ovf[0]))
    assert bool(jnp.any(sm[0] & qm[0])), (s0, s1, q0, q1)


sext = st.floats(min_value=0.0, max_value=0.05, allow_nan=False)


@given(coord, lon_c, sext, sext, sext, sext, sext, sext, sext, sext)
@settings(deadline=None, max_examples=40)
def test_spatial_overlap_implies_edge_intersection(lat, lon, a1, a2, b1, b2,
                                                   c1, c2, d1, d2):
    # both bboxes contain (lat, lon) => overlap by construction
    slat0, slat1 = lat - a1, lat + a2
    slon0, slon1 = lon - b1, lon + b2
    qlat0, qlat1 = lat - c1, lat + c2
    qlon0, qlon1 = lon - d1, lon + d2
    f32 = lambda x: jnp.asarray([x], jnp.float32)
    sm, s_ovf = spatial_slice_edges(f32(slat0), f32(slat1), f32(slon0),
                                    f32(slon1), SITES, CFG)
    qm, q_ovf = spatial_slice_edges(f32(qlat0), f32(qlat1), f32(qlon0),
                                    f32(qlon1), SITES, CFG)
    assume(not bool(s_ovf[0]) and not bool(q_ovf[0]))
    assert bool(jnp.any(sm[0] & qm[0]))


def test_point_range_slices():
    """Degenerate (point) ranges produce exactly one slice edge."""
    m, ovf = temporal_slice_edges(jnp.asarray([500.0]), jnp.asarray([500.0]),
                                  E, CFG)
    assert int(m.sum()) == 1 and not bool(ovf[0])
