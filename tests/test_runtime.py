"""Runtime tests: training convergence, checkpoint/restore (incl. elastic
re-mesh), gradient compression, paged KV cache, serving engine, pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.pipeline import AerialPipeline, PipelineConfig
from repro.distributed import compression as comp
from repro.models.model import Model
from repro.serve import kv_cache as kvc
from repro.serve.engine import Engine, ServeConfig
from repro.train import checkpoint as ckpt
from repro.train import optimizer as optlib

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=128,
                   loss_chunk=64, attn_chunk_kv=32)


def make_trainer(cfg=TINY, seed=0):
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    opt_cfg = optlib.OptConfig(lr=1e-2, warmup_steps=5, total_steps=100,
                               clip_norm=1.0)
    opt_state = optlib.init_opt_state(opt_cfg, params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, m = optlib.adamw_update(opt_cfg, grads, opt_state,
                                                   params)
        return params, opt_state, loss

    return model, params, opt_state, step


def fixed_batch(cfg=TINY, b=4, s=32, seed=7):
    toks = jax.random.randint(jax.random.key(seed), (b, s + 1), 0, cfg.vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@pytest.mark.slow
def test_training_reduces_loss():
    model, params, opt_state, step = make_trainer()
    batch = fixed_batch()
    losses = []
    for _ in range(30):
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0], losses[::10]
    assert np.isfinite(losses).all()


def test_checkpoint_roundtrip(tmp_path):
    model, params, opt_state, step = make_trainer()
    batch = fixed_batch()
    for _ in range(3):
        params, opt_state, loss = step(params, opt_state, batch)
    ckpt.save_checkpoint(tmp_path, 3, {"params": params, "opt": opt_state})
    restored, got_step = ckpt.restore_checkpoint(
        tmp_path, {"params": params, "opt": opt_state})
    assert got_step == 3
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # training continues identically after restore
    p1, o1, l1 = step(params, opt_state, batch)
    p2, o2, l2 = step(restored["params"], restored["opt"], batch)
    assert float(l1) == float(l2)


def test_checkpoint_elastic_remesh(tmp_path):
    """Save under one sharding, restore under another (elastic restart)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    model, params, _, _ = make_trainer()
    ckpt.save_checkpoint(tmp_path, 1, {"params": params})
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    restored, _ = ckpt.restore_checkpoint(tmp_path, {"params": params},
                                          shardings={"params": sh})
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    model, params, _, _ = make_trainer()
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(tmp_path, s, {"p": params}, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    import os
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000004", "step_00000005"]


def test_int8_error_feedback_converges():
    """EF-int8 psum: mean error over steps must stay bounded and small
    relative to signal (error feedback re-injects residuals)."""
    mesh = jax.make_mesh((1,), ("dp",))
    g = jax.random.normal(jax.random.key(0), (256,), jnp.float32)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(gi, err):
        return comp.ef_allreduce_int8(gi, err, "dp")

    # Wrap + jit ONCE: re-wrapping shard_map inside the loop would retrace
    # and recompile on every iteration (20x the test's runtime).
    step = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(), P()),
                             out_specs=(P(), P())))

    err = jnp.zeros_like(g)
    total_true = jnp.zeros_like(g)
    total_comp = jnp.zeros_like(g)
    for i in range(20):
        gi = g * (1.0 + 0.1 * i)
        mg, err = step(gi, err)
        total_true += gi
        total_comp += mg
    rel = float(jnp.linalg.norm(total_comp - total_true) /
                jnp.linalg.norm(total_true))
    assert rel < 0.02, rel


def test_paged_cache_matches_contiguous():
    """Hash-placed paged cache must reproduce the contiguous KV stream."""
    rng = np.random.default_rng(0)
    block, kv, dh = 4, 2, 8
    cache = kvc.init_paged(n_slots=64, block=block, kv=kv, dh=dh,
                           max_seqs=3, max_blocks=8, dtype=jnp.float32)
    streams = {0: [], 2: []}
    for pos in range(13):
        for sid in streams:
            k_new = rng.normal(0, 1, (kv, dh)).astype(np.float32)
            v_new = rng.normal(0, 1, (kv, dh)).astype(np.float32)
            cache, ok = kvc.append_token(cache, sid, pos, jnp.asarray(k_new),
                                         jnp.asarray(v_new), block)
            assert bool(ok)
            streams[sid].append(k_new)
    for sid, ks in streams.items():
        k_got, _ = kvc.gather_sequence(cache, sid, max_blocks=8)
        np.testing.assert_allclose(np.asarray(k_got)[:13], np.stack(ks),
                                   rtol=1e-6)


def test_paged_cache_collision_probing():
    """Tiny pool forces collisions; successor probing must keep streams
    separate (AerialDB §3.4.2 fallback rule reused)."""
    rng = np.random.default_rng(1)
    block, kv, dh = 2, 1, 4
    cache = kvc.init_paged(n_slots=8, block=block, kv=kv, dh=dh,
                           max_seqs=4, max_blocks=2, dtype=jnp.float32)
    vals = {}
    for sid in range(4):
        for pos in range(4):
            k_new = rng.normal(0, 1, (kv, dh)).astype(np.float32)
            cache, ok = kvc.append_token(cache, sid, pos, jnp.asarray(k_new),
                                         jnp.asarray(k_new), block)
            assert bool(ok)
            vals[(sid, pos)] = k_new
    table = np.asarray(cache.table)[:4, :2]
    assert len(set(table.ravel().tolist())) == 8  # all distinct slots
    for sid in range(4):
        k_got, _ = kvc.gather_sequence(cache, sid, max_blocks=2)
        for pos in range(4):
            np.testing.assert_allclose(np.asarray(k_got)[pos],
                                       vals[(sid, pos)], rtol=1e-6)


def test_engine_generates():
    model = Model(TINY)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, ServeConfig(max_new_tokens=8, max_seq=64))
    prompts = np.array([[5, 6, 7], [9, 10, 11]], np.int32)
    out = eng.generate(prompts)
    assert out.shape == (2, 8)
    assert (out >= 0).all() and (out < TINY.vocab).all()
    # greedy decoding is deterministic
    out2 = eng.generate(prompts)
    np.testing.assert_array_equal(out, out2)


def test_pipeline_deterministic_resume():
    pipe = AerialPipeline(PipelineConfig(rounds=3, n_drones=8, batch=2, seq=16))
    b5 = pipe.get_batch(5)
    pipe2 = AerialPipeline(PipelineConfig(rounds=3, n_drones=8, batch=2, seq=16))
    b5b = pipe2.get_batch(5)
    np.testing.assert_array_equal(np.asarray(b5["tokens"]),
                                  np.asarray(b5b["tokens"]))
    assert b5["tokens"].shape == (2, 16)
