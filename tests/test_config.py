"""StoreConfig validation: default construction, replication bounds, the
broadcast-baseline x replication interaction, and insert batch limits."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.datastore import StoreConfig, init_store, insert_step
from repro.core.placement import ShardMeta
from repro.data.synthetic import DroneFleet


def test_default_config_constructs_and_is_usable():
    """StoreConfig() with no sites synthesizes a deterministic grid."""
    cfg = StoreConfig()
    sites = np.asarray(cfg.sites_array())
    assert sites.shape == (cfg.n_edges, 2)
    assert len({tuple(s) for s in sites.tolist()}) == cfg.n_edges  # distinct
    assert StoreConfig().sites == cfg.sites                        # deterministic
    state = init_store(cfg)
    # Column-major log: field rows x lane-aligned tuple axis.
    assert state.tup_f.shape == (cfg.n_edges, cfg.tuple_width,
                                 cfg.padded_capacity)
    assert state.tup_sid.shape == (cfg.n_edges, 2, cfg.padded_capacity)


def test_padded_capacity_lane_alignment():
    """padded_capacity rounds the stored tuple axis up to a 128 multiple;
    aligned capacities are unchanged."""
    assert StoreConfig(tuple_capacity=100).padded_capacity == 128
    assert StoreConfig(tuple_capacity=128).padded_capacity == 128
    assert StoreConfig(tuple_capacity=129).padded_capacity == 256
    assert StoreConfig().padded_capacity == StoreConfig().tuple_capacity
    cfg = StoreConfig(tuple_capacity=100)
    assert init_store(cfg).tup_f.shape[-1] == 128


def test_sites_length_mismatch_raises():
    with pytest.raises(ValueError, match="n_edges"):
        StoreConfig(n_edges=4, sites=((0.0, 0.0), (1.0, 1.0)))


@pytest.mark.parametrize("replication", [0, -1, 4, 7])
def test_replication_out_of_range_raises(replication):
    """Seed bug: replication > 3 crashed insert_step with a negative pad
    width; now rejected at config construction."""
    with pytest.raises(ValueError, match="replication"):
        StoreConfig(replication=replication)


def test_broadcast_baseline_requires_replication_one():
    """Seed bug: use_index=False with replication > 1 silently overcounted
    ~R-fold (every replica edge scans every tuple); now rejected."""
    with pytest.raises(ValueError, match="overcount"):
        StoreConfig(use_index=False, replication=3)
    StoreConfig(use_index=False, replication=1)  # the valid baseline


def test_retention_every_validated():
    with pytest.raises(ValueError, match="retention_every"):
        StoreConfig(retention_every=0)


def test_insert_batch_larger_than_capacity_raises():
    """A single batch that could wrap one edge's ring within one insert_step
    is rejected at trace time (scatter order would be undefined)."""
    cfg = StoreConfig(n_edges=4, tuple_capacity=64, records_per_shard=16)
    state = init_store(cfg)
    fleet = DroneFleet(8, records_per_shard=16)
    payload, meta = fleet.next_shards()
    meta = ShardMeta(*[jnp.asarray(x) for x in meta])
    alive = jnp.ones(cfg.n_edges, bool)
    with pytest.raises(ValueError, match="tuple_capacity"):
        insert_step(cfg, state, jnp.asarray(payload), meta, alive)
