"""Order-insensitive bitwise store audits for chaos differential checks.

After heal + repair, a faulted store holds exactly the data a never-faulted
run does — same tuple bits on the same edges, same per-shard replica sets,
same index coverage — but NOT the same ring layout: repair appends
backfilled copies at ring tails in sweep order and stamps backfilled index
entries with the repair step, whereas the reference interleaved them in
insert order. The truly bitwise property (incremental repair == full sweep
from the same pre-state) is asserted directly on states; *cross-history*
equivalence is asserted on this module's canonical form instead:
:func:`canonical_content` sorts each edge's live ring window by record bits
and reduces the index to per-shard (replica set, holder-edge set) — two
stores with the same content compare bit-equal here regardless of write
order or entry epochs.

Precondition: no retention eviction during the compared histories. Ring
wraparound retires the oldest tuples per *edge*, and faults skew per-edge
load (a partition concentrates ingest on the reachable side), so once any
ring wraps, the faulted and never-faulted histories legitimately age out
different tuples. Chaos harnesses that gate on content equality size
``tuple_capacity`` above the workload's total volume (the soak benchmark
gates wrap-free-ness explicitly).
"""

from __future__ import annotations

import numpy as np

__all__ = ["canonical_content", "assert_content_equal"]


def canonical_content(db) -> dict:
    """Canonical (order-insensitive, bit-exact) content of a session's
    store: ``edges`` — per-edge (w, 2 + width) int64 matrices of the live
    ring window's records ``[sid_hi, sid_lo, float32-bits...]`` sorted
    lexicographically, and ``index`` — ``{sid_key: (replica tuple, holder
    edge tuple)}`` over valid entries."""
    state, cfg = db.state, db.cfg
    cap = cfg.tuple_capacity
    tup_f = np.asarray(state.tup_f)
    tup_sid = np.asarray(state.tup_sid)
    tup_count = np.asarray(state.tup_count)
    edges = []
    for e in range(cfg.n_edges):
        w = min(int(tup_count[e]), cap)
        rows = np.empty((w, 2 + cfg.tuple_width), np.int64)
        rows[:, 0] = tup_sid[e, 0, :w]
        rows[:, 1] = tup_sid[e, 1, :w]
        # float32 bit patterns, not values: NaN payload channels stay
        # comparable and -0.0 != 0.0 stays visible.
        rows[:, 2:] = tup_f[e, :, :w].T.astype(np.float32).view(np.int32)
        edges.append(rows[np.lexsort(rows.T[::-1])])

    ent_i = np.asarray(state.index.ent_i)
    valid = np.asarray(state.index.valid)
    index: dict = {}
    for v, c in zip(*np.nonzero(valid)):
        key = (int(ent_i[v, c, 0]) << 32) | (int(ent_i[v, c, 1])
                                             & 0xFFFFFFFF)
        reps = tuple(sorted(int(r) for r in ent_i[v, c, 2:5] if r >= 0))
        holders = index.setdefault(key, (reps, set()))[1]
        holders.add(int(v))
    return {"edges": edges,
            "index": {k: (reps, tuple(sorted(h)))
                      for k, (reps, h) in sorted(index.items())}}


def assert_content_equal(a: dict, b: dict, msg: str = "") -> None:
    """Assert two :func:`canonical_content` snapshots are identical."""
    assert len(a["edges"]) == len(b["edges"]), f"{msg}edge count differs"
    for e, (ra, rb) in enumerate(zip(a["edges"], b["edges"])):
        np.testing.assert_array_equal(
            ra, rb, err_msg=f"{msg}edge {e} ring content differs")
    assert a["index"].keys() == b["index"].keys(), (
        f"{msg}tracked shard sets differ: only-a="
        f"{sorted(set(a['index']) - set(b['index']))[:5]} only-b="
        f"{sorted(set(b['index']) - set(a['index']))[:5]}")
    for k in a["index"]:
        assert a["index"][k] == b["index"][k], (
            f"{msg}shard {k >> 32}/{k & 0xFFFFFFFF}: "
            f"{a['index'][k]} != {b['index'][k]}")
