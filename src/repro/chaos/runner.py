"""``ChaosRunner``: drive a :class:`FaultPlan` against a live deployment.

The runner is the thin applicator between a seeded schedule and the
tolerance machinery it exercises: membership events hit the ``AerialDB``
session (``fail_edges`` / ``recover_edges`` / ``fail_device`` /
``recover_device`` / ``partition`` / ``heal`` — recoveries and heals run
the incremental repair inline, the path under test), ingest events arm the
``IngestPipeline``'s ``fault_hook`` (``flush_fail`` raises
``TransientDispatchError`` on the next n dispatch attempts;
``pipeline_crash`` raises ``PipelineCrash`` once). Every applied event is
appended to :attr:`log` as a machine-readable dict — event identity plus
the effect telemetry (repair summary, ledger snapshot) — so a soak run's
full fault history lands in the BENCH JSON artifact.

Determinism: the runner adds no randomness — applying the same plan to
identically-seeded sessions/pipelines with the same workload produces
bitwise-identical stores and identical logs (gated in
``tests/test_chaos.py``). The runner deliberately does NOT catch
``PipelineCrash``: a crash tears the flush mid-flight exactly like a real
process death, and recovery (fresh pipeline + ``replay_journal``) is the
harness's job — see ``fig19_chaos_soak``.
"""

from __future__ import annotations

import json
from typing import Callable, Optional

from repro.chaos.plan import FaultPlan
from repro.ingest.pipeline import PipelineCrash, TransientDispatchError

__all__ = ["ChaosRunner"]

# Repair telemetry keys worth echoing per event (the full dict stays on
# AerialDB.last_repair).
_REPAIR_KEYS = ("shards_swept", "shards_tracked", "shards_replaced",
                "shards_unrepairable", "tuples_copied", "slots_reclaimed",
                "entries_backfilled", "mode")


class ChaosRunner:
    """Apply a fault plan step by step (see module docstring).

    Args:
      plan:     the seeded :class:`FaultPlan`.
      db:       the ``AerialDB`` session to inject membership faults into.
      pipeline: the ``IngestPipeline`` for ``flush_fail`` /
                ``pipeline_crash`` events (those raise without one).
    """

    def __init__(self, plan: FaultPlan, db, pipeline=None):
        self.plan = plan
        self.db = db
        self.pipeline = pipeline
        self.log: list = []
        self._i = 0

    @property
    def done(self) -> bool:
        return self._i >= len(self.plan.events)

    def advance(self, step: int) -> list:
        """Apply every not-yet-applied event due at or before ``step``, in
        plan order; returns the telemetry entries appended for them."""
        applied = []
        while (self._i < len(self.plan.events)
               and self.plan.events[self._i].step <= step):
            ev = self.plan.events[self._i]
            self._i += 1
            applied.append(self._apply(ev))
        return applied

    def run(self, tick: Callable[[int], None],
            n_steps: Optional[int] = None) -> list:
        """Drive the whole plan: for each step, apply due events then call
        ``tick(step)`` (the workload — submits, flushes, queries), and
        finally apply the closing events at the horizon. Returns the full
        log. Crashes (``PipelineCrash`` out of a tick) propagate — use
        manual :meth:`advance` loops when the harness owns recovery."""
        n = self.plan.n_steps if n_steps is None else n_steps
        for step in range(n):
            self.advance(step)
            tick(step)
        self.advance(self.plan.n_steps)
        return self.log

    def to_json(self) -> str:
        return json.dumps(self.log)

    # -- event application ---------------------------------------------------

    def _apply(self, ev) -> dict:
        entry = {"step": int(ev.step), "kind": ev.kind,
                 "args": _plain(list(ev.args))}
        fn = getattr(self, f"_ev_{ev.kind}")
        fn(ev.args, entry)
        self.log.append(entry)
        return entry

    def _note_repair(self, entry) -> None:
        rep = self.db.last_repair
        if rep is not None:
            entry["repair"] = {k: rep[k] for k in _REPAIR_KEYS}
        entry["ledger"] = self.db.ledger()

    def _need_pipeline(self, kind):
        if self.pipeline is None:
            raise ValueError(
                f"plan contains a {kind!r} event but the runner has no "
                "pipeline: pass ChaosRunner(plan, db, pipeline=...).")
        return self.pipeline

    def _ev_fail_edges(self, args, entry):
        self.db.fail_edges(list(args[0]))
        entry["ledger"] = self.db.ledger()

    def _ev_recover_edges(self, args, entry):
        self.db.recover_edges(list(args[0]))
        self._note_repair(entry)

    def _ev_fail_device(self, args, entry):
        self.db.fail_device(int(args[0]))
        entry["ledger"] = self.db.ledger()

    def _ev_recover_device(self, args, entry):
        self.db.recover_device(int(args[0]))
        self._note_repair(entry)

    def _ev_partition(self, args, entry):
        self.db.partition([list(g) for g in args[0]])
        entry["ledger"] = self.db.ledger()

    def _ev_heal(self, args, entry):
        self.db.heal()
        self._note_repair(entry)

    def _ev_flush_fail(self, args, entry):
        pipe = self._need_pipeline("flush_fail")
        burst = {"left": int(args[0])}
        entry["burst"] = int(args[0])

        def hook(pipeline, attempt):
            if burst["left"] > 0:
                burst["left"] -= 1
                raise TransientDispatchError(
                    f"chaos: injected transient dispatch failure "
                    f"({burst['left']} left in burst)")
        pipe.fault_hook = hook

    def _ev_pipeline_crash(self, args, entry):
        pipe = self._need_pipeline("pipeline_crash")

        def hook(pipeline, attempt):
            pipeline.fault_hook = None       # one-shot: crash exactly once
            raise PipelineCrash("chaos: injected mid-flush pipeline crash")
        pipe.fault_hook = hook


def _plain(x):
    if isinstance(x, (tuple, list)):
        return [_plain(v) for v in x]
    return int(x) if hasattr(x, "__int__") and not isinstance(x, bool) else x
