"""Chaos engine (PR 9): seeded fault-injection for the federated store.

The paper's resilience claims are about *graceful degradation under
intermittent connectivity* — more failure shapes than a clean
``fail_edges``. This package is the fault model and its harness:

* :class:`FaultPlan` / :class:`FaultEvent` (``plan.py``) — a deterministic,
  seeded schedule of timed faults: edge crash/recover, whole-device loss,
  fleet network partition/heal, transient flush-dispatch failures,
  mid-flush pipeline crash. ``FaultPlan.random(seed, ...)`` is pure in its
  seed — every run replays bit-identically.
* :class:`ChaosRunner` (``runner.py``) — applies a plan against a live
  ``AerialDB`` session + ``IngestPipeline``, logging every injected event
  (with repair/ledger effect telemetry) machine-readably.
* ``audit.py`` — the canonical-content equivalence check: after final
  heal + repair a faulted store must hold bit-identical content to a
  never-faulted reference (same tuples on same edges, same replica sets
  and index coverage), independent of ring write order.

Layering: chaos sits ABOVE ``repro.ingest`` and ``repro.api`` — it only
drives public surfaces (session membership calls, the pipeline's
documented ``fault_hook``), so the differential harness covering those
covers every injected run too.
"""

from repro.chaos.audit import assert_content_equal, canonical_content
from repro.chaos.plan import EVENT_KINDS, FaultEvent, FaultPlan
from repro.chaos.runner import ChaosRunner

__all__ = ["EVENT_KINDS", "FaultEvent", "FaultPlan", "ChaosRunner",
           "assert_content_equal", "canonical_content"]
