"""Seeded, replayable fault-injection schedules (PR 9 tentpole).

A ``FaultPlan`` is a deterministic list of timed :class:`FaultEvent`\\ s —
the fault taxonomy the chaos engine can inject against a live ``AerialDB``
session (+ ``IngestPipeline``):

=================  ========================================================
kind               meaning / args
=================  ========================================================
``fail_edges``     edge crash — ``(edge_ids,)``
``recover_edges``  edge recovery (+ incremental repair) — ``(edge_ids,)``
``fail_device``    whole failure-domain loss — ``(domain,)``
``recover_device`` failure-domain recovery (+ repair) — ``(domain,)``
``partition``      fleet network partition — ``(groups,)``: connectivity
                   groups, coordinator keeps the first
``heal``           partition heal (+ repair) — ``()``
``flush_fail``     transient flush-dispatch failures — ``(n,)``: the next
                   n dispatch attempts raise ``TransientDispatchError``
``pipeline_crash`` mid-flush process crash — ``()``: the next dispatch
                   raises ``PipelineCrash`` (recovery = fresh pipeline +
                   journal replay)
=================  ========================================================

``FaultPlan.random(seed, ...)`` generates a *well-formed* schedule from a
PRNG seed — pure in the seed and parameters, so the same seed replays the
identical plan (the determinism contract the soak benchmark and the
property tests gate). Well-formed means: at least ``min_alive`` edges stay
alive AND reachable at every point (placement keeps its full replication
degree), at most one partition is open at a time, transient bursts stay
within ``max_transient`` (callers bound it by the pipeline's retry budget
to keep ``gave_up == 0``), and every fault is closed by the end — trailing
``recover_*`` / ``heal`` events at step ``n_steps`` return the fleet to
full health, so a final repair converges the store to the never-faulted
canonical placement.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import numpy as np

__all__ = ["EVENT_KINDS", "FaultEvent", "FaultPlan"]

EVENT_KINDS = ("fail_edges", "recover_edges", "fail_device",
               "recover_device", "partition", "heal", "flush_fail",
               "pipeline_crash")


class FaultEvent(NamedTuple):
    """One timed injection: fires when the runner advances to ``step``."""
    step: int
    kind: str
    args: Tuple = ()


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, replayable fault schedule (see module docstring).

    ``events`` must be step-sorted with known kinds — validated eagerly so
    a malformed hand-built plan fails at construction, not mid-soak.
    ``seed`` records provenance for plans built by :meth:`random` (None
    for hand-built ones); two plans are equal iff their events and horizon
    are — the replay-determinism property is ``FaultPlan.random(s, ...) ==
    FaultPlan.random(s, ...)``.
    """
    events: Tuple[FaultEvent, ...]
    n_steps: int
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "events",
                           tuple(FaultEvent(*e) for e in self.events))
        steps = [e.step for e in self.events]
        if steps != sorted(steps):
            raise ValueError("FaultPlan events must be step-sorted "
                             f"(got steps {steps}).")
        bad = sorted({e.kind for e in self.events} - set(EVENT_KINDS))
        if bad:
            raise ValueError(f"unknown fault kind(s) {bad}: valid kinds "
                             f"are {EVENT_KINDS}.")

    def kinds(self) -> Tuple[str, ...]:
        return tuple(e.kind for e in self.events)

    def to_rows(self) -> list:
        """JSON-serializable event rows (telemetry / BENCH artifacts)."""
        def plain(x):
            if isinstance(x, (tuple, list)):
                return [plain(v) for v in x]
            return int(x) if isinstance(x, (int, np.integer)) else x
        return [{"step": int(e.step), "kind": e.kind,
                 "args": plain(list(e.args))} for e in self.events]

    @classmethod
    def random(cls, seed: int, *, n_edges: int, n_steps: int = 12,
               n_domains: int = 0, min_alive: int = 4,
               p_fault: float = 0.6, max_concurrent: int = 3,
               max_transient: int = 2, allow_crash: bool = False,
               require: Tuple[str, ...] = ()) -> "FaultPlan":
        """Generate a well-formed seeded schedule (module docstring).

        Args:
          seed:        the replay key — same seed, same plan, always.
          n_edges:     deployment size (edge ids drawn from it).
          n_steps:     schedule horizon; closing recover/heal events land
                       at step ``n_steps`` exactly.
          n_domains:   > 0 enables ``fail_device``/``recover_device``
                       events over contiguous blocks of
                       ``n_edges // n_domains`` edges (must match the
                       session's failure-domain layout).
          min_alive:   edges that stay alive AND reachable throughout —
                       keep >= the replication degree so placement never
                       degrades below full replication.
          p_fault:     per-step probability of injecting an event.
          max_concurrent: bound on simultaneously-dead edges.
          max_transient:  cap on each ``flush_fail`` burst; bound it by
                       the pipeline's ``max_retries`` for ``gave_up == 0``.
          allow_crash: permit one ``pipeline_crash`` per plan (the caller
                       must then own journal-replay recovery).
          require:     event kinds that must appear; the generator retries
                       derived sub-seeds (deterministically) until they do.
        """
        for attempt in range(64):
            plan = cls._random_once(np.random.default_rng(
                np.random.SeedSequence([int(seed), attempt])),
                seed, n_edges, n_steps, n_domains, min_alive, p_fault,
                max_concurrent, max_transient, allow_crash)
            if set(require) <= set(plan.kinds()):
                return plan
        raise ValueError(
            f"could not generate a plan containing {require} in 64 "
            f"attempts (seed {seed}): loosen the constraints (more steps, "
            "higher p_fault) or drop the requirement.")

    @classmethod
    def _random_once(cls, rng, seed, n_edges, n_steps, n_domains,
                     min_alive, p_fault, max_concurrent, max_transient,
                     allow_crash) -> "FaultPlan":
        events = []
        dead_edges: set = set()       # edge-granular failures
        dead_doms: set = set()        # whole-domain failures
        partition: Optional[set] = None
        crashed = False
        block = (n_edges // n_domains) if n_domains else 0

        def dom_edges(d):
            return set(range(d * block, (d + 1) * block))

        def dead_all():
            out = set(dead_edges)
            for d in dead_doms:
                out |= dom_edges(d)
            return out

        def effective():
            return (set(range(n_edges)) - dead_all()
                    - (partition if partition else set()))

        for step in range(n_steps):
            if rng.random() >= p_fault:
                continue
            feasible = ["flush_fail"]
            eff = effective()
            if (len(dead_all()) < max_concurrent
                    and len(eff) > min_alive + 1):
                feasible.append("fail_edges")
            # One dead domain at a time: a whole-block loss already counts
            # as the plan's big concurrent failure.
            if n_domains and not dead_doms and any(
                    dom_edges(d) <= eff
                    and len(eff - dom_edges(d)) >= min_alive
                    for d in range(n_domains)):
                feasible.append("fail_device")
            if dead_edges:
                feasible.append("recover_edges")
            if dead_doms:
                feasible.append("recover_device")
            if partition is None and len(eff) >= min_alive + 2:
                feasible.append("partition")
            if partition is not None:
                feasible.append("heal")
            if allow_crash and not crashed:
                feasible.append("pipeline_crash")

            kind = str(rng.choice(sorted(feasible)))
            if kind == "fail_edges":
                k = int(rng.integers(1, min(2, len(eff) - min_alive) + 1))
                picks = rng.choice(sorted(eff), size=k, replace=False)
                edges = tuple(int(e) for e in np.sort(picks))
                dead_edges |= set(edges)
                events.append(FaultEvent(step, kind, (edges,)))
            elif kind == "fail_device":
                cands = [d for d in range(n_domains)
                         if d not in dead_doms
                         and dom_edges(d) <= eff
                         and len(eff - dom_edges(d)) >= min_alive]
                if not cands:
                    continue
                d = int(rng.choice(cands))
                dead_doms.add(d)
                events.append(FaultEvent(step, kind, (d,)))
            elif kind == "recover_edges":
                k = int(rng.integers(1, len(dead_edges) + 1))
                picks = rng.choice(sorted(dead_edges), size=k,
                                   replace=False)
                edges = tuple(int(e) for e in np.sort(picks))
                dead_edges -= set(edges)
                events.append(FaultEvent(step, kind, (edges,)))
            elif kind == "recover_device":
                d = int(rng.choice(sorted(dead_doms)))
                dead_doms.discard(d)
                events.append(FaultEvent(step, kind, (d,)))
            elif kind == "partition":
                reach = sorted(effective())
                hi = max(1, len(reach) - min_alive)
                k = int(rng.integers(1, hi + 1))
                cut = {int(e) for e in rng.choice(reach, size=k,
                                                  replace=False)}
                partition = cut
                keep = tuple(s for s in range(n_edges) if s not in cut)
                events.append(FaultEvent(
                    step, kind, ((keep, tuple(sorted(cut))),)))
            elif kind == "heal":
                partition = None
                events.append(FaultEvent(step, kind, ()))
            elif kind == "pipeline_crash":
                crashed = True
                events.append(FaultEvent(step, kind, ()))
            else:       # flush_fail
                n = int(rng.integers(1, max_transient + 1))
                events.append(FaultEvent(step, kind, (n,)))

        # Close every open fault at the horizon: the fleet must end whole
        # so the final repair converges to the never-faulted placement.
        if partition is not None:
            events.append(FaultEvent(n_steps, "heal", ()))
        if dead_edges:
            events.append(FaultEvent(
                n_steps, "recover_edges", (tuple(sorted(dead_edges)),)))
        for d in sorted(dead_doms):
            events.append(FaultEvent(n_steps, "recover_device", (d,)))
        return cls(events=tuple(events), n_steps=n_steps, seed=int(seed))
