"""AerialDB-backed training data pipeline — the paper's technique as the
framework's data plane (DESIGN.md §4).

Sensor tuples stream from the drone fleet into the federated store
(content-hash placement, 3x replication). The training pipeline assembles
token batches by issuing *locality-aware spatio-temporal queries* against the
store: each training step queries a sliding temporal window over a spatial
tile, and the resulting observations are discretized into token ids. Batch
assembly therefore inherits AerialDB's guarantees: any <= 2 edge failures
leave the pipeline exact; 3+ degrade gracefully (missing tuples, never
corrupt ones).

Determinism/resume: batch content is a pure function of (seed, step), so a
restarted trainer replays the exact stream from the checkpointed step.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import AerialDB, StoreConfig, make_pred
from repro.data.synthetic import CityConfig, DroneFleet, make_sites


@dataclasses.dataclass
class PipelineConfig:
    vocab: int = 512
    batch: int = 4
    seq: int = 64
    n_drones: int = 16
    n_edges: int = 8
    rounds: int = 6               # fleet collection rounds to ingest
    records_per_shard: int = 30
    seed: int = 0


class AerialPipeline:
    """Ingest a synthetic fleet into AerialDB, then serve token batches."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        sites = make_sites(cfg.n_edges, CityConfig(), seed=cfg.seed + 3)
        self.store_cfg = StoreConfig(
            n_edges=cfg.n_edges, sites=tuple(map(tuple, sites.tolist())),
            tuple_capacity=1 << 14, index_capacity=2048,
            max_shards_per_query=64, records_per_shard=cfg.records_per_shard)
        self.db = AerialDB.open(self.store_cfg, seed=cfg.seed)
        fleet = DroneFleet(cfg.n_drones, records_per_shard=cfg.records_per_shard,
                           seed=cfg.seed + 1)
        self.t_max = 0.0
        for _ in range(cfg.rounds):
            payload, meta = fleet.next_shards()
            self.db.insert(payload, meta)
            self.t_max = float(payload[..., 0].max())

    def _window_stats(self, step: int, q: int):
        """Query q spatio-temporal windows; returns per-window aggregate
        stats used to seed the tokenizer (count/sum/min/max)."""
        rng = np.random.default_rng((self.cfg.seed, step))
        city = CityConfig()
        span = 0.05
        lat0 = rng.uniform(city.lat_min, city.lat_max - span, q).astype(np.float32)
        lon0 = rng.uniform(city.lon_min, city.lon_max - span, q).astype(np.float32)
        t0 = rng.uniform(0, max(self.t_max - 300.0, 1.0), q).astype(np.float32)
        pred = make_pred(q=q, lat0=lat0, lat1=lat0 + span, lon0=lon0,
                         lon1=lon0 + span, t0=t0, t1=t0 + 600.0,
                         has_spatial=True, has_temporal=True, is_and=True)
        result, _ = self.db.query(pred, key=jax.random.key(step))
        return result

    def get_batch(self, step: int):
        """Deterministic token batch derived from store queries at ``step``."""
        cfg = self.cfg
        result = self._window_stats(step, cfg.batch)
        # Tokenize: fold window aggregates into a per-sequence PRNG stream;
        # observations perturb the stream so data content matters.
        stats = np.stack([np.asarray(result.count, np.float32),
                          np.asarray(result.vsum, np.float32)], axis=1)
        toks = np.empty((cfg.batch, cfg.seq + 1), np.int32)
        for i in range(cfg.batch):
            h = np.int64(abs(int(stats[i, 0]) * 2654435761 + int(stats[i, 1] * 100)))
            rng = np.random.default_rng((cfg.seed, step, int(h) & 0x7FFFFFFF))
            toks[i] = rng.integers(0, cfg.vocab, cfg.seq + 1)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:].astype(np.int32))}
