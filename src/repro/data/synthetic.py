"""Synthetic drone-fleet workload generator (paper §4.2, §4.4.1).

Emulates the paper's setup: D drones random-walking a city region (the paper
uses ~20 km x 25 km of Bangalore; we use a configurable lat/lon box), each
sampling sensors every ``sample_period`` seconds and batching
``records_per_shard`` records into a shard (paper: 60 records / 5 min,
~17 kB). Edge sites are placed uniformly at random inside the region (the
paper samples OpenCellID tower locations).

Mobility follows the paper's random walk: at every step a drone either hovers
(P=0.8) or moves to a random neighboring waypoint (P=0.2) at ~10 m/s. Since
street graphs are out of scope, waypoints are a jittered lattice — what
matters to AerialDB is the spatio-temporal *distribution* of shards, not road
topology (the paper itself confines mobility to the communication plane,
§4.6).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.placement import ShardMeta


@dataclasses.dataclass(frozen=True)
class CityConfig:
    lat_min: float = 12.85      # ~Bangalore
    lat_max: float = 13.10      # ~27 km
    lon_min: float = 77.45
    lon_max: float = 77.75      # ~33 km
    p_hover: float = 0.8
    speed_deg: float = 0.0001   # ~11 m per 1 s step at these latitudes


def make_sites(n_edges: int, city: CityConfig, seed: int = 0) -> np.ndarray:
    """(E, 2) edge-server locations (stand-in for OpenCellID towers)."""
    rng = np.random.default_rng(seed)
    lat = rng.uniform(city.lat_min, city.lat_max, n_edges)
    lon = rng.uniform(city.lon_min, city.lon_max, n_edges)
    return np.stack([lat, lon], axis=1).astype(np.float32)


class DroneFleet:
    """Streaming shard generator for D drones."""

    def __init__(self, n_drones: int, city: CityConfig = CityConfig(),
                 records_per_shard: int = 60, sample_period: float = 5.0,
                 n_values: int = 4, seed: int = 1, stagger_s: float = 0.0):
        """``stagger_s`` de-synchronizes drone clocks (paper §3.4.1's
        random-delay mitigation for the H_t temporal-clustering hotspot):
        each drone's collection schedule is offset uniformly in
        [0, stagger_s). stagger_s ~ tau spreads per-round temporal
        mid-points across H_t buckets."""
        self.n_drones = n_drones
        self.city = city
        self.r = records_per_shard
        self.n_values = n_values
        self.sample_period = sample_period
        self.rng = np.random.default_rng(seed)
        self.t_offset = self.rng.uniform(0, stagger_s, n_drones) \
            if stagger_s > 0 else np.zeros(n_drones)
        self.pos = np.stack([
            self.rng.uniform(city.lat_min, city.lat_max, n_drones),
            self.rng.uniform(city.lon_min, city.lon_max, n_drones)], axis=1)
        self.t = 0.0
        self.seq = 0

    def next_shards(self):
        """One collection round: every drone emits one shard.

        Returns (payload (D, R, 3+V) float32, ShardMeta arrays as numpy).
        """
        d, r, v = self.n_drones, self.r, self.n_values
        c = self.city
        times = self.t + np.arange(r)[None, :] * self.sample_period \
            + self.t_offset[:, None]                                  # (D, R)
        lats = np.empty((d, r))
        lons = np.empty((d, r))
        for k in range(r):
            hover = self.rng.random(d) < c.p_hover
            step = self.rng.normal(0, c.speed_deg * self.sample_period, (d, 2))
            self.pos = np.where(hover[:, None], self.pos, self.pos + step)
            self.pos[:, 0] = np.clip(self.pos[:, 0], c.lat_min, c.lat_max)
            self.pos[:, 1] = np.clip(self.pos[:, 1], c.lon_min, c.lon_max)
            lats[:, k] = self.pos[:, 0]
            lons[:, k] = self.pos[:, 1]
        values = self.rng.normal(25.0, 5.0, (d, r, v))                # sensor obs
        payload = np.concatenate(
            [times[..., None], lats[..., None], lons[..., None], values],
            axis=-1).astype(np.float32)

        meta = ShardMeta(
            sid_hi=np.arange(d, dtype=np.int32),
            sid_lo=np.full(d, self.seq, np.int32),
            lat0=lats.min(1).astype(np.float32), lat1=lats.max(1).astype(np.float32),
            lon0=lons.min(1).astype(np.float32), lon1=lons.max(1).astype(np.float32),
            t0=times.min(1).astype(np.float32), t1=times.max(1).astype(np.float32),
        )
        self.t += r * self.sample_period
        self.seq += 1
        return payload, meta

    def next_rounds(self, n: int):
        """Stack ``n`` collection rounds for the fused ingest driver
        (``distributed.federation.ingest_rounds``): returns
        (payloads (N, D, R, 3+V) float32, ShardMeta with (N, D) fields)."""
        rounds = [self.next_shards() for _ in range(n)]
        payloads = np.stack([p for p, _ in rounds])
        meta = ShardMeta(*(np.stack([np.asarray(getattr(m, f)) for _, m in rounds])
                           for f in ShardMeta._fields))
        return payloads, meta


def make_query_workload(rng, n_queries: int, city: CityConfig, t_max: float,
                        spatial_km: float, temporal_s: float):
    """Paper §4.5.1 query workloads: random bbox of given size x time range.

    spatial_km in {0.2, 1, 5}; temporal_s in {300, 1800, 7200}.
    """
    deg = spatial_km / 111.0
    lat0 = rng.uniform(city.lat_min, city.lat_max - deg, n_queries).astype(np.float32)
    lon0 = rng.uniform(city.lon_min, city.lon_max - deg, n_queries).astype(np.float32)
    t0 = rng.uniform(0, max(t_max - temporal_s, 1.0), n_queries).astype(np.float32)
    return dict(
        lat0=lat0, lat1=(lat0 + deg).astype(np.float32),
        lon0=lon0, lon1=(lon0 + deg).astype(np.float32),
        t0=t0, t1=(t0 + temporal_s).astype(np.float32),
    )
