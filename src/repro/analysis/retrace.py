"""aeriallint layer 2: the jit-retrace budget harness.

Every federated operation dispatches through an ``lru_cache``-memoized jitted
entry point (``distributed.federation._insert_fn`` / ``_ingest_fn`` /
``_query_fn``; single-device ``core.datastore._insert_step_jit`` /
``_query_step_jit``). The steady-state contract is *zero retraces*: a fleet
session compiles each entry point once per (config, mesh, AggSpec-channels)
key and then never again — a weak-hash config dataclass, a shape-unstable
call site, or an accidentally-traced Python value silently 10x's ingest
latency without failing any correctness test.

This harness runs the canonical facade workload (insert, fused multi-round
ingest, one query per AggSpec channel set, fail/recover with implicit
repair, then post-repair re-insert/re-query) on every configured mesh shape
plus the single-device path, under a compilation counter, and asserts

  * **cold**: each budgeted entry point compiles EXACTLY its
    ``[tool.aeriallint.retrace.budgets]`` count, and
  * **warm**: a second, fresh session over the same config re-runs the whole
    workload and compiles none of them (the caches are keyed by value-equal
    configs, so a fresh ``AerialDB.open`` must be a pure cache hit).

Counting uses ``jax_log_compiles``: XLA's dispatch layer logs
``"Compiling <name> with global shapes ..."`` exactly once per jit cache
miss (the persistent compilation cache short-circuits *compilation*, not the
trace, so counts stay deterministic under a warm ``.jax_cache``).

CLI (also a tier-1 test — ``tests/test_analysis.py``):

    python -m repro.analysis.retrace            # human-readable, exit 1 on violation
    python -m repro.analysis.retrace --json -o ANALYSIS_retrace.json
"""

import os

# The canonical meshes need 4 host devices; the flag only matters before the
# first backend use, so setting it at import is safe even when a test runner
# (tests/conftest.py) already configured it.
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4"
                               ).strip()

import argparse
import collections
import json
import logging
import re
import sys
from typing import Optional

import jax

from repro.analysis.config import AeriallintConfig, load_config
from repro.api import AerialDB, AggSpec, Query, StoreConfig
from repro.data.synthetic import DroneFleet
from repro.launch.mesh import make_edge_mesh, make_fleet_mesh

# "Compiling <name> with global shapes and types ..." — emitted by the
# dispatch/pxla layer once per jit cache miss when jax_log_compiles is on.
_COMPILE_RE = re.compile(r"Compiling ([^\s]+) with global shapes")
_JAX_LOGGERS = ("jax._src.interpreters.pxla", "jax._src.dispatch")


class CompileCounter(logging.Handler):
    """Context manager counting XLA compilations by jitted-function name.

    Usage::

        with CompileCounter() as cc:
            run_workload()
        assert cc.counts["outer"] == 2

    ``counts`` maps jaxpr entry-point name -> number of compilations
    observed inside the ``with`` block (a ``collections.Counter``).
    """

    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.counts = collections.Counter()

    def emit(self, record):
        m = _COMPILE_RE.search(record.getMessage())
        if m:
            self.counts[m.group(1)] += 1

    def __enter__(self):
        self._prev = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        self._prev_levels = []
        for name in _JAX_LOGGERS:
            lg = logging.getLogger(name)
            self._prev_levels.append((lg, lg.level))
            lg.addHandler(self)
        return self

    def __exit__(self, *exc):
        for lg, _lvl in self._prev_levels:
            lg.removeHandler(self)
        jax.config.update("jax_log_compiles", self._prev)
        return False


# Distinctive shapes so the harness' jit cache keys cannot collide with any
# other config in the process (tier-1 runs this in the same interpreter as
# the rest of the suite; a shared (cfg, mesh) key would eat a cold compile).
_CANON_KWARGS = dict(n_edges=8, tuple_capacity=384, index_capacity=160,
                     max_shards_per_query=24, records_per_shard=3, n_values=2)
_N_DRONES = 6


def canonical_config(**overrides) -> StoreConfig:
    kw = dict(_CANON_KWARGS)
    kw.update(overrides)
    return StoreConfig(**kw)


def mesh_for(shape, n_edges: int):
    """Build the datastore mesh for a budget mesh shape: (N,) -> 1-D edge
    mesh, (F, E) -> 2-D (fleet, edge) mesh."""
    shape = tuple(int(x) for x in shape)
    if len(shape) == 1:
        return make_edge_mesh(shape[0], n_edges=n_edges)
    if len(shape) == 2:
        return make_fleet_mesh(shape[0], shape[1], n_edges=n_edges)
    raise ValueError(f"unsupported retrace mesh shape {shape}: the runtime "
                     "has 1-D (edge,) and 2-D (fleet, edge) meshes.")


def canonical_workload(cfg: StoreConfig, mesh) -> None:
    """The facade workload every budget is defined against: one insert, one
    fused 2-round ingest, one query per AggSpec channel set, a fail/recover
    cycle (implicit incremental repair), then a post-repair re-insert and
    re-query — the latter two must be pure cache hits even cold."""
    db = AerialDB.open(cfg, mesh=mesh, seed=0)
    fleet = DroneFleet(_N_DRONES, records_per_shard=cfg.records_per_shard,
                       n_values=cfg.n_values, seed=7)
    db.insert(*fleet.next_shards())
    db.ingest_rounds(*fleet.next_rounds(2))

    window = Query().bbox(12.0, 14.0, 77.0, 79.0).time(0.0, 1e5)
    single = window.agg("mean", channel=0)
    db.query(single)
    pred, _ = window.build()
    db.query(pred, agg=AggSpec(channels=(0, 1)))

    db.fail_edges(1)
    db.query(single)                      # re-plan around the dead edge
    db.recover_edges(1)                   # implicit incremental repair
    db.insert(*fleet.next_shards())       # post-repair: zero retraces
    db.query(pred, agg=AggSpec(channels=(0, 1)))


def _check(budgets: dict, counts: collections.Counter, phase: str,
           label: str) -> list:
    out = []
    for name, want in budgets.items():
        want = want if phase == "cold" else 0
        got = counts.get(name, 0)
        if got != want:
            out.append({
                "mesh": label, "phase": phase, "entry": name,
                "want": want, "got": got,
                "message": (f"[{label}/{phase}] jitted entry '{name}' "
                            f"compiled {got}x, budget is {want} — "
                            + ("a retrace regression (weak config hash / "
                               "shape-unstable call site?)" if phase == "warm"
                               or got > want else
                               "either dead dispatch or a stale budget "
                               "table in [tool.aeriallint.retrace]")),
            })
    return out


def run_retrace(repo_root: Optional[str] = None,
                cfg: Optional[AeriallintConfig] = None,
                seed_offset: int = 0) -> dict:
    """Run the budget harness on every configured mesh shape plus the
    single-device path; returns the machine-readable report.

    ``seed_offset`` perturbs the canonical StoreConfig's capacities so a
    repeated in-process run (e.g. CLI after the test suite already ran the
    harness) still measures a cold cache.
    """
    cfg = cfg or load_config(repo_root)
    extra = {"tuple_capacity": 384 + 128 * seed_offset} if seed_offset else {}
    store_cfg = canonical_config(**extra)

    runs = []
    legs = [("single_device", None, cfg.budgets(federated=False))]
    if jax.device_count() >= 4:
        for shape in cfg.retrace_mesh_shapes:
            label = "mesh" + str(tuple(int(x) for x in shape))
            legs.append((label, mesh_for(shape, store_cfg.n_edges),
                         cfg.budgets(federated=True)))
    else:  # pragma: no cover - CI always forces 4 host devices
        runs.append({"mesh": "mesh-legs-skipped",
                     "reason": f"device_count={jax.device_count()} < 4"})

    violations = []
    for label, mesh, budgets in legs:
        with CompileCounter() as cold:
            canonical_workload(store_cfg, mesh)
        with CompileCounter() as warm:
            canonical_workload(store_cfg, mesh)   # fresh session, same keys
        v = (_check(budgets, cold.counts, "cold", label)
             + _check(budgets, warm.counts, "warm", label))
        violations += v
        runs.append({"mesh": label, "budgets": budgets,
                     "cold": dict(cold.counts), "warm": dict(warm.counts),
                     "violations": len(v)})
    return {
        "tool": "aeriallint.retrace",
        "mesh_shapes": [list(s) for s in cfg.retrace_mesh_shapes],
        "runs": runs,
        "violations": violations,
        "ok": not violations,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.retrace",
        description="aeriallint layer 2: jit-retrace budget harness.")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable report")
    ap.add_argument("-o", "--output", default=None,
                    help="also write the JSON report to this file")
    ap.add_argument("--root", default=None, help="repo root override")
    args = ap.parse_args(argv)

    report = run_retrace(args.root)
    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)
    if args.json:
        json.dump(report, sys.stdout, indent=2)
        print()
    else:
        for v in report["violations"]:
            print(v["message"])
        n_legs = sum("budgets" in r for r in report["runs"])
        print(f"aeriallint.retrace: {n_legs} leg(s), "
              f"{len(report['violations'])} budget violation(s).")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
