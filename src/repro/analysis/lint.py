"""aeriallint driver: walk the configured roots, apply the rule engine,
emit findings.

    python -m repro.analysis.lint            # human-readable, exit 1 on open
    python -m repro.analysis.lint --json     # machine-readable findings
    python -m repro.analysis.lint --json -o AERIALLINT.json

Exit status is 0 iff every finding is suppressed by a *reasoned* pragma or
allowlist entry — CI gates on it. The JSON payload carries every finding
(open, disabled, allowlisted) plus config-policy errors (reasonless
allowlist entries), so the suppression surface itself stays reviewable.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.analysis.config import (AeriallintConfig, find_repo_root,
                                   load_config)
from repro.analysis.rules import Finding, lint_source

_SKIP_DIRS = {"__pycache__", ".git", ".jax_cache", ".ruff_cache", "node_modules"}


def iter_py_files(repo_root: str, roots) -> List[str]:
    out = []
    for r in roots:
        base = os.path.join(repo_root, r)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return out


def _relpath(path: str, repo_root: str) -> str:
    return os.path.relpath(os.path.abspath(path), repo_root).replace(
        os.sep, "/")


def lint_files(paths, repo_root: str,
               cfg: Optional[AeriallintConfig] = None) -> List[Finding]:
    """Lint explicit files (absolute or repo-relative); returns every
    finding, suppressed ones included."""
    cfg = cfg or load_config(repo_root)
    findings: List[Finding] = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(repo_root, p)
        rel = _relpath(full, repo_root)
        with open(full, encoding="utf-8") as fh:
            findings.extend(lint_source(fh.read(), rel, cfg))
    return findings


def config_policy_findings(cfg: AeriallintConfig) -> List[Finding]:
    """R0 findings for allowlist entries that are missing their reason (the
    rule engine skips reasonless entries; here they become hard errors)."""
    out = []
    for i, e in enumerate(cfg.allow):
        if not e.reason.strip():
            out.append(Finding(
                "R0", "pyproject.toml", 0,
                f"[tool.aeriallint] allow entry #{i + 1} (rule={e.rule!r}, "
                f"path={e.path!r}) has no reason — every suppression must "
                "say why it is intentional."))
        if not e.rule or not e.path:
            out.append(Finding(
                "R0", "pyproject.toml", 0,
                f"[tool.aeriallint] allow entry #{i + 1} needs both rule= "
                "and path=."))
    return out


def run_lint(repo_root: Optional[str] = None,
             paths=None) -> dict:
    """Full repo lint -> machine-readable report dict."""
    repo_root = repo_root or find_repo_root()
    cfg = load_config(repo_root)
    files = ([os.path.join(repo_root, p) if not os.path.isabs(p) else p
              for p in paths] if paths
             else iter_py_files(repo_root, cfg.roots))
    findings = config_policy_findings(cfg)
    findings += lint_files(files, repo_root, cfg)
    open_f = [f for f in findings if f.status == "open"]
    return {
        "tool": "aeriallint",
        "roots": list(cfg.roots),
        "files_scanned": len(files),
        "findings": [f.to_json() for f in findings],
        "open": len(open_f),
        "disabled": sum(f.status == "disabled" for f in findings),
        "allowlisted": sum(f.status == "allowlisted" for f in findings),
        "ok": not open_f,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="aeriallint: AerialDB repo-invariant static analysis.")
    ap.add_argument("paths", nargs="*",
                    help="specific files to lint (default: configured roots)")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable findings report")
    ap.add_argument("-o", "--output", default=None,
                    help="also write the JSON report to this file")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detected via "
                         "pyproject.toml)")
    args = ap.parse_args(argv)

    report = run_lint(args.root, args.paths or None)
    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)
    if args.json:
        json.dump(report, sys.stdout, indent=2)
        print()
    else:
        for f in report["findings"]:
            if f["status"] == "open":
                print(f"{f['path']}:{f['line']}: {f['rule']}: {f['message']}")
        print(f"aeriallint: {report['files_scanned']} files, "
              f"{report['open']} open finding(s), "
              f"{report['disabled']} pragma-disabled, "
              f"{report['allowlisted']} allowlisted.")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
