"""aeriallint configuration: the ``[tool.aeriallint]`` table of pyproject.toml.

Rules are data, not code — scan roots, allowlists, retrace budgets, and the
HLO collective contract all live in the repo's pyproject so a contract
change is a reviewable one-line diff, not a linter patch. Schema:

    [tool.aeriallint]
    roots = ["src", "benchmarks", "examples"]
    hot_functions = ["src/repro/core/datastore.py::insert_local", ...]

    [[tool.aeriallint.allow]]
    rule = "R3"                       # rule id the entry silences
    path = "src/repro/launch/dryrun.py"   # fnmatch glob, repo-relative
    match = "time.time"               # optional substring of the finding
    reason = "why this is intentional"    # REQUIRED — reasonless = finding

    [tool.aeriallint.retrace]
    mesh_shapes = [[4], [2, 2]]
    [tool.aeriallint.retrace.budgets.federated]
    step = 1        # jaxpr name -> exact cold-compile count per mesh
    [tool.aeriallint.retrace.budgets.single_device]
    _insert_step_jit = 1

    [tool.aeriallint.hlo]
    query_collectives = ["all-gather", "all-reduce"]
    insert_collectives = ["all-gather"]
    min_donated_aliases = 16

Parsing uses stdlib ``tomllib`` (3.11+) with a ``tomli`` fallback for 3.10
(already a transitive dependency of the packaging stack — no new install).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

try:
    import tomllib as _toml  # Python 3.11+
except ImportError:  # pragma: no cover - py3.10 path
    import tomli as _toml


@dataclasses.dataclass(frozen=True)
class AllowEntry:
    """One allowlist row: silences ``rule`` findings in files matching the
    ``path`` glob (optionally narrowed by a ``match`` substring over the
    finding message / source line). ``reason`` is mandatory policy."""
    rule: str
    path: str
    reason: str = ""
    match: str = ""


@dataclasses.dataclass(frozen=True)
class AeriallintConfig:
    roots: Tuple[str, ...] = ("src", "benchmarks", "examples")
    hot_functions: Tuple[str, ...] = ()
    allow: Tuple[AllowEntry, ...] = ()
    # Layer 2: canonical-workload compile budgets, keyed by jaxpr name.
    retrace_mesh_shapes: Tuple[Tuple[int, ...], ...] = ((4,), (2, 2))
    retrace_budget_federated: Tuple[Tuple[str, int], ...] = ()
    retrace_budget_single: Tuple[Tuple[str, int], ...] = ()
    # Layer 3: the ROADMAP collective contract.
    query_collectives: Tuple[str, ...] = ("all-gather", "all-reduce")
    insert_collectives: Tuple[str, ...] = ("all-gather",)
    min_donated_aliases: int = 1

    def budgets(self, federated: bool) -> dict:
        return dict(self.retrace_budget_federated if federated
                    else self.retrace_budget_single)


def find_repo_root(start: Optional[str] = None) -> str:
    """Walk up from ``start`` (default: this file) to the directory holding
    pyproject.toml. The linter is repo-relative everywhere."""
    d = os.path.abspath(start or os.path.dirname(__file__))
    while True:
        if os.path.exists(os.path.join(d, "pyproject.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            raise FileNotFoundError(
                "no pyproject.toml above "
                f"{start or os.path.dirname(__file__)}: aeriallint needs the "
                "repo root for its [tool.aeriallint] config.")
        d = parent


def load_config(repo_root: Optional[str] = None) -> AeriallintConfig:
    """Read ``[tool.aeriallint]`` from the repo's pyproject.toml. Missing
    table (or keys) falls back to defaults, so the linter degrades to its
    built-in policy outside this repo."""
    root = repo_root or find_repo_root()
    with open(os.path.join(root, "pyproject.toml"), "rb") as fh:
        data = _toml.load(fh)
    tbl = data.get("tool", {}).get("aeriallint", {})
    allow = tuple(
        AllowEntry(rule=str(e.get("rule", "")), path=str(e.get("path", "")),
                   reason=str(e.get("reason", "")),
                   match=str(e.get("match", "")))
        for e in tbl.get("allow", ()))
    retr = tbl.get("retrace", {})
    budgets = retr.get("budgets", {})
    hlo = tbl.get("hlo", {})
    dflt = AeriallintConfig()
    return AeriallintConfig(
        roots=tuple(tbl.get("roots", dflt.roots)),
        hot_functions=tuple(tbl.get("hot_functions", ())),
        allow=allow,
        retrace_mesh_shapes=tuple(
            tuple(int(x) for x in shape)
            for shape in retr.get("mesh_shapes", [[4], [2, 2]])),
        retrace_budget_federated=tuple(
            (str(k), int(v)) for k, v in budgets.get("federated", {}).items()),
        retrace_budget_single=tuple(
            (str(k), int(v))
            for k, v in budgets.get("single_device", {}).items()),
        query_collectives=tuple(
            hlo.get("query_collectives", dflt.query_collectives)),
        insert_collectives=tuple(
            hlo.get("insert_collectives", dflt.insert_collectives)),
        min_donated_aliases=int(
            hlo.get("min_donated_aliases", dflt.min_donated_aliases)),
    )
