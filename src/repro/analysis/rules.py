"""aeriallint rule engine: repo-specific AST rules over one source file.

Rule catalog (ids are stable — they key allowlists and disable pragmas):

  R0  meta: a ``# aeriallint: disable=`` pragma or a ``[tool.aeriallint]``
      allowlist entry without a reason string (suppressions are themselves
      policy and must be justified).
  R1  layering: ``repro.core`` / ``repro.distributed`` / ``repro.kernels``
      never import ``repro.api`` / ``repro.ingest`` / ``repro.chaos`` (the
      facade sits strictly ABOVE the runtime — PR 3 contract), and
      ``repro.ingest`` touches only the facade (``repro.api``) plus itself —
      never the runtime internals (PR 8 contract).
  R2  deprecation: no ``insert_step`` / ``query_step`` call sites or imports
      outside their defining module (PR 3: new code goes through
      ``repro.api``; the shims exist only for pinned-return-value tests).
  R3  determinism: no wall-clock reads (``time.time``/``monotonic``/
      ``perf_counter``/``sleep``, ``datetime.now``...) in ``src/repro`` and
      no unseeded randomness (global-state ``np.random.*``, bare stdlib
      ``random.*``) anywhere scanned — the PR-9 bitwise-replay contract:
      same seeds + same workload must reproduce stores bit-for-bit.
      Seeded constructs (``np.random.default_rng`` / ``Generator`` /
      ``SeedSequence`` / ``PCG64`` / ``Philox``) are always fine.
  R4  host-sync hygiene: no ``.item()``, ``float(<traced>)``,
      ``np.asarray`` / ``np.array``, or ``jax.device_get`` inside jitted /
      shard_map / pallas bodies or the configured hot-path functions — each
      is a device sync that serializes the async dispatch pipeline (the
      PR-8 lazy drop-watch rule, generalized).
  R5  traced branching: no Python ``if`` / ``while`` whose test calls into
      ``jnp`` / ``jax.numpy`` / ``jax.lax`` inside a traced body — a traced
      value in a Python branch either raises under jit or silently bakes in
      one trace-time path.
  R6  dead imports: a module-level import never referenced in the module
      (skipped for ``__init__.py`` re-export surfaces and names in
      ``__all__``).

Escape hatch: ``# aeriallint: disable=R3 -- <reason>`` on the finding line
or the line directly above. The reason is mandatory (R0 otherwise).
"""

from __future__ import annotations

import ast
import dataclasses
import re
from fnmatch import fnmatch
from typing import List, Optional, Tuple

from repro.analysis.config import AeriallintConfig

RULE_IDS = ("R0", "R1", "R2", "R3", "R4", "R5", "R6")

# R1: the runtime layers that must never see the layers above them.
_RUNTIME_LAYERS = ("src/repro/core/", "src/repro/distributed/",
                   "src/repro/kernels/")
_UPPER_LAYERS = ("repro.api", "repro.ingest", "repro.chaos")
_INGEST_OK = ("repro.api", "repro.ingest")

# R2: the deprecated PR-3 shims and their one legitimate home.
_DEPRECATED = ("insert_step", "query_step")
_DEPRECATED_HOME = "src/repro/core/datastore.py"

# R3: wall-clock reads (src/repro only — benchmarks legitimately time).
_CLOCK_CALLS = {("time", "time"), ("time", "time_ns"), ("time", "monotonic"),
                ("time", "perf_counter"), ("time", "sleep"),
                ("datetime", "now"), ("datetime", "utcnow"),
                ("datetime", "today")}
# R3: np.random attributes that are seeded constructs, not global-state RNG.
_SEEDED_OK = {"default_rng", "Generator", "SeedSequence", "PCG64", "PCG64DXSM",
              "Philox", "MT19937", "SFC64", "BitGenerator", "RandomState"}

# R4/R5: callables whose function-reference arguments become traced bodies.
_TRACING_CALLS = {"jit", "shard_map", "pallas_call", "scan", "while_loop",
                  "fori_loop", "cond", "switch", "checkpoint", "remat",
                  "custom_vjp", "custom_jvp", "vmap", "grad", "value_and_grad"}

_PRAGMA_RE = re.compile(
    r"#\s*aeriallint:\s*disable=([A-Za-z0-9,\s]+?)\s*(?:--\s*(.*))?$")


@dataclasses.dataclass
class Finding:
    rule: str
    path: str          # repo-relative, forward slashes
    line: int
    message: str
    snippet: str = ""
    status: str = "open"   # open | disabled (pragma) | allowlisted (config)
    reason: str = ""       # the pragma / allowlist justification

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        tag = "" if self.status == "open" else f" [{self.status}]"
        return f"{self.path}:{self.line}: {self.rule}{tag}: {self.message}"


def _dotted(node: ast.AST) -> Optional[str]:
    """'np.random.rand' for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _ModuleScan(ast.NodeVisitor):
    """One pass collecting everything the rules need: imports (+aliases),
    every name use, and the set of function defs that are traced (jit /
    shard_map / pallas bodies, their nested defs, and configured hot
    functions)."""

    def __init__(self):
        self.imports: List[Tuple[ast.AST, str, str]] = []  # (node, module, asname)
        self.import_binds: dict = {}       # local name -> canonical dotted
        self.used_names: set = set()
        self.func_defs: dict = {}          # name -> [def nodes]
        self.traced_args: set = set()      # func names passed to tracing calls
        self.decorated_traced: set = set() # func names with jit-ish decorators
        self.all_exports: set = set()
        self._func_stack: List[ast.AST] = []

    # -- imports ------------------------------------------------------------

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            local = a.asname or a.name.split(".")[0]
            self.imports.append((node, a.name, a.asname or a.name))
            self.import_binds[local] = a.name if a.asname else \
                a.name.split(".")[0]
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = node.module or ""
        for a in node.names:
            local = a.asname or a.name
            self.imports.append((node, f"{mod}.{a.name}" if mod else a.name,
                                 local))
            self.import_binds[local] = f"{mod}.{a.name}" if mod else a.name
        self.generic_visit(node)

    # -- usage --------------------------------------------------------------

    def visit_Name(self, node: ast.Name):
        if isinstance(node.ctx, ast.Load):
            self.used_names.add(node.id)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign):
        # __all__ = [...] marks re-export surfaces for R6.
        for t in node.targets:
            if isinstance(t, ast.Name) and t.id == "__all__":
                for el in ast.walk(node.value):
                    if isinstance(el, ast.Constant) and isinstance(
                            el.value, str):
                        self.all_exports.add(el.value)
        self.generic_visit(node)

    # -- traced-body discovery ----------------------------------------------

    def _is_tracing_callable(self, func: ast.AST) -> bool:
        d = _dotted(func)
        if d is None:
            return False
        leaf = d.split(".")[-1]
        return leaf in _TRACING_CALLS

    def visit_Call(self, node: ast.Call):
        if self._is_tracing_callable(node.func):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    self.traced_args.add(arg.id)
                elif isinstance(arg, (ast.Lambda,)):
                    arg._aeriallint_traced = True  # noqa: SLF001 (own marker)
        # functools.partial(jax.jit, ...) decorators route through here too.
        self.generic_visit(node)

    def _handle_func(self, node):
        self.func_defs.setdefault(node.name, []).append(node)
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if self._is_tracing_callable(target):
                self.decorated_traced.add(node.name)
            elif isinstance(dec, ast.Call) and _dotted(dec.func) in (
                    "partial", "functools.partial") and dec.args:
                if self._is_tracing_callable(dec.args[0]):
                    self.decorated_traced.add(node.name)
        self._func_stack.append(node)
        self.generic_visit(node)
        self._func_stack.pop()

    def visit_FunctionDef(self, node):
        self._handle_func(node)

    def visit_AsyncFunctionDef(self, node):
        self._handle_func(node)


def _collect_pragmas(source: str):
    """line number -> (set of rule ids, reason, pragma line no)."""
    out = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out[i] = (rules, (m.group(2) or "").strip())
    return out


def _traced_functions(scan: _ModuleScan, path: str,
                      cfg: AeriallintConfig) -> List[ast.AST]:
    """Every function whose body jit traces: decorated, passed to a tracing
    callable, named in ``hot_functions`` config, or nested inside one of
    those."""
    hot = set()
    for spec in cfg.hot_functions:
        if "::" in spec:
            glob, fname = spec.rsplit("::", 1)
            if fnmatch(path, glob):
                hot.add(fname)
    roots = []
    for name, defs in scan.func_defs.items():
        if name in scan.traced_args or name in scan.decorated_traced \
                or name in hot:
            roots.extend(defs)
    # Nested defs inside a traced function trace with it.
    seen = set()
    out = []
    stack = list(roots)
    while stack:
        fn = stack.pop()
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        out.append(fn)
        for sub in ast.walk(fn):
            if sub is not fn and isinstance(
                    sub, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                if id(sub) not in seen:
                    stack.append(sub)
    return out


def _r1_layering(tree, scan, path, add):
    in_runtime = any(path.startswith(p) for p in _RUNTIME_LAYERS)
    in_ingest = path.startswith("src/repro/ingest/")
    if not (in_runtime or in_ingest):
        return
    for node, module, _local in scan.imports:
        if not module.startswith("repro"):
            continue
        if in_runtime and any(module == up or module.startswith(up + ".")
                              for up in _UPPER_LAYERS):
            add("R1", node.lineno,
                f"layering violation: {path} (runtime layer) imports "
                f"'{module}' — core/distributed/kernels must never see the "
                "facade, ingest, or chaos layers above them (PR 3/8/9 "
                "contracts).")
        if in_ingest and not any(
                module == ok or module.startswith(ok + ".")
                for ok in _INGEST_OK):
            add("R1", node.lineno,
                f"layering violation: repro.ingest imports '{module}' — the "
                "ingest pipeline is strictly host-side OVER the facade "
                "(repro.api) and must not reach runtime internals, or the "
                "federation differential harness no longer covers its "
                "flush paths (PR 8 contract).")


def _r2_deprecation(tree, scan, path, add):
    if path == _DEPRECATED_HOME:
        return
    for node, module, local in scan.imports:
        leaf = module.split(".")[-1]
        if leaf in _DEPRECATED:
            add("R2", node.lineno,
                f"deprecated shim import: '{leaf}' — the PR-2-pinned "
                "1-device shims exist only for shim-equivalence tests; go "
                "through repro.api.AerialDB (insert/ingest_rounds/query).")
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = None
            if isinstance(node.func, ast.Name):
                name = node.func.id
            elif isinstance(node.func, ast.Attribute):
                name = node.func.attr
            if name in _DEPRECATED:
                add("R2", node.lineno,
                    f"deprecated shim call: '{name}(...)' — use the "
                    "AerialDB facade (PR 3: insert_step/query_step are "
                    "warned 1-device shims, not API).")


def _r3_determinism(tree, scan, path, add):
    check_clock = path.startswith("src/repro/")
    # Bare stdlib `random` only counts when this module imported it (jax and
    # numpy both expose a `random` attribute that is fine).
    stdlib_random = scan.import_binds.get("random") == "random"
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        d = _dotted(node.func)
        if d is None:
            continue
        parts = tuple(d.split("."))
        if check_clock and len(parts) >= 2 and parts[-2:] in _CLOCK_CALLS \
                and parts[0] in ("time", "datetime"):
            add("R3", node.lineno,
                f"wall-clock read '{d}()' in src/repro — the PR-9 "
                "bitwise-replay contract forbids nondeterministic inputs "
                "outside injected points (pass clocks/sleeps in, or "
                "allowlist telemetry-only uses with a reason).")
        if len(parts) >= 3 and parts[0] in ("np", "numpy") \
                and parts[1] == "random" and parts[2] not in _SEEDED_OK:
            add("R3", node.lineno,
                f"unseeded global-state RNG '{d}()' — use "
                "np.random.default_rng(seed) (or a passed-in Generator) so "
                "replay is pure in its seeds.")
        if stdlib_random and len(parts) == 2 and parts[0] == "random":
            add("R3", node.lineno,
                f"bare stdlib RNG '{d}()' draws from hidden global state — "
                "use np.random.default_rng(seed) / jax.random keys.")


def _r4_r5_traced(tree, scan, path, cfg, add):
    np_aliases = {local for local, mod in scan.import_binds.items()
                  if mod in ("numpy", "np")}
    np_aliases.add("np")
    traced_roots = ("jnp", "jax.numpy", "jax.lax")
    for fn in _traced_functions(scan, path, cfg):
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        fname = getattr(fn, "name", "<lambda>")
        for node in [n for b in body for n in ast.walk(b)]:
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" and not node.args:
                    add("R4", node.lineno,
                        f"'.item()' inside traced body '{fname}' — a "
                        "device->host sync on the hot path (PR 8 rule: "
                        "read telemetry lazily, outside the dispatch "
                        "pipeline).")
                elif d is not None and d.split(".")[0] in np_aliases \
                        and d.split(".")[-1] in ("asarray", "array"):
                    add("R4", node.lineno,
                        f"'{d}(...)' inside traced body '{fname}' — numpy "
                        "materialization forces a host sync under jit; use "
                        "jnp, or hoist to the host-side wrapper.")
                elif d in ("jax.device_get",):
                    add("R4", node.lineno,
                        f"'jax.device_get' inside traced body '{fname}' — "
                        "device->host transfer cannot be traced.")
                elif isinstance(node.func, ast.Name) \
                        and node.func.id == "float" and node.args \
                        and not isinstance(node.args[0], ast.Constant):
                    add("R4", node.lineno,
                        f"'float(...)' on a (potentially traced) value "
                        f"inside '{fname}' — concretizes the tracer; use "
                        "jnp.float32(...) / .astype instead.")
            if isinstance(node, (ast.If, ast.While)):
                for sub in ast.walk(node.test):
                    if isinstance(sub, ast.Call):
                        d = _dotted(sub.func)
                        if d and any(d.startswith(r + ".")
                                     for r in traced_roots):
                            add("R5", node.lineno,
                                f"Python branch on a traced expression "
                                f"('{d}' in the test) inside '{fname}' — "
                                "under jit this either raises a tracer "
                                "error or silently freezes one trace-time "
                                "path; use jnp.where / lax.cond.")
                            break


def _r6_dead_imports(tree, scan, path, add):
    if path.endswith("__init__.py"):
        return  # re-export surface
    for node, module, local in scan.imports:
        base = local.split(".")[0]
        if base.startswith("_") or module.startswith("__future__"):
            continue
        if base in scan.used_names or base in scan.all_exports:
            continue
        add("R6", node.lineno,
            f"dead import: '{local}' (from '{module}') is never used in "
            "this module.")


def lint_source(source: str, path: str,
                cfg: Optional[AeriallintConfig] = None) -> List[Finding]:
    """Lint one file's source text. ``path`` is repo-relative with forward
    slashes — rules key scope off it. Returns ALL findings, with pragma- and
    allowlist-suppressed ones carrying status 'disabled'/'allowlisted'
    (callers gate on status == 'open')."""
    cfg = cfg or AeriallintConfig()
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("R0", path, e.lineno or 1,
                        f"file does not parse: {e.msg}")]
    scan = _ModuleScan()
    scan.visit(tree)
    lines = source.splitlines()
    findings: List[Finding] = []

    def add(rule: str, line: int, message: str):
        snippet = lines[line - 1].strip() if 0 < line <= len(lines) else ""
        findings.append(Finding(rule, path, line, message, snippet=snippet))

    _r1_layering(tree, scan, path, add)
    _r2_deprecation(tree, scan, path, add)
    _r3_determinism(tree, scan, path, add)
    _r4_r5_traced(tree, scan, path, cfg, add)
    _r6_dead_imports(tree, scan, path, add)

    # Pragmas: suppress findings on the pragma line or the line below an
    # own-line pragma; a pragma without a reason is itself a finding.
    pragmas = _collect_pragmas(source)
    for pline, (rules, reason) in pragmas.items():
        if not reason:
            findings.append(Finding(
                "R0", path, pline,
                "aeriallint disable pragma without a reason: write "
                "'# aeriallint: disable=Rn -- <why this is intentional>'.",
                snippet=lines[pline - 1].strip()))
    for f in findings:
        for pline in (f.line, f.line - 1):
            pr = pragmas.get(pline)
            if pr and f.rule in pr[0] and pr[1]:
                f.status = "disabled"
                f.reason = pr[1]
                break

    # Config allowlist (reasonless entries are reported by the lint driver,
    # which sees the whole config once — not per file).
    for f in findings:
        if f.status != "open":
            continue
        for e in cfg.allow:
            if e.rule != f.rule or not e.reason:
                continue
            if not fnmatch(f.path, e.path):
                continue
            if e.match and e.match not in f.message and \
                    e.match not in f.snippet:
                continue
            f.status = "allowlisted"
            f.reason = e.reason
            break
    return findings
