"""aeriallint layer 3: the HLO collective-contract verifier.

The ROADMAP communication contract (PR 2, generalized cross-host in PR 6)
says cross-device traffic on the datastore mesh is exactly three things: the
O(E) watermark all-gather on insert, the metadata-scale hierarchical
candidate-merge all-gathers, and the final (Q[, K], E) combine all-reduces
on query — and none of it scales with ``tuple_capacity`` (the per-edge log
stays device-local; only watermarks, candidate sets, and aggregates move).
The differential tests prove the *values* right; nothing so far proved the
*traffic* right — an accidental resharding that all-gathers the tuple ring
would be bitwise invisible and catastrophically slow at paper scale.

This verifier lowers the federated insert / fused-ingest / query entry
points (the same ``distributed.federation`` factories the facade
dispatches through) on every configured mesh shape and statically checks
the compiled, post-SPMD HLO:

  * **kinds** — each module executes only its contracted collective kinds
    (``[tool.aeriallint.hlo] insert_collectives / query_collectives``);
    ingest of N rounds runs exactly N watermark all-gathers.
  * **capacity independence** — the execution-weighted multiset of
    (collective kind, result type) is IDENTICAL when lowered at two
    different ``tuple_capacity`` values: growing the log must not change a
    single cross-device tensor.
  * **donation** — ``ingest_rounds`` donates the 16-leaf StoreState; the
    compiled module must declare at least ``min_donated_aliases``
    input/output aliases, the static witness that sustained ingest updates
    rings in place instead of double-allocating.

CLI (also a tier-1 test — ``tests/test_analysis.py``):

    python -m repro.analysis.hlo_contract            # exit 1 on violation
    python -m repro.analysis.hlo_contract --json -o ANALYSIS_hlo.json
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4"
                               ).strip()

import argparse
import json
import sys
from typing import Optional

import jax
import jax.numpy as jnp

from repro.analysis.config import AeriallintConfig, load_config
from repro.analysis.retrace import canonical_config, mesh_for
from repro.api import ShardMeta
from repro.core.datastore import init_store, make_pred
from repro.data.synthetic import DroneFleet
from repro.distributed import federation as fed
from repro.distributed.sharding import shard_store
from repro.launch.hlo_analysis import collective_shapes, io_alias_pairs

_N_ROUNDS = 2          # fused-ingest rounds to lower
_CAPACITIES = (384, 1024)   # tuple_capacity pair for the independence check


def _inputs(cfg, mesh):
    """Concrete lowering inputs for one (cfg, mesh): sharded init state, one
    insert round, N stacked ingest rounds, a 4-window predicate, a key."""
    fleet = DroneFleet(6, records_per_shard=cfg.records_per_shard,
                       n_values=cfg.n_values, seed=7)
    payload, meta = fleet.next_shards()
    payloads, metas = fleet.next_rounds(_N_ROUNDS)
    state = shard_store(init_store(cfg), mesh)
    alive = jnp.ones(cfg.n_edges, bool)
    pred = make_pred(q=4, lat0=12.0, lat1=14.0, lon0=77.0, lon1=79.0,
                     t0=0.0, t1=1e5, has_spatial=True, has_temporal=True)
    return dict(
        state=state, alive=alive, pred=pred,
        payload=jnp.asarray(payload),
        meta=ShardMeta(*[jnp.asarray(f) for f in meta]),
        payloads=jnp.asarray(payloads),
        metas=ShardMeta(*[jnp.asarray(f) for f in metas]),
        key_data=jax.random.key_data(jax.random.key(0)))


def lower_entry_points(cfg, mesh) -> dict:
    """Compiled per-device HLO text for the three federated entry points,
    via the exact ``federation`` factories the facade dispatches through."""
    a = _inputs(cfg, mesh)
    insert = fed._insert_fn(cfg, mesh).lower(
        a["state"], a["payload"], a["meta"], a["alive"])
    ingest = fed._ingest_fn(cfg, mesh).lower(
        a["state"], a["payloads"], a["metas"], a["alive"])
    query = fed._query_fn(cfg, mesh, False, None, (0,)).lower(
        a["state"], a["pred"], a["alive"], a["key_data"])
    return {name: lowered.compile().as_text()
            for name, lowered in
            [("insert", insert), ("ingest", ingest), ("query", query)]}


def check_collective_contract(hlo: str, allowed, label: str,
                              exact_counts: Optional[dict] = None) -> list:
    """Violations if ``hlo`` executes a collective kind outside ``allowed``
    (or, with ``exact_counts``, the wrong number of a kind). Takes raw HLO
    text so tests can inject a contraband collective."""
    shapes = collective_shapes(hlo)
    out = []
    by_kind = {}
    for (kind, shape), n in shapes.items():
        by_kind[kind] = by_kind.get(kind, 0) + n
        if kind not in allowed:
            out.append({
                "check": "kinds", "label": label, "kind": kind,
                "message": (f"[{label}] contraband collective: {n}x "
                            f"'{kind}' of {shape} — contract allows only "
                            f"{sorted(allowed)} (ROADMAP communication "
                            "contract)."),
            })
    for kind, want in (exact_counts or {}).items():
        got = by_kind.get(kind, 0)
        if got != want:
            out.append({
                "check": "counts", "label": label, "kind": kind,
                "message": (f"[{label}] expected exactly {want}x '{kind}', "
                            f"compiled module executes {got}x."),
            })
    return out


def check_capacity_independence(shapes_a: dict, shapes_b: dict,
                                label: str, capacities) -> list:
    """Violation if the two capacity lowerings move different cross-device
    tensor multisets."""
    if shapes_a == shapes_b:
        return []
    def fmt(d):
        return {f"{k}:{s}": n for (k, s), n in sorted(d.items())}
    return [{
        "check": "capacity", "label": label,
        "message": (f"[{label}] collective traffic depends on "
                    f"tuple_capacity: {capacities[0]} -> {fmt(shapes_a)} vs "
                    f"{capacities[1]} -> {fmt(shapes_b)} — the log must stay "
                    "device-local (tuple-volume-independent queries)."),
    }]


def check_donation(hlo: str, min_aliases: int, label: str) -> list:
    got = io_alias_pairs(hlo)
    if got >= min_aliases:
        return []
    return [{
        "check": "donation", "label": label, "aliases": got,
        "message": (f"[{label}] donated StoreState produced only {got} "
                    f"input/output aliases (contract: >= {min_aliases}) — "
                    "XLA is making defensive copies; sustained ingest "
                    "double-allocates the ring."),
    }]


def run_hlo_contract(repo_root: Optional[str] = None,
                     cfg: Optional[AeriallintConfig] = None) -> dict:
    """Verify the contract on every configured mesh shape; returns the
    machine-readable report."""
    cfg = cfg or load_config(repo_root)
    runs = []
    violations = []
    if jax.device_count() < 4:  # pragma: no cover - CI forces 4 devices
        return {"tool": "aeriallint.hlo_contract", "runs": [],
                "violations": [{"check": "devices", "message":
                                f"device_count={jax.device_count()} < 4"}],
                "ok": False}
    for shape in cfg.retrace_mesh_shapes:
        label = "mesh" + str(tuple(int(x) for x in shape))
        per_cap = {}
        for capacity in _CAPACITIES:
            store_cfg = canonical_config(tuple_capacity=capacity)
            mesh = mesh_for(shape, store_cfg.n_edges)
            per_cap[capacity] = lower_entry_points(store_cfg, mesh)
        base = per_cap[_CAPACITIES[0]]

        v = []
        v += check_collective_contract(
            base["insert"], set(cfg.insert_collectives), f"{label}/insert",
            exact_counts={"all-gather": 1})
        v += check_collective_contract(
            base["ingest"], set(cfg.insert_collectives), f"{label}/ingest",
            exact_counts={"all-gather": _N_ROUNDS})
        v += check_collective_contract(
            base["query"], set(cfg.query_collectives), f"{label}/query")
        for name in ("insert", "ingest", "query"):
            v += check_capacity_independence(
                collective_shapes(per_cap[_CAPACITIES[0]][name]),
                collective_shapes(per_cap[_CAPACITIES[1]][name]),
                f"{label}/{name}", _CAPACITIES)
        v += check_donation(base["ingest"], cfg.min_donated_aliases,
                            f"{label}/ingest")

        violations += v
        runs.append({
            "mesh": label, "capacities": list(_CAPACITIES),
            "collectives": {
                name: {f"{k}:{s}": n
                       for (k, s), n in
                       sorted(collective_shapes(base[name]).items())}
                for name in ("insert", "ingest", "query")},
            "ingest_io_aliases": io_alias_pairs(base["ingest"]),
            "violations": len(v),
        })
    return {
        "tool": "aeriallint.hlo_contract",
        "contract": {"insert": sorted(cfg.insert_collectives),
                     "query": sorted(cfg.query_collectives),
                     "min_donated_aliases": cfg.min_donated_aliases},
        "runs": runs,
        "violations": violations,
        "ok": not violations,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.hlo_contract",
        description="aeriallint layer 3: HLO collective-contract verifier.")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable report")
    ap.add_argument("-o", "--output", default=None,
                    help="also write the JSON report to this file")
    ap.add_argument("--root", default=None, help="repo root override")
    args = ap.parse_args(argv)

    report = run_hlo_contract(args.root)
    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)
    if args.json:
        json.dump(report, sys.stdout, indent=2)
        print()
    else:
        for v in report["violations"]:
            print(v["message"])
        print(f"aeriallint.hlo_contract: {len(report['runs'])} mesh(es), "
              f"{len(report['violations'])} violation(s).")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
