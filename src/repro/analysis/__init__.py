"""``repro.analysis`` — repo-invariant static analysis (aeriallint).

Nine PRs of architecture contracts live in ROADMAP prose and scattered
tests; this package turns the machine-checkable subset into three enforced
layers, each with a ``--json`` CLI and a CI gate:

  1. **AST lint** (``rules`` + ``lint``): repo-specific rules over ``src/``,
     ``benchmarks/``, ``examples/`` — layering (R1), deprecated-shim call
     sites (R2), determinism / seeded-randomness (R3, the PR-9 bitwise-replay
     contract), host-sync hygiene inside jitted bodies (R4, the PR-8 lazy
     drop-watch rule generalized), traced-value Python branching (R5), and
     dead imports (R6). Run ``python -m repro.analysis.lint --json``.
  2. **jit-retrace budget** (``retrace``): the canonical facade workload
     (insert / ingest_rounds / query per AggSpec / fail / recover / repair,
     on the ``(4,)`` and ``(2, 2)`` meshes) under a compilation-counting
     harness; every jitted entry point must compile exactly its budgeted
     count and re-running the workload must compile nothing — catching
     weak-hash config dataclasses and shape-unstable call sites that
     silently 10x latency. Run ``python -m repro.analysis.retrace --json``.
  3. **HLO collective contract** (``hlo_contract``): lowers the federated
     insert / ingest / query paths on both mesh shapes and statically
     asserts the compiled HLO contains only the contracted collectives,
     that cross-device collective byte counts are independent of
     ``tuple_capacity``, and that ``ingest_rounds``' donated state produces
     real input/output aliases (no defensive copies). Run
     ``python -m repro.analysis.hlo_contract --json``.

Rules, allowlists, and budgets are data, not code: they live in
``pyproject.toml`` under ``[tool.aeriallint]`` (see ``config``). Every
allowlist entry and every ``# aeriallint: disable=Rn`` escape hatch must
carry a reason string — reasonless suppressions are themselves findings.

Layering: this package sits OUTSIDE the runtime stack (it imports the
runtime only to lower/trace it); nothing under ``repro`` may import it.
"""

from repro.analysis.config import AeriallintConfig, load_config
from repro.analysis.rules import Finding, lint_source

__all__ = ["AeriallintConfig", "Finding", "lint_source", "load_config"]
