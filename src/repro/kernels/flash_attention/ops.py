"""Jit'd wrapper: model-layout (B, S, H, dh) GQA attention on the Pallas
flash kernel (interpret on CPU, native on TPU)."""

from __future__ import annotations

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel


def flash_attention_pallas(q, k, v, *, causal: bool = True, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """Drop-in for models.attention.flash_attention (same layout/semantics)."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, k.shape[1], dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, v.shape[1], dh)
    o = flash_attention_kernel(qf, kf, vf, group=g, causal=causal,
                               q_offset=q_offset, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return o.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
