"""Pallas TPU kernel: FlashAttention-2-style fused attention with GQA.

Grid (N_q_heads_flat, Sq/bq, Skv/bk) — kv innermost. Per (head, q-block):
running max / sum / accumulator live in VMEM scratch across kv steps; the
output tile is written once on the last kv step (classic online softmax).
GQA is handled by the index map: q-head n reads kv-head n // group.

Tiling: bq x d and bk x d tiles in VMEM; the bq x bk score tile never leaves
VMEM — the O(S^2) matrix never touches HBM, which is the entire point.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_k: int, causal: bool, q_offset: int,
            scale: float, n_kv_steps: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                  # (bq, d)
    k = k_ref[0]                                  # (bk, d)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                        # (bq, bk) fp32
    corr = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_kv_steps - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group", "causal", "q_offset",
                                             "block_q", "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, group: int = 1, causal: bool = True,
                           q_offset: int = 0, block_q: int = 128,
                           block_k: int = 128, interpret: bool = True):
    """q: (N, Sq, d) with N = B*H_q; k/v: (N // group, Skv, d)."""
    n, sq, d = q.shape
    skv = k.shape[1]
    if sq % block_q or skv % block_k:
        raise ValueError(f"Sq={sq} % {block_q} or Skv={skv} % {block_k} != 0")
    n_kv = skv // block_k
    grid = (n, sq // block_q, n_kv)
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, causal=causal,
        q_offset=q_offset, scale=d ** -0.5, n_kv_steps=n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, qi, ki: (h // group, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, qi, ki: (h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((n, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
