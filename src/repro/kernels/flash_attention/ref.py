"""Oracle for the flash attention kernel: naive O(S^2) attention
(repro.models.attention.naive_attention re-exported for the kernel tests)."""
from repro.models.attention import naive_attention

__all__ = ["naive_attention"]
