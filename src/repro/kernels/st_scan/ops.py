"""Jit'd wrapper for the st_scan Pallas kernel.

Accepts the datastore's row-major layout and QueryPred struct, performs the
TPU-friendly column-major relayout + padding, and invokes the kernel.
``interpret=None`` (the default) auto-selects: compiled execution on TPU,
interpret mode elsewhere (CPU tests / this container).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.st_scan.st_scan import st_scan_kernel


def pack_pred(pred):
    """QueryPred -> (Q, 8) float32 + (Q, 8) int32 arrays for the kernel."""
    zf = jnp.zeros_like(pred.lat0)
    pred_f = jnp.stack([pred.lat0, pred.lat1, pred.lon0, pred.lon1,
                        pred.t0, pred.t1, zf, zf], axis=-1).astype(jnp.float32)
    zi = jnp.zeros_like(pred.sid_hi)
    pred_i = jnp.stack([pred.sid_hi, pred.sid_lo,
                        pred.has_spatial.astype(jnp.int32),
                        pred.has_temporal.astype(jnp.int32),
                        pred.has_sid.astype(jnp.int32),
                        pred.is_and.astype(jnp.int32), zi, zi], axis=-1)
    return pred_f, pred_i.astype(jnp.int32)


@partial(jax.jit, static_argnames=("block_c", "interpret", "channel"))
def st_scan(tup_f, tup_sid, tup_count, pred, sublists, sublist_len,
            block_c: int = 512, interpret: Optional[bool] = None,
            channel: int = 0):
    """Drop-in replacement for ref.st_scan_ref backed by the Pallas kernel.

    ``tup_count`` is the monotonic total-written counter; the valid window is
    ``min(count, C)`` (ring-buffer retention). The unpadded C is forwarded to
    the kernel as ``valid_c`` so its per-lane bound never admits the lanes
    this wrapper pads on. ``channel`` (static) selects the sensor channel to
    aggregate — value column ``3 + channel`` of the row-major log.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    e, c, w = tup_f.shape
    if not 0 <= channel < w - 3:
        raise ValueError(
            f"channel={channel} is not a valid sensor channel: the tuple log "
            f"holds {w - 3} channels (value columns 3..{w - 1}; negative "
            "channels would alias the t/lat/lon metadata columns).")
    pad_c = (-c) % block_c
    tupf_t = jnp.swapaxes(tup_f, 1, 2)           # (E, W, C): tuples on lanes
    sid_t = jnp.swapaxes(tup_sid, 1, 2)          # (E, 2, C)
    if pad_c:
        tupf_t = jnp.pad(tupf_t, ((0, 0), (0, 0), (0, pad_c)))
        sid_t = jnp.pad(sid_t, ((0, 0), (0, 0), (0, pad_c)), constant_values=-1)
    # Pad the OR-list length to a lane multiple.
    l = sublists.shape[2]
    pad_l = (-l) % 128
    if pad_l:
        sublists = jnp.pad(sublists, ((0, 0), (0, 0), (0, pad_l), (0, 0)),
                           constant_values=-(1 << 30))
    pred_f, pred_i = pack_pred(pred)
    return st_scan_kernel(tupf_t, sid_t, tup_count[:, None], pred_f, pred_i,
                          sublists, sublist_len, block_c=block_c,
                          interpret=interpret, valid_c=c,
                          value_col=3 + channel)
