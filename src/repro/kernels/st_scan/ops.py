"""Jit'd wrapper for the st_scan Pallas kernel.

Accepts the datastore's NATIVE column-major layout (``(E, 3+V, C)`` tuple
log, ``(E, 2, C)`` shard ids) and the QueryPred struct. The hot path
performs **no relayout**: the only data movement before the kernel is
constant padding — the tuple axis to a ``block_c`` multiple (a no-op for
lane-aligned store capacities), the query axis to a ``block_q`` multiple
(padding queries carry ``sublist_len == 0`` so they match nothing and are
sliced off the outputs), and the OR-list axis to a lane multiple.
``interpret=None`` (the default) auto-selects: compiled execution on TPU,
interpret mode elsewhere (CPU tests / this container).
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.st_scan.ref import check_channels
from repro.kernels.st_scan.st_scan import st_scan_kernel


def pack_pred(pred):
    """QueryPred -> (Q, 8) float32 + (Q, 8) int32 arrays for the kernel."""
    zf = jnp.zeros_like(pred.lat0)
    pred_f = jnp.stack([pred.lat0, pred.lat1, pred.lon0, pred.lon1,
                        pred.t0, pred.t1, zf, zf], axis=-1).astype(jnp.float32)
    zi = jnp.zeros_like(pred.sid_hi)
    pred_i = jnp.stack([pred.sid_hi, pred.sid_lo,
                        pred.has_spatial.astype(jnp.int32),
                        pred.has_temporal.astype(jnp.int32),
                        pred.has_sid.astype(jnp.int32),
                        pred.is_and.astype(jnp.int32), zi, zi], axis=-1)
    return pred_f, pred_i.astype(jnp.int32)


@partial(jax.jit, static_argnames=("block_c", "block_q", "interpret",
                                   "channels", "valid_c"))
def st_scan(tup_f, tup_sid, tup_count, pred, sublists, sublist_len,
            block_c: int = 512, block_q: int = 8,
            interpret: Optional[bool] = None,
            channels: Tuple[int, ...] = (0,),
            valid_c: Optional[int] = None):
    """Drop-in replacement for ref.st_scan_ref backed by the Pallas kernel.

    ``tup_f``/``tup_sid`` are column-major ``(E, 3+V, C)`` / ``(E, 2, C)``
    (the native StoreState layout — nothing is transposed here).
    ``tup_count`` is the monotonic total-written counter; the valid window is
    ``min(count, valid_c)`` where ``valid_c`` is the logical ring capacity
    (None = C) — forwarded to the kernel so neither store lane-padding nor
    this wrapper's block padding is ever admitted. ``channels`` (static)
    selects the sensor channels to aggregate — value rows ``3 + channel`` of
    the log, all fused into one sweep.

    Returns (count, vsum, vmin, vmax): count (Q, E) int32; vsum/vmin/vmax
    (Q, K, E) float32 with K = len(channels).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    e, w, c = tup_f.shape
    value_cols = check_channels(channels, w)
    if valid_c is None:
        valid_c = c
    block_c = min(block_c, max(c, 1))
    pad_c = (-c) % block_c
    if pad_c:
        tup_f = jnp.pad(tup_f, ((0, 0), (0, 0), (0, pad_c)))
        tup_sid = jnp.pad(tup_sid, ((0, 0), (0, 0), (0, pad_c)),
                          constant_values=-1)
    # Pad the query batch to a tile multiple: padding queries are inert
    # (sublist_len == 0 selects no edge) and sliced off below. block_q is
    # NOT shrunk for small batches — a lone query runs as a degenerate
    # block_q-wide tile (same HBM tuple traffic, one compiled variant).
    q = pred.lat0.shape[0]
    pad_q = (-q) % block_q
    pred_f, pred_i = pack_pred(pred)
    if pad_q:
        pred_f = jnp.pad(pred_f, ((0, pad_q), (0, 0)))
        pred_i = jnp.pad(pred_i, ((0, pad_q), (0, 0)))
        sublists = jnp.pad(sublists, ((0, pad_q), (0, 0), (0, 0), (0, 0)),
                           constant_values=-(1 << 30))
        sublist_len = jnp.pad(sublist_len, ((0, pad_q), (0, 0)))
    # Pad the OR-list length to a lane multiple.
    l = sublists.shape[2]
    pad_l = (-l) % 128
    if pad_l:
        sublists = jnp.pad(sublists, ((0, 0), (0, 0), (0, pad_l), (0, 0)),
                           constant_values=-(1 << 30))
    count, vsum, vmin, vmax = st_scan_kernel(
        tup_f, tup_sid, tup_count[:, None], pred_f, pred_i, sublists,
        sublist_len, block_c=block_c, block_q=block_q, interpret=interpret,
        valid_c=min(valid_c, c), value_cols=value_cols)
    if pad_q:
        count, vsum, vmin, vmax = (count[:q], vsum[:q], vmin[:q], vmax[:q])
    return count, vsum, vmin, vmax
