"""Pure-jnp oracle for the spatio-temporal predicate scan (st_scan).

Semantics (the per-edge "InfluxDB role", paper §3.5.2): for every
(query q, edge e) pair, scan all edge-local tuples and aggregate those that
satisfy the query's spatio-temporal/sid predicate AND belong to a shard in
the sub-query's shard OR-list.

``sublist_len[q, e]`` semantics:
    > 0  — OR-list filter with that many valid (hi, lo) entries,
    = 0  — edge not selected: contributes nothing,
    < 0  — scan-all sentinel (broadcast baseline: no shard scoping).
"""

from __future__ import annotations

import jax.numpy as jnp


def tuple_pred_match(tup_f, tup_sid, pred):
    """(Q, E, C) bool — tuple-level predicate evaluation (no shard list)."""
    t, lat, lon = tup_f[..., 0], tup_f[..., 1], tup_f[..., 2]

    def bc(x):
        return x[:, None, None]

    sp = (bc(pred.lat0) <= lat) & (lat <= bc(pred.lat1)) & \
         (bc(pred.lon0) <= lon) & (lon <= bc(pred.lon1))
    tp = (bc(pred.t0) <= t) & (t <= bc(pred.t1))
    ip = (tup_sid[..., 0] == bc(pred.sid_hi)) & (tup_sid[..., 1] == bc(pred.sid_lo))
    hs, ht, hi = bc(pred.has_spatial), bc(pred.has_temporal), bc(pred.has_sid)
    m_and = (sp | ~hs) & (tp | ~ht) & (ip | ~hi)
    m_or = (sp & hs) | (tp & ht) | (ip & hi)
    return jnp.where(bc(pred.is_and), m_and, m_or)


def st_scan_ref(tup_f, tup_sid, tup_count, pred, sublists, sublist_len,
                channel: int = 0):
    """Oracle scan.

    Args:
      tup_f:       (E, C, 3+V) float32.
      tup_sid:     (E, C, 2) int32.
      tup_count:   (E,) int32 total tuples ever written (monotonic); the log
                   is a ring buffer, so slots < min(count, C) hold live data.
      pred:        QueryPred with (Q,) fields.
      sublists:    (Q, E, L, 2) int32 shard OR-lists.
      sublist_len: (Q, E) int32 (see module docstring).
      channel:     sensor channel to aggregate — value column
                   ``tup_f[..., 3 + channel]`` (static).

    Returns:
      (count, vsum, vmin, vmax) each (Q, E) — per-edge partial aggregates
      of the selected value column.
    """
    e, c, w = tup_f.shape
    q = sublists.shape[0]
    l = sublists.shape[2]
    if not 0 <= channel < w - 3:
        raise ValueError(
            f"channel={channel} is not a valid sensor channel: the tuple log "
            f"holds {w - 3} channels (value columns 3..{w - 1}; negative "
            "channels would alias the t/lat/lon metadata columns).")

    # Ring-buffer validity: every slot below min(count, capacity) is live
    # (once the ring wraps, all slots are — count keeps growing past C).
    n_valid = jnp.minimum(tup_count, c)
    alive_t = jnp.arange(c, dtype=jnp.int32)[None, :] < n_valid[:, None]     # (E, C)
    pm = tuple_pred_match(tup_f[None], tup_sid[None], pred)                  # (Q, E, C)

    # Shard OR-list membership: tuple sid against each list entry.
    k = jnp.arange(l, dtype=jnp.int32)
    entry_valid = k[None, None, :] < jnp.abs(sublist_len)[..., None]         # (Q, E, L)
    hit = (tup_sid[None, :, :, None, 0] == sublists[:, :, None, :, 0]) & \
          (tup_sid[None, :, :, None, 1] == sublists[:, :, None, :, 1])       # (Q, E, C, L)
    in_list = jnp.any(hit & entry_valid[:, :, None, :], axis=-1)             # (Q, E, C)

    scan_all = (sublist_len < 0)[..., None]                                  # (Q, E, 1)
    selected = (sublist_len != 0)[..., None]
    shard_ok = jnp.where(scan_all, True, in_list) & selected

    m = pm & shard_ok & alive_t[None]
    v0 = tup_f[None, ..., 3 + channel]
    count = jnp.sum(m, axis=-1).astype(jnp.int32)
    vsum = jnp.sum(jnp.where(m, v0, 0.0), axis=-1)
    vmin = jnp.min(jnp.where(m, v0, jnp.inf), axis=-1)
    vmax = jnp.max(jnp.where(m, v0, -jnp.inf), axis=-1)
    return count, vsum, vmin, vmax
