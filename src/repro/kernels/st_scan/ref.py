"""Pure-jnp oracle for the spatio-temporal predicate scan (st_scan).

Semantics (the per-edge "InfluxDB role", paper §3.5.2): for every
(query q, edge e) pair, scan all edge-local tuples and aggregate those that
satisfy the query's spatio-temporal/sid predicate AND belong to a shard in
the sub-query's shard OR-list.

Layout: the tuple log arrives **column-major** — ``(E, 3+V, C)`` with the
tuple axis last — matching the native ``StoreState`` layout (each field is a
contiguous (E, C) plane, so per-field slices here are views, not copies).
``C`` may be lane-padded above the logical ring capacity; ``valid_c`` names
the logical capacity so padding slots are never admitted.

``sublist_len[q, e]`` semantics:
    > 0  — OR-list filter with that many valid (hi, lo) entries,
    = 0  — edge not selected: contributes nothing,
    < 0  — scan-all sentinel (broadcast baseline: no shard scoping).

Multi-channel aggregation: ``channels`` is a static tuple of sensor channels;
the predicate mask is evaluated ONCE and all K channels' sum/min/max are
accumulated in the same sweep (the fused-aggregation contract the Pallas
kernel implements tile-wise).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def check_channels(channels, n_cols: int) -> Tuple[int, ...]:
    """Validate a static channel tuple against a ``3 + V``-row log; returns
    the value-row indices (``3 + channel``). Shared by both engines."""
    if isinstance(channels, int):
        channels = (channels,)
    channels = tuple(int(c) for c in channels)
    if not channels:
        raise ValueError("channels is empty: select at least one sensor "
                         "channel to aggregate.")
    if len(set(channels)) != len(channels):
        raise ValueError(
            f"channels={channels} contains duplicates: each channel is "
            "aggregated once per scan; deduplicate the request.")
    for ch in channels:
        if not 0 <= ch < n_cols - 3:
            raise ValueError(
                f"channel={ch} is not a valid sensor channel: the tuple log "
                f"holds {n_cols - 3} channels (value rows 3..{n_cols - 1}; "
                "negative channels would alias the t/lat/lon metadata rows).")
    return tuple(3 + ch for ch in channels)


def tuple_pred_match(tup_f, tup_sid, pred):
    """(Q, E, C) bool — tuple-level predicate evaluation (no shard list).

    ``tup_f``/``tup_sid`` are column-major ``(E, 3+V, C)`` / ``(E, 2, C)``.
    """
    t, lat, lon = tup_f[:, 0, :], tup_f[:, 1, :], tup_f[:, 2, :]   # (E, C)
    sid_hi, sid_lo = tup_sid[:, 0, :], tup_sid[:, 1, :]

    def bc(x):
        return x[:, None, None]

    sp = (bc(pred.lat0) <= lat) & (lat <= bc(pred.lat1)) & \
         (bc(pred.lon0) <= lon) & (lon <= bc(pred.lon1))
    tp = (bc(pred.t0) <= t) & (t <= bc(pred.t1))
    ip = (sid_hi == bc(pred.sid_hi)) & (sid_lo == bc(pred.sid_lo))
    hs, ht, hi = bc(pred.has_spatial), bc(pred.has_temporal), bc(pred.has_sid)
    m_and = (sp | ~hs) & (tp | ~ht) & (ip | ~hi)
    m_or = (sp & hs) | (tp & ht) | (ip & hi)
    return jnp.where(bc(pred.is_and), m_and, m_or)


def st_scan_ref(tup_f, tup_sid, tup_count, pred, sublists, sublist_len,
                channels: Tuple[int, ...] = (0,),
                valid_c: Optional[int] = None):
    """Oracle scan.

    Args:
      tup_f:       (E, 3+V, C) float32 column-major tuple log.
      tup_sid:     (E, 2, C) int32.
      tup_count:   (E,) int32 total tuples ever written (monotonic); the log
                   is a ring buffer, so slots < min(count, valid_c) hold live
                   data.
      pred:        QueryPred with (Q,) fields.
      sublists:    (Q, E, L, 2) int32 shard OR-lists.
      sublist_len: (Q, E) int32 (see module docstring).
      channels:    static tuple of sensor channels to aggregate — value rows
                   ``3 + channel`` of the column-major log.
      valid_c:     logical ring capacity. The stored C axis may be
                   lane-padded above it; slots >= valid_c are never live.
                   None = C (unpadded input).

    Returns:
      (count, vsum, vmin, vmax): ``count`` is (Q, E) int32; ``vsum``/
      ``vmin``/``vmax`` are (Q, K, E) float32 per-edge partial aggregates,
      one row per requested channel (K = len(channels)).
    """
    e, w, c = tup_f.shape
    q = sublists.shape[0]
    l = sublists.shape[2]
    value_rows = check_channels(channels, w)
    if valid_c is None:
        valid_c = c

    # Ring-buffer validity: every slot below min(count, logical capacity) is
    # live (once the ring wraps, all logical slots are — count keeps growing
    # past the cap); lane-padding slots in [valid_c, C) are never written.
    n_valid = jnp.minimum(tup_count, min(valid_c, c))
    alive_t = jnp.arange(c, dtype=jnp.int32)[None, :] < n_valid[:, None]     # (E, C)
    pm = tuple_pred_match(tup_f, tup_sid, pred)                              # (Q, E, C)

    # Shard OR-list membership: tuple sid against each list entry.
    sid_hi, sid_lo = tup_sid[:, 0, :], tup_sid[:, 1, :]                      # (E, C)
    k = jnp.arange(l, dtype=jnp.int32)
    entry_valid = k[None, None, :] < jnp.abs(sublist_len)[..., None]         # (Q, E, L)
    hit = (sid_hi[None, :, :, None] == sublists[:, :, None, :, 0]) & \
          (sid_lo[None, :, :, None] == sublists[:, :, None, :, 1])           # (Q, E, C, L)
    in_list = jnp.any(hit & entry_valid[:, :, None, :], axis=-1)             # (Q, E, C)

    scan_all = (sublist_len < 0)[..., None]                                  # (Q, E, 1)
    selected = (sublist_len != 0)[..., None]
    shard_ok = jnp.where(scan_all, True, in_list) & selected

    m = pm & shard_ok & alive_t[None]                                        # (Q, E, C)
    # Fused multi-channel aggregation: one mask, K channels per sweep.
    vals = jnp.stack([tup_f[:, row, :] for row in value_rows])               # (K, E, C)
    mk = m[:, None]                                                          # (Q, 1, E, C)
    count = jnp.sum(m, axis=-1).astype(jnp.int32)                            # (Q, E)
    vsum = jnp.sum(jnp.where(mk, vals[None], 0.0), axis=-1)                  # (Q, K, E)
    vmin = jnp.min(jnp.where(mk, vals[None], jnp.inf), axis=-1)
    vmax = jnp.max(jnp.where(mk, vals[None], -jnp.inf), axis=-1)
    return count, vsum, vmin, vmax
