"""Pallas TPU kernel: blocked spatio-temporal predicate scan + aggregation.

This is the per-edge query engine hot loop (the paper's InfluxDB role,
§3.5.2, Fig 5). For each (edge, query-tile) pair the kernel streams the
edge's tuple log through VMEM in ``block_c``-tuple tiles, evaluates the
spatio-temporal predicate and the shard-id OR-list membership of a whole
``block_q``-query tile entirely in vector registers, and accumulates
count/sum/min/max — for a static tuple of sensor channels at once — into
revisited output tiles.

TPU-native layout decisions (vs the paper's row-store in InfluxDB):
  * the tuple log is stored column-major (E, W, C) — NATIVELY, in
    ``StoreState`` itself — so the *tuple* axis is the lane dimension
    (128-aligned by ``init_store``'s capacity padding), giving unit-stride
    vector loads per field with no per-query relayout;
  * queries are tiled: the predicate is a (block_q, block_c) broadcast
    evaluation and the shard OR-list membership a (block_q, L, block_c)
    broadcast-compare, so each resident VMEM tuple tile answers block_q
    queries before the grid advances — HBM tuple traffic is
    ceil(Q/block_q)x the log instead of Qx;
  * aggregation is fused across channels: one predicate mask drives the
    count and every requested channel's sum/min/max accumulators
    (the marginal cost per extra channel is one VMEM row already resident
    in the tuple tile);
  * accumulators are (block_q, 1) / (block_q, K, 1) output tiles revisited
    across the c-grid (Pallas revisiting-output pattern), so no cross-block
    reduction pass.

Grid note: the grid is ``(E, Q // block_q, C // block_c)`` with the c axis
FASTEST — each (edge, query-tile) accumulator is completed over consecutive
grid steps before the grid moves on (the only ordering under which Pallas
revisited outputs are well-defined), and the tuple-tile index map depends
only on (e, c), so one fetch of the log serves the whole query tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tupf_ref, sidl_ref, cnt_ref, predf_ref, predi_ref, subl_ref,
            slen_ref, count_ref, vsum_ref, vmin_ref, vmax_ref, *, block_c: int,
            valid_c: int, value_cols: tuple):
    pc = pl.program_id(2)

    @pl.when(pc == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)
        vsum_ref[...] = jnp.zeros_like(vsum_ref)
        vmin_ref[...] = jnp.full_like(vmin_ref, jnp.inf)
        vmax_ref[...] = jnp.full_like(vmax_ref, -jnp.inf)

    t = tupf_ref[0, 0:1, :]      # (1, BC)
    lat = tupf_ref[0, 1:2, :]
    lon = tupf_ref[0, 2:3, :]
    sid_hi = sidl_ref[0, 0:1, :]
    sid_lo = sidl_ref[0, 1:2, :]

    # Ring-buffer validity: slots below min(count, valid_c) are live, where
    # valid_c is the LOGICAL ring capacity — a monotonic total-written count
    # above capacity must never admit lane-padding slots.
    n_valid = jnp.minimum(cnt_ref[0, 0], valid_c)
    base = pc * block_c
    idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_c), 1)
    alive = idx < n_valid        # (1, BC)

    pf = predf_ref[...]          # (BQ, 8) lat0, lat1, lon0, lon1, t0, t1, -, -
    pi = predi_ref[...]          # (BQ, 8) sid_hi, sid_lo, has_s, has_t, has_i, is_and
    sp = (pf[:, 0:1] <= lat) & (lat <= pf[:, 1:2]) & \
         (pf[:, 2:3] <= lon) & (lon <= pf[:, 3:4])            # (BQ, BC)
    tp = (pf[:, 4:5] <= t) & (t <= pf[:, 5:6])
    ip = (sid_hi == pi[:, 0:1]) & (sid_lo == pi[:, 1:2])
    hs, ht, hi = pi[:, 2:3] != 0, pi[:, 3:4] != 0, pi[:, 4:5] != 0
    m_and = (sp | ~hs) & (tp | ~ht) & (ip | ~hi)
    m_or = (sp & hs) | (tp & ht) | (ip & hi)
    pm = jnp.where(pi[:, 5:6] != 0, m_and, m_or)              # (BQ, BC)

    # Shard OR-list membership: (BQ, L, BC) broadcast compare.
    slen = slen_ref[...]                                      # (BQ, 1)
    l = subl_ref.shape[2]
    list_hi = subl_ref[:, 0, :, 0]                            # (BQ, L)
    list_lo = subl_ref[:, 0, :, 1]
    k = jax.lax.broadcasted_iota(jnp.int32, (1, l), 1)
    entry_ok = k < jnp.abs(slen)                              # (BQ, L)
    hit = (sid_hi[:, None, :] == list_hi[:, :, None]) & \
          (sid_lo[:, None, :] == list_lo[:, :, None]) & entry_ok[:, :, None]
    in_list = jnp.any(hit, axis=1)                            # (BQ, BC)
    shard_ok = jnp.where(slen < 0, True, in_list) & (slen != 0)

    m = pm & shard_ok & alive                                 # (BQ, BC)
    count_ref[...] += jnp.sum(m, axis=1, keepdims=True).astype(jnp.int32)
    # Fused multi-channel aggregation: the mask is computed once; every
    # requested channel's row is already resident in the VMEM tuple tile.
    for kk, col in enumerate(value_cols):
        v = tupf_ref[0, col:col + 1, :]                       # (1, BC)
        vsum_ref[:, kk] += jnp.sum(jnp.where(m, v, 0.0), axis=1, keepdims=True)
        vmin_ref[:, kk] = jnp.minimum(
            vmin_ref[:, kk],
            jnp.min(jnp.where(m, v, jnp.inf), axis=1, keepdims=True))
        vmax_ref[:, kk] = jnp.maximum(
            vmax_ref[:, kk],
            jnp.max(jnp.where(m, v, -jnp.inf), axis=1, keepdims=True))


def st_scan_kernel(tupf_t, sid_t, tup_count, pred_f, pred_i, sublists_t,
                   sublist_len, *, block_c: int = 512, block_q: int = 8,
                   interpret: "bool | None" = None,
                   valid_c: "int | None" = None,
                   value_cols: "tuple[int, ...]" = (3,)):
    """Invoke the Pallas scan.

    Args:
      tupf_t:      (E, W, C) float32 column-major tuple log (W >= 4).
      sid_t:       (E, 2, C) int32 shard ids.
      tup_count:   (E, 1) int32 — ring-buffer total-written counter; clamped
                   in-kernel to min(count, valid_c).
      pred_f:      (Q, 8) float32 packed predicate; Q % block_q == 0
                   (ops.py pads the query batch).
      pred_i:      (Q, 8) int32 packed predicate.
      sublists_t:  (Q, E, L, 2) int32 OR-lists.
      sublist_len: (Q, E) int32.
      block_q:     queries evaluated per resident tuple tile — the HBM
                   tuple-traffic divisor for batched queries.
      interpret:   None = auto (compiled on TPU, interpreted elsewhere).
      valid_c:     logical ring capacity (ops.py forwards the store's
                   un-lane-padded capacity so padding lanes are never
                   admitted); None = C.
      value_cols:  static rows of the column-major log to aggregate (the
                   selected sensor channels; 3 = v0). All are accumulated in
                   the same sweep.

    Returns (count, vsum, vmin, vmax): count (Q, E) int32; the rest
    (Q, K, E) float32 with K = len(value_cols).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    e, w, c = tupf_t.shape
    if valid_c is None:
        valid_c = c
    n_ch = len(value_cols)
    for col in value_cols:
        if not 3 <= col < w:
            raise ValueError(
                f"value_col={col} out of range: the column-major log has "
                f"rows 0..2 = (t, lat, lon) and value rows 3..{w - 1}.")
    q = pred_f.shape[0]
    l = sublists_t.shape[2]
    if c % block_c:
        raise ValueError(f"C={c} must be a multiple of block_c={block_c}")
    if q % block_q:
        raise ValueError(f"Q={q} must be a multiple of block_q={block_q} "
                         "(ops.py pads the query batch)")
    grid = (e, q // block_q, c // block_c)

    kernel = functools.partial(_kernel, block_c=block_c, valid_c=valid_c,
                               value_cols=tuple(value_cols))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w, block_c), lambda e_, q_, c_: (e_, 0, c_)),
            pl.BlockSpec((1, 2, block_c), lambda e_, q_, c_: (e_, 0, c_)),
            pl.BlockSpec((1, 1), lambda e_, q_, c_: (e_, 0)),
            pl.BlockSpec((block_q, 8), lambda e_, q_, c_: (q_, 0)),
            pl.BlockSpec((block_q, 8), lambda e_, q_, c_: (q_, 0)),
            pl.BlockSpec((block_q, 1, l, 2), lambda e_, q_, c_: (q_, e_, 0, 0)),
            pl.BlockSpec((block_q, 1), lambda e_, q_, c_: (q_, e_)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1), lambda e_, q_, c_: (q_, e_)),
            pl.BlockSpec((block_q, n_ch, 1), lambda e_, q_, c_: (q_, 0, e_)),
            pl.BlockSpec((block_q, n_ch, 1), lambda e_, q_, c_: (q_, 0, e_)),
            pl.BlockSpec((block_q, n_ch, 1), lambda e_, q_, c_: (q_, 0, e_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, e), jnp.int32),
            jax.ShapeDtypeStruct((q, n_ch, e), jnp.float32),
            jax.ShapeDtypeStruct((q, n_ch, e), jnp.float32),
            jax.ShapeDtypeStruct((q, n_ch, e), jnp.float32),
        ],
        interpret=interpret,
    )(tupf_t, sid_t, tup_count, pred_f, pred_i, sublists_t, sublist_len)
    return tuple(out)
