"""Pallas TPU kernel: blocked spatio-temporal predicate scan + aggregation.

This is the per-edge query engine hot loop (the paper's InfluxDB role,
§3.5.2, Fig 5). For each (edge, query) pair the kernel streams the edge's
tuple log through VMEM in ``block_c``-tuple tiles, evaluates the
spatio-temporal predicate and the shard-id OR-list membership entirely in
vector registers, and accumulates count/sum/min/max into the output tile.

TPU-native layout decisions (vs the paper's row-store in InfluxDB):
  * tuple log is stored column-major (E, W, C) so the *tuple* axis is the
    lane dimension (128-aligned), giving unit-stride vector loads per field;
  * shard OR-lists are (2, L) per (q, e) with L lanes — the membership test
    is a (L, block_c) broadcast-compare, i.e. the "OR clause" of Fig 5
    becomes one vectorized compare per list entry rather than a regex walk;
  * aggregation is a running (1, 1) accumulator revisited across the c-grid
    (Pallas revisiting-output pattern), so no cross-block reduction pass.

Grid: (E, Q, C // block_c) — c fastest, so each (e, q) accumulator is
complete before the grid moves on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tupf_ref, sidl_ref, cnt_ref, predf_ref, predi_ref, subl_ref,
            slen_ref, count_ref, vsum_ref, vmin_ref, vmax_ref, *, block_c: int,
            valid_c: int, value_col: int):
    pc = pl.program_id(2)

    @pl.when(pc == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)
        vsum_ref[...] = jnp.zeros_like(vsum_ref)
        vmin_ref[...] = jnp.full_like(vmin_ref, jnp.inf)
        vmax_ref[...] = jnp.full_like(vmax_ref, -jnp.inf)

    t = tupf_ref[0, 0:1, :]      # (1, BC)
    lat = tupf_ref[0, 1:2, :]
    lon = tupf_ref[0, 2:3, :]
    v0 = tupf_ref[0, value_col:value_col + 1, :]   # static channel selection
    sid_hi = sidl_ref[0, 0:1, :]
    sid_lo = sidl_ref[0, 1:2, :]

    # Ring-buffer validity: slots below min(count, valid_c) are live, where
    # valid_c is the UNPADDED log length — a monotonic total-written count
    # above capacity must never admit zero-padding lanes.
    n_valid = jnp.minimum(cnt_ref[0, 0], valid_c)
    base = pc * block_c
    idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_c), 1)
    alive = idx < n_valid

    pf = predf_ref[0]            # (8,) lat0, lat1, lon0, lon1, t0, t1, -, -
    pi = predi_ref[0]            # (8,) sid_hi, sid_lo, has_s, has_t, has_i, is_and
    sp = (pf[0] <= lat) & (lat <= pf[1]) & (pf[2] <= lon) & (lon <= pf[3])
    tp = (pf[4] <= t) & (t <= pf[5])
    ip = (sid_hi == pi[0]) & (sid_lo == pi[1])
    hs, ht, hi = pi[2] != 0, pi[3] != 0, pi[4] != 0
    m_and = (sp | ~hs) & (tp | ~ht) & (ip | ~hi)
    m_or = (sp & hs) | (tp & ht) | (ip & hi)
    pm = jnp.where(pi[5] != 0, m_and, m_or)

    # Shard OR-list membership: (L, BC) broadcast compare.
    slen = slen_ref[0, 0]
    l = subl_ref.shape[2]
    list_hi = subl_ref[0, 0, :, 0:1]   # (L, 1)
    list_lo = subl_ref[0, 0, :, 1:2]
    k = jax.lax.broadcasted_iota(jnp.int32, (l, 1), 0)
    entry_ok = k < jnp.abs(slen)
    hit = (sid_hi == list_hi) & (sid_lo == list_lo) & entry_ok   # (L, BC)
    in_list = jnp.any(hit, axis=0, keepdims=True)                # (1, BC)
    shard_ok = jnp.where(slen < 0, True, in_list) & (slen != 0)

    m = pm & shard_ok & alive
    count_ref[0, 0] += jnp.sum(m).astype(jnp.int32)
    vsum_ref[0, 0] += jnp.sum(jnp.where(m, v0, 0.0))
    vmin_ref[0, 0] = jnp.minimum(vmin_ref[0, 0], jnp.min(jnp.where(m, v0, jnp.inf)))
    vmax_ref[0, 0] = jnp.maximum(vmax_ref[0, 0], jnp.max(jnp.where(m, v0, -jnp.inf)))


def st_scan_kernel(tupf_t, sid_t, tup_count, pred_f, pred_i, sublists_t,
                   sublist_len, *, block_c: int = 512,
                   interpret: "bool | None" = None,
                   valid_c: "int | None" = None, value_col: int = 3):
    """Invoke the Pallas scan.

    Args:
      tupf_t:      (E, W, C) float32 column-major tuple log (W >= 4).
      sid_t:       (E, 2, C) int32 shard ids.
      tup_count:   (E, 1) int32 — ring-buffer total-written counter; clamped
                   in-kernel to min(count, valid_c).
      pred_f:      (Q, 8) float32 packed predicate.
      pred_i:      (Q, 8) int32 packed predicate.
      sublists_t:  (Q, E, L, 2) int32 OR-lists.
      sublist_len: (Q, E) int32.
      interpret:   None = auto (compiled on TPU, interpreted elsewhere).
      valid_c:     unpadded log length (ops.py pads C to a block multiple and
                   passes the original here so padding lanes are never
                   admitted); None = C.
      value_col:   static row of the column-major log to aggregate (the
                   selected sensor channel; 3 = v0).

    Returns (count, vsum, vmin, vmax), each (Q, E).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    e, w, c = tupf_t.shape
    if valid_c is None:
        valid_c = c
    if not 3 <= value_col < w:
        raise ValueError(
            f"value_col={value_col} out of range: the column-major log has "
            f"rows 0..2 = (t, lat, lon) and value rows 3..{w - 1}.")
    q = pred_f.shape[0]
    l = sublists_t.shape[2]
    if c % block_c:
        raise ValueError(f"C={c} must be a multiple of block_c={block_c}")
    grid = (e, q, c // block_c)

    kernel = functools.partial(_kernel, block_c=block_c, valid_c=valid_c,
                               value_col=value_col)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w, block_c), lambda e_, q_, c_: (e_, 0, c_)),
            pl.BlockSpec((1, 2, block_c), lambda e_, q_, c_: (e_, 0, c_)),
            pl.BlockSpec((1, 1), lambda e_, q_, c_: (e_, 0)),
            pl.BlockSpec((1, 8), lambda e_, q_, c_: (q_, 0)),
            pl.BlockSpec((1, 8), lambda e_, q_, c_: (q_, 0)),
            pl.BlockSpec((1, 1, l, 2), lambda e_, q_, c_: (q_, e_, 0, 0)),
            pl.BlockSpec((1, 1), lambda e_, q_, c_: (q_, e_)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda e_, q_, c_: (q_, e_)),
            pl.BlockSpec((1, 1), lambda e_, q_, c_: (q_, e_)),
            pl.BlockSpec((1, 1), lambda e_, q_, c_: (q_, e_)),
            pl.BlockSpec((1, 1), lambda e_, q_, c_: (q_, e_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, e), jnp.int32),
            jax.ShapeDtypeStruct((q, e), jnp.float32),
            jax.ShapeDtypeStruct((q, e), jnp.float32),
            jax.ShapeDtypeStruct((q, e), jnp.float32),
        ],
        interpret=interpret,
    )(tupf_t, sid_t, tup_count, pred_f, pred_i, sublists_t, sublist_len)
    return tuple(out)
