"""Jit'd wrapper for voronoi_assign (interpret on CPU, native on TPU)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.voronoi_assign.voronoi_assign import voronoi_assign


def hash_spatial_kernel(lat: jnp.ndarray, lon: jnp.ndarray,
                        sites: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Kernel-backed H_s: (lat, lon) -> edge index."""
    pts = jnp.stack([lat.reshape(-1), lon.reshape(-1)], axis=-1)
    return voronoi_assign(pts, sites, interpret=interpret).reshape(lat.shape)
