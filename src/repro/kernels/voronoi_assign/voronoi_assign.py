"""Pallas TPU kernel: Voronoi point-location as MXU nearest-site search.

H_s point-location (paper §3.4.1) = nearest site over E edges. The kernel
computes the distance matrix for a block of points via the matmul expansion
``||p-s||^2 = ||p||^2 - 2 p.s + ||s||^2`` (the ||p||^2 term is argmin-
invariant and dropped), so the inner loop is a (BP, 2) x (2, E) dot_general on
the MXU followed by a lane-wise argmin. Points are stored coordinate-major
(2, N) so point blocks load with unit stride on the lane axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pts_ref, sites_ref, snorm_ref, out_ref):
    pts = pts_ref[...]                 # (2, BP)
    sites = sites_ref[...]             # (2, E)
    snorm = snorm_ref[...]             # (1, E)
    # dist (BP, E) = snorm - 2 * pts^T sites  (MXU contraction over coord dim)
    cross = jax.lax.dot_general(pts, sites, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (BP, E)
    dist = snorm - 2.0 * cross
    out_ref[...] = jnp.argmin(dist, axis=1).astype(jnp.int32)[None, :]


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def voronoi_assign(points: jnp.ndarray, sites: jnp.ndarray,
                   block_p: int = 1024, interpret: bool = True) -> jnp.ndarray:
    """(N, 2) float points x (E, 2) sites -> (N,) int32 nearest site."""
    n = points.shape[0]
    e = sites.shape[0]
    pad = (-n) % block_p
    # Center on the site centroid: argmin-invariant, but essential for fp32
    # accuracy with raw geographic coordinates (see core/voronoi.py).
    c = jnp.mean(sites.astype(jnp.float32), axis=0)
    pts_t = jnp.pad(points.astype(jnp.float32) - c, ((0, pad), (0, 0))).T  # (2, N+pad)
    sites_t = (sites.astype(jnp.float32) - c).T                            # (2, E)
    snorm = jnp.sum(sites_t * sites_t, axis=0, keepdims=True)          # (1, E)
    rows = pts_t.shape[1] // block_p
    out = pl.pallas_call(
        _kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((2, block_p), lambda r: (0, r)),
                  pl.BlockSpec((2, e), lambda r: (0, 0)),
                  pl.BlockSpec((1, e), lambda r: (0, 0))],
        out_specs=pl.BlockSpec((1, block_p), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block_p), jnp.int32),
        interpret=interpret,
    )(pts_t, sites_t, snorm)
    return out.reshape(-1)[:n]
