"""Oracle for voronoi_assign: brute-force nearest site in float64 numpy."""

from __future__ import annotations

import numpy as np


def voronoi_assign_ref(points: np.ndarray, sites: np.ndarray) -> np.ndarray:
    """(N, 2) points x (E, 2) sites -> (N,) int32 nearest-site (ties: lowest id)."""
    p = np.asarray(points, np.float64)
    s = np.asarray(sites, np.float64)
    d = ((p[:, None, :] - s[None, :, :]) ** 2).sum(-1)
    return np.argmin(d, axis=1).astype(np.int32)
