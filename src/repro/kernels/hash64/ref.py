"""Oracle for the lane-split xxHash64 kernel: pure-python-int xxHash64
(8-byte input path), bit-exact per the reference implementation."""

from __future__ import annotations

import numpy as np

M64 = (1 << 64) - 1
P1 = 0x9E3779B185EBCA87
P2 = 0xC2B2AE3D27D4EB4F
P3 = 0x165667B19E3779F9
P4 = 0x85EBCA77C2B2AE63
P5 = 0x27D4EB2F165667C5


def _rotl(x: int, n: int) -> int:
    return ((x << n) | (x >> (64 - n))) & M64


def xxh64_u64_py(key: int, seed: int = 0) -> int:
    """xxHash64 of a single little-endian 64-bit word."""
    h = (seed + P5 + 8) & M64
    k1 = (key * P2) & M64
    k1 = _rotl(k1, 31)
    k1 = (k1 * P1) & M64
    h ^= k1
    h = (_rotl(h, 27) * P1 + P4) & M64
    h ^= h >> 33
    h = (h * P2) & M64
    h ^= h >> 29
    h = (h * P3) & M64
    h ^= h >> 32
    return h


def xxh64_batch_py(hi: np.ndarray, lo: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Vector oracle over (hi, lo) uint32 limb arrays."""
    out_hi = np.empty_like(hi, dtype=np.uint32)
    out_lo = np.empty_like(lo, dtype=np.uint32)
    for i, (h32, l32) in enumerate(zip(hi.reshape(-1).tolist(), lo.reshape(-1).tolist())):
        h = xxh64_u64_py(((h32 & 0xFFFFFFFF) << 32) | (l32 & 0xFFFFFFFF))
        out_hi.reshape(-1)[i] = h >> 32
        out_lo.reshape(-1)[i] = h & 0xFFFFFFFF
    return out_hi, out_lo
