"""Pallas TPU kernel: vectorized lane-split xxHash64.

TPU VPU lanes are 32-bit, so 64-bit hashing runs as uint32 limb arithmetic
(16-bit digit splits for the 32x32->64 partial products). The kernel is pure
VPU work — it exists because placement hashing sits on the insertion critical
path for every shard of every drone (paper §3.4.1) and fuses the
hash + avalanche + modulo pipeline in registers with no HBM round-trips.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashing


def _kernel(hi_ref, lo_ref, out_hi_ref, out_lo_ref):
    h = hashing.xxh64_u64((hi_ref[...], lo_ref[...]))
    out_hi_ref[...] = h[0]
    out_lo_ref[...] = h[1]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xxh64(hi: jnp.ndarray, lo: jnp.ndarray, block: int = 1024,
          interpret: bool = True):
    """Batched xxHash64 over (hi, lo) uint32 limb arrays of shape (N,)."""
    n = hi.shape[0]
    pad = (-n) % block
    hi_p = jnp.pad(hi.astype(jnp.uint32), (0, pad)).reshape(-1, block)
    lo_p = jnp.pad(lo.astype(jnp.uint32), (0, pad)).reshape(-1, block)
    rows = hi_p.shape[0]
    out = pl.pallas_call(
        _kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, block), lambda r: (r, 0)),
                  pl.BlockSpec((1, block), lambda r: (r, 0))],
        out_specs=[pl.BlockSpec((1, block), lambda r: (r, 0)),
                   pl.BlockSpec((1, block), lambda r: (r, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, block), jnp.uint32),
                   jax.ShapeDtypeStruct((rows, block), jnp.uint32)],
        interpret=interpret,
    )(hi_p, lo_p)
    return out[0].reshape(-1)[:n], out[1].reshape(-1)[:n]
