"""Jit'd wrapper for the hash64 kernel (interpret on CPU, native on TPU)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.hash64.hash64 import xxh64


def xxh64_mod(hi: jnp.ndarray, lo: jnp.ndarray, n_edges: int,
              interpret: bool = True) -> jnp.ndarray:
    """H_i-style placement hash: xxh64(key) mod n_edges, int32."""
    out_hi, out_lo = xxh64(hi, lo, interpret=interpret)
    from repro.core.hashing import mod_u64
    return mod_u64((out_hi, out_lo), n_edges)
