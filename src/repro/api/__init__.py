"""Public AerialDB API: the ``AerialDB`` session facade + ``Query`` builder.

This package is the **stable surface** of the reproduction — what examples,
benchmarks, and downstream workloads program against:

    from repro.api import AerialDB, Query, AggSpec

    db = AerialDB.open(n_edges=8)                # or .open(cfg, mesh=...)
    db.ingest_rounds(payloads, metas)
    res, info = db.query(
        Query().bbox(12.9, 13.0, 77.5, 77.6).time(0, 600).agg("mean",
                                                              channel=2))

Layering contract (facade vs local bodies)
------------------------------------------
``repro.api`` sits strictly ABOVE the runtimes and owns only *session*
concerns: config + state + alive-mask + PRNG-key custody, query compilation
(``Query`` -> ``QueryPred`` + static ``AggSpec``), and the dispatch choice
between the single-device jit path and the shard_map federated path. All
datastore *semantics* live below, in the shard-local bodies
(``core.datastore.insert_local`` / ``query_local``) that both runtimes share
— the facade never reimplements placement, indexing, planning, or scanning,
so the differential harness (``tests/test_federation.py``) proving the two
runtimes bit-identical covers every facade operation too. Nothing in
``core``/``distributed``/``kernels`` imports this package; the deprecated
free functions (``insert_step``/``query_step``) remain as thin shims over
the same bodies.
"""

from repro.api.query import Query
from repro.api.session import AerialDB
from repro.core.datastore import (AGG_OPS, AggSpec, LatestResult, QueryInfo,
                                  QueryResult, StoreConfig, make_pred)
from repro.core.index import QueryPred
from repro.core.placement import ShardMeta

__all__ = ["AerialDB", "Query", "AggSpec", "AGG_OPS", "QueryPred",
           "QueryResult", "QueryInfo", "LatestResult", "ShardMeta",
           "StoreConfig", "make_pred"]
