"""Composable query builder: fluent clauses -> ``(QueryPred, AggSpec)``.

The engine (``core.datastore.query_local`` + the st_scan engines) evaluates a
*batch* of predicates, each a single AND or OR over at most one spatial bbox,
one temporal range, and one shard-id clause (paper Fig 6, §3.5.1). ``Query``
is the ergonomic, *validating* front door to that shape:

    Query().bbox(12.9, 13.0, 77.5, 77.6).time(0, 600).agg("mean", channel=2)
    Query().time(0, 600).agg("mean", channels=(0, 2))   # K channels, ONE scan
    Query().bbox(...) | Query().time(...)          # OR combinator
    Query().shard(3, 1) & Query().time(0, 300)     # AND combinator
    Query.batch(q1, q2, q3)                        # one batched QueryPred

Builders are immutable — every method returns a new ``Query`` — so partial
queries can be shared and extended without aliasing. ``build()`` compiles to
the engine's ``QueryPred`` (q=1) plus the static ``AggSpec``; ``Query.batch``
stacks several built queries into one (Q,) predicate batch (they must share
one AggSpec, which is compiled into the scan).

Validation happens eagerly, at build time, with concrete Python scalars:
inverted ranges (``lat1 < lat0``, ``t1 < t0``) raise immediately instead of
silently matching nothing, and clause combinations the engine cannot express
((A AND B) OR C) are rejected with an explanation rather than mis-compiled.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.datastore import AGG_OPS, AggSpec, make_pred
from repro.core.index import QueryPred

__all__ = ["Query"]

_CLAUSES = ("spatial", "temporal", "sid")


def _scalar(name: str, x) -> float:
    try:
        return float(x)
    except (TypeError, ValueError):
        raise TypeError(
            f"{name}={x!r} is not a scalar: the Query builder takes concrete "
            "per-query bounds (batch many queries with Query.batch, or build "
            "array workloads directly with core.datastore.make_pred).")


@dataclasses.dataclass(frozen=True)
class Query:
    """One spatio-temporal/id range-aggregation query, under construction.

    Fields hold the clauses added so far; ``mode`` is fixed to "and"/"or" by
    chaining a second clause (AND) or by the ``&``/``|`` combinators.
    """
    spatial: Optional[Tuple[float, float, float, float]] = None
    temporal: Optional[Tuple[float, float]] = None
    sid: Optional[Tuple[int, int]] = None
    mode: Optional[str] = None          # "and" | "or"; None until fixed
    spec: Optional[AggSpec] = None      # None -> AggSpec() at build time
    want_latest: bool = False           # latest-per-drone hot-cache read

    # -- clauses ------------------------------------------------------------

    def _n_clauses(self) -> int:
        return sum(getattr(self, c) is not None for c in _CLAUSES)

    def _no_latest(self, what: str) -> None:
        if self.want_latest:
            raise ValueError(
                f"cannot add {what} to a latest() query: the latest-per-drone "
                "read is a whole-cache O(drones) fast path with no predicate "
                "or aggregation — filter the returned (D, 3+V) records on the "
                "host, or issue a separate range query.")

    def _with_clause(self, kind: str, value) -> "Query":
        self._no_latest(f"a {kind} clause")
        if getattr(self, kind) is not None:
            raise ValueError(
                f"query already has a {kind} clause: the engine evaluates at "
                f"most one spatial, one temporal, and one shard-id clause per "
                "predicate — issue two queries (Query.batch) to cover "
                "disjoint ranges.")
        mode = self.mode
        if mode is None and self._n_clauses() >= 1:
            mode = "and"                # chaining clauses means AND
        return dataclasses.replace(self, **{kind: value, "mode": mode})

    def bbox(self, lat0, lat1, lon0, lon1) -> "Query":
        """Spatial clause: inclusive [lat0, lat1] x [lon0, lon1] box."""
        lat0, lat1 = _scalar("lat0", lat0), _scalar("lat1", lat1)
        lon0, lon1 = _scalar("lon0", lon0), _scalar("lon1", lon1)
        if lat0 > lat1:
            raise ValueError(
                f"inverted latitude range: lat0={lat0} > lat1={lat1}. "
                "Inverted ranges match nothing; pass bbox(lat_min, lat_max, "
                "lon_min, lon_max) with lat_min <= lat_max.")
        if lon0 > lon1:
            raise ValueError(
                f"inverted longitude range: lon0={lon0} > lon1={lon1}. "
                "Inverted ranges match nothing; pass bbox(lat_min, lat_max, "
                "lon_min, lon_max) with lon_min <= lon_max.")
        return self._with_clause("spatial", (lat0, lat1, lon0, lon1))

    def time(self, t0, t1) -> "Query":
        """Temporal clause: inclusive [t0, t1] window."""
        t0, t1 = _scalar("t0", t0), _scalar("t1", t1)
        if t0 > t1:
            raise ValueError(
                f"inverted time range: t0={t0} > t1={t1}. Inverted ranges "
                "match nothing; pass time(t_start, t_end) with "
                "t_start <= t_end.")
        return self._with_clause("temporal", (t0, t1))

    def shard(self, sid_hi, sid_lo) -> "Query":
        """Shard-id point clause (drone id, collection round)."""
        return self._with_clause(
            "sid", (int(sid_hi), int(sid_lo)))

    def latest(self) -> "Query":
        """Latest-per-drone hot-cache read (paper §4.4 near-real-time path):
        ``AerialDB.query(Query().latest())`` returns the O(drones)
        ``LatestResult`` — last (max-t) record + last-seen step per drone —
        straight from the replicated cache, bypassing the log scan, the
        index, and the planner entirely. Terminal: takes no clauses and no
        aggregation (requires ``StoreConfig.max_drones > 0``)."""
        if self._n_clauses() or self.spec is not None:
            raise ValueError(
                "latest() is a whole-cache read and cannot be combined with "
                "clauses or aggregation: the hot path answers 'newest record "
                "per drone' only — filter the returned records on the host, "
                "or issue a separate range query for historical windows.")
        return dataclasses.replace(self, want_latest=True)

    # -- aggregation --------------------------------------------------------

    def agg(self, *ops: str, channel: Optional[int] = None,
            channels: Optional[Tuple[int, ...]] = None) -> "Query":
        """Request aggregates of one or more sensor channels: any of
        {"count", "sum", "min", "max", "mean"}. Pass ``channel=`` for the
        single-channel case or ``channels=`` for a static tuple aggregated
        in the SAME single scan (multi-channel results are (Q, K)-shaped,
        one column per channel). Calls accumulate ops, but the channel set
        is fixed once chosen — it is compiled into the scan."""
        self._no_latest("aggregation")
        if channel is not None and channels is not None:
            raise ValueError(
                "pass channel= (single) OR channels= (batched), not both.")
        if isinstance(channels, int):     # bare int normalizes like AggSpec
            channels = (channels,)
        new_channels = (tuple(channels) if channels is not None
                        else (channel,) if channel is not None else None)
        if (self.spec is not None and new_channels is not None
                and self.spec.channels != new_channels):
            raise ValueError(
                f"query already aggregates channels {self.spec.channels}; "
                f"the channel set is fixed per query (got {new_channels}). "
                "Request every channel in one .agg(channels=...) call, or "
                "issue a second query.")
        if new_channels is None:
            new_channels = self.spec.channels if self.spec is not None else (0,)
        prev = self.spec.ops if self.spec is not None else ()
        merged = prev + tuple(op for op in ops if op not in prev)
        return dataclasses.replace(
            self, spec=AggSpec(channels=new_channels, ops=merged or AGG_OPS))

    # -- combinators --------------------------------------------------------

    def _combine(self, other: "Query", mode: str) -> "Query":
        if not isinstance(other, Query):
            return NotImplemented
        sym = "&" if mode == "and" else "|"
        for side in (self, other):
            side._no_latest(f"the {sym} combinator")
        for side in (self, other):
            if side.mode is not None and side.mode != mode \
                    and side._n_clauses() >= 2:
                raise ValueError(
                    f"cannot {sym}-combine a query already fixed to "
                    f"{side.mode.upper()}: each predicate is a single AND or "
                    "OR over its clauses — (A AND B) OR C is not expressible "
                    "in one predicate. Run the two sides as separate batched "
                    "queries and combine the results.")
        merged = {}
        for kind in _CLAUSES:
            a, b = getattr(self, kind), getattr(other, kind)
            if a is not None and b is not None and a != b:
                raise ValueError(
                    f"both sides of {sym} carry a {kind} clause: the engine "
                    f"evaluates at most one {kind} clause per predicate — "
                    "issue two batched queries to cover both ranges.")
            merged[kind] = a if a is not None else b
        if self.spec is not None and other.spec is not None \
                and self.spec != other.spec:
            raise ValueError(
                "both sides carry a different AggSpec: the aggregation spec "
                "is static (compiled into the scan); set it once, on the "
                "combined query.")
        return Query(mode=mode, spec=self.spec or other.spec, **merged)

    def __and__(self, other: "Query") -> "Query":
        """AND-combine: tuples must satisfy every clause."""
        return self._combine(other, "and")

    def __or__(self, other: "Query") -> "Query":
        """OR-combine: tuples may satisfy any clause."""
        return self._combine(other, "or")

    @staticmethod
    def all_of(*queries: "Query") -> "Query":
        out = queries[0]
        for q in queries[1:]:
            out = out & q
        return out

    @staticmethod
    def any_of(*queries: "Query") -> "Query":
        out = queries[0]
        for q in queries[1:]:
            out = out | q
        return out

    # -- compilation --------------------------------------------------------

    def build(self) -> Tuple[QueryPred, AggSpec]:
        """Compile to the engine's ``(QueryPred, AggSpec)`` (q=1)."""
        if self.want_latest:
            raise ValueError(
                "a latest() query does not compile to a QueryPred: it never "
                "touches the scan engine. Run it through AerialDB.query(...) "
                "(or AerialDB.latest() directly) to read the hot cache.")
        if self._n_clauses() == 0:
            raise ValueError(
                "empty query: add at least one clause (bbox / time / shard). "
                "For a catch-all scan use .time(0, big) or the broadcast "
                "baseline config.")
        lat0, lat1, lon0, lon1 = self.spatial or (0.0, 0.0, 0.0, 0.0)
        t0, t1 = self.temporal or (0.0, 0.0)
        sid_hi, sid_lo = self.sid or (-1, -1)
        pred = make_pred(
            q=1, lat0=lat0, lat1=lat1, lon0=lon0, lon1=lon1, t0=t0, t1=t1,
            sid_hi=sid_hi, sid_lo=sid_lo,
            has_spatial=self.spatial is not None,
            has_temporal=self.temporal is not None,
            has_sid=self.sid is not None,
            is_and=self.mode != "or")
        return pred, self.spec if self.spec is not None else AggSpec()

    @staticmethod
    def batch(*queries: "Query") -> Tuple[QueryPred, AggSpec]:
        """Stack several built queries into one batched (Q,) QueryPred.

        All queries must resolve to the same ``AggSpec`` — the spec is static
        (one compiled scan serves the whole batch); split differing specs
        into separate ``AerialDB.query`` calls.
        """
        if not queries:
            raise ValueError("Query.batch needs at least one query.")
        built = [q.build() for q in queries]
        specs = {spec for _, spec in built}
        if len(specs) > 1:
            raise ValueError(
                f"queries in a batch must share one AggSpec, got {specs}: "
                "the spec is compiled into the scan; run differing specs as "
                "separate AerialDB.query calls.")
        preds = [p for p, _ in built]
        pred = QueryPred(*(jnp.concatenate([getattr(p, f) for p in preds])
                           for f in QueryPred._fields))
        return pred, built[0][1]
