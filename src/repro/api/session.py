"""``AerialDB``: the session facade over both runtimes.

One object owns everything callers used to hand-thread — ``StoreConfig``,
``StoreState``, the edge ``alive`` mask, the planner PRNG key, the scan-engine
flags — and transparently dispatches every operation to the single-device jit
path (``core.datastore``) or the shard_map federated path
(``distributed.federation``) depending on whether the session was opened on
an edge mesh. The two paths are differentially tested bit-identical
(``tests/test_federation.py``), so the dispatch is a pure deployment choice.

    db = AerialDB.open(cfg)                      # single device
    db = AerialDB.open(cfg, mesh=make_edge_mesh(4))   # 4-device federation
    db = AerialDB.open(cfg, mesh=make_fleet_mesh(2, 2))  # 2 fleets x 2 edges
    db.ingest_rounds(payloads, metas)
    res, info = db.query(Query().bbox(...).time(...).agg("mean", channel=2))
    db.fail_edges(1, 5); ...; db.recover_edges(1, 5)
    db.fail_device(0); ...; db.recover_device(0)      # whole failure domain
    db.partition([[0, 1], [2, 3]]); ...; db.heal()    # network partition

Failure-domain resilience (paper §4.5.3): ``fail_device`` / ``recover_device``
flip an entire contiguous device block of the edge axis at once — the unit
that actually fails when an edge *server* (one mesh device hosting
``E / n_devices`` edges) goes down. Recovery triggers an **anti-entropy
repair pass** (``core.repair``) by default: shards placed around the outage
are re-placed under the recovered mask, added replicas are backfilled with
tuples from surviving copies, and the recovered edges' indexes are
backfilled with every entry they missed — so a recovered edge serves
complete results instead of a silent lookup hole. The session keeps a
host-side **outage-epoch ledger** — every ``fail_*`` call opens an epoch
record ``(dead edges, fail_step)``, every ``recover_*`` call closes the
window at the current ingest step — and hands it to ``repair_state`` as an
``OutageLog``, so repair sweeps only the shards the recorded outages could
have touched (O(outage), not O(store); ``repair(full=True)`` forces the
full sweep). ``QueryInfo`` reports the degraded-query accounting
(``replicas_lost`` / ``completeness_bound``), and ``QueryResult.view``
carries both keys so applications see degradation without digging.

Fleet partition tolerance (PR 9): :meth:`partition` / :meth:`heal` model a
network partition — edges that are **unreachable but intact**, a ledger
state distinct from dead. The session keeps a ``reachable`` mask next to
``alive``; every placement/query/repair decision sees their conjunction
(:attr:`effective_alive`), so inserts re-route around the unreachable side
and queries surface the degradation through the same
``completeness_bound`` / ``replicas_lost`` accounting as a crash — but the
unreachable edges' state is never mutated, never backfilled, and never
reclaimed while the partition is open (their intact data may be the only
surviving copy). A heal closes an epoch window on the same outage ledger a
recovery does, so the incremental repair sweeps only shards ingested
*during* the partition plus those whose replicas straddled it — edges whose
data never died get no backfill.

See the package docstring (``repro.api``) for the facade-vs-local-bodies
layering contract.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.query import Query
from repro.core import datastore as _ds
from repro.core import repair as _repair
from repro.core.datastore import (AggSpec, LatestResult, QueryInfo,
                                  QueryResult, StoreConfig, StoreState,
                                  init_store)
from repro.core.index import QueryPred
from repro.core.placement import ShardMeta
from repro.distributed import federation as _fed
from repro.distributed.sharding import (device_edge_block, mesh_edge_devices,
                                        shard_store)

__all__ = ["AerialDB"]

Queryish = Union[Query, QueryPred, Tuple[QueryPred, AggSpec]]


class AerialDB:
    """An open AerialDB deployment: state + alive mask + key, one dispatch."""

    def __init__(self, cfg: StoreConfig, state: StoreState, alive, key,
                 mesh=None, use_kernel: bool = False,
                 interpret: Optional[bool] = None):
        """Wrap existing parts (the differential tests use this to adopt
        pre-loaded states); most callers want :meth:`open`."""
        if mesh is not None:
            _fed.check_edge_mesh(cfg, mesh)
        self._cfg = cfg
        self._state = state
        self._alive = jnp.asarray(alive, bool)
        self._key = key
        self._mesh = mesh
        self._use_kernel = use_kernel
        self._interpret = interpret
        self._last_repair: Optional[dict] = None
        # Outage-epoch ledger (see ``core.repair``): open records are
        # in-flight outages ``[dead edge set, fail_step]``; closed records
        # ``(recovered edge set, fail_step, recover_step)`` accumulate until
        # a repair consumes them. ``_pending_sids`` holds shards swept by a
        # repair that ran while other edges were still dead — they were
        # normalized to a *degraded* canonical placement and must be
        # re-swept until a repair completes with every edge alive.
        self._open_outages: list = []
        self._closed_outages: list = []
        self._pending_sids: set = set()
        # Fleet partition state (PR 9): ``_reachable`` marks edges the
        # session can still talk to — unreachable edges are intact (their
        # state is frozen, like dead ones) but excluded from placement,
        # query planning, and repair via ``effective_alive``. At most one
        # partition is open at a time; ``_partition`` records its
        # unreachable set + the step it opened at, closed onto the outage
        # ledger by :meth:`heal`.
        self._reachable = jnp.ones(cfg.n_edges, bool)
        self._partition: Optional[dict] = None
        # Ingest-time index-capacity drop watch: each insert's
        # (sid arrays, per-edge index_entries_dropped DEVICE array) is
        # recorded WITHOUT reading the array — reading would force a device
        # sync and break the ingest pipeline's double-buffering. The watch is
        # drained (arrays finally read, affected batches' sids folded into
        # ``_dropped_sids``) lazily: at repair/ledger-snapshot time, or once
        # the backlog passes a bound. ``_dropped_sids`` ride the OutageLog's
        # pending set so an INCREMENTAL repair re-attempts the dropped
        # entries exactly like a full sweep would.
        self._drop_watch: list = []
        self._dropped_sids: set = set()
        dead = np.nonzero(~np.asarray(self._alive, bool))[0]
        if dead.size:
            # Adopted state with unknown outage history: a fail_step of -1
            # covers every index entry, so the first repair after recovery
            # degenerates to (a correct) full-coverage sweep.
            self._open_outages.append([set(dead.tolist()), -1])

    @classmethod
    def open(cls, cfg: Optional[StoreConfig] = None, mesh=None, *,
             seed: int = 0, use_kernel: bool = False,
             interpret: Optional[bool] = None,
             **cfg_overrides) -> "AerialDB":
        """Open a fresh deployment.

        Args:
          cfg:   deployment config; None builds ``StoreConfig(**overrides)``.
          mesh:  optional datastore mesh — 1-D ``("edge",)``
                 (``launch.mesh.make_edge_mesh``) or 2-D ``("fleet", "edge")``
                 (``launch.mesh.make_fleet_mesh``): state is sharded per the
                 layout contract and every operation runs the federated
                 shard_map path. None = single-device jit path.
          seed:  planner PRNG seed (the facade owns and splits the key).
          use_kernel / interpret: scan-engine selection, as in
                 ``scan_engine`` (Pallas TPU kernel vs jnp reference).
          **cfg_overrides: with ``cfg=None``, StoreConfig fields; with a
                 config given, ``dataclasses.replace`` overrides.
        """
        if cfg is None:
            cfg = StoreConfig(**cfg_overrides)
        elif cfg_overrides:
            cfg = dataclasses.replace(cfg, **cfg_overrides)
        state = init_store(cfg)
        if mesh is not None:
            _fed.check_edge_mesh(cfg, mesh)
            state = shard_store(state, mesh)
        return cls(cfg, state, jnp.ones(cfg.n_edges, bool),
                   jax.random.key(seed), mesh=mesh, use_kernel=use_kernel,
                   interpret=interpret)

    # -- owned pieces (read-only views) -------------------------------------

    @property
    def cfg(self) -> StoreConfig:
        return self._cfg

    @property
    def state(self) -> StoreState:
        return self._state

    @property
    def alive(self) -> jnp.ndarray:
        return self._alive

    @property
    def reachable(self) -> jnp.ndarray:
        """(E,) bool — edges NOT cut off by an open :meth:`partition`.
        Orthogonal to :attr:`alive`: an edge can be dead, unreachable, or
        both; only ``alive & reachable`` edges serve."""
        return self._reachable

    @property
    def effective_alive(self) -> jnp.ndarray:
        """(E,) bool — the mask every placement/query/repair decision sees:
        ``alive & reachable``. Equals :attr:`alive` while no partition is
        open."""
        return self._alive & self._reachable

    @property
    def mesh(self):
        return self._mesh

    # -- ingest -------------------------------------------------------------

    # Drop-watch backlog bound: past this many unread insert telemetry
    # records, the next ingest drains them (each is months stale by then —
    # its compute long finished — so reading does not stall the device).
    _DROP_WATCH_MAX = 64

    def _watch_drops(self, sid_hi, sid_lo, dropped) -> None:
        """Record one ingest's (sids, device drop-count array) for lazy
        draining. ``sid_hi``/``sid_lo`` are host-side (N, B); ``dropped`` is
        the un-synced (N, E) device array from the insert info."""
        self._drop_watch.append((sid_hi, sid_lo, dropped))
        if len(self._drop_watch) > self._DROP_WATCH_MAX:
            self._drain_drop_watch()

    def _drain_drop_watch(self) -> None:
        """Read the watched drop counters (device sync point) and fold the
        sids of every round that dropped index entries into
        ``_dropped_sids``. Superset semantics are fine: sweeping a batch-mate
        whose entry landed is a canonical-placement no-op."""
        for hi, lo, dropped in self._drop_watch:
            d = np.asarray(dropped)
            for rnd in np.nonzero(d.sum(axis=1) > 0)[0]:
                self._dropped_sids.update(
                    _repair.sid_key(int(h), int(l))
                    for h, l in zip(hi[rnd], lo[rnd]))
        self._drop_watch = []

    def insert(self, payload, meta: ShardMeta) -> dict:
        """Insert one batch of B shards (R tuples each); returns the info
        dict (replicas, per-edge intake/index telemetry)."""
        payload = jnp.asarray(payload)
        sid_hi = np.asarray(meta.sid_hi)[None]       # host copies of INPUTS —
        sid_lo = np.asarray(meta.sid_lo)[None]       # no device-sync hazard
        meta = ShardMeta(*[jnp.asarray(f) for f in meta])
        mask = self.effective_alive
        if self._mesh is None:
            self._state, info = _ds._insert(self._cfg, self._state, payload,
                                            meta, mask)
        else:
            self._state, info = _fed.federated_insert_step(
                self._cfg, self._state, payload, meta, mask, self._mesh)
        self._watch_drops(sid_hi, sid_lo,
                          info["index_entries_dropped"][None])
        return info

    def ingest_rounds(self, payloads, metas) -> dict:
        """Fused multi-round ingest (one ``lax.scan`` dispatch, donated
        state); returns the info dict stacked over rounds."""
        sid_hi = np.asarray(metas.sid_hi)            # (N, B) host copies
        sid_lo = np.asarray(metas.sid_lo)
        self._state, info = _fed.ingest_rounds(
            self._cfg, self._state, payloads, metas, self.effective_alive,
            mesh=self._mesh)
        self._watch_drops(sid_hi, sid_lo, info["index_entries_dropped"])
        return info

    # -- query --------------------------------------------------------------

    def _compile(self, q: Queryish,
                 agg: Optional[AggSpec]) -> Tuple[QueryPred, AggSpec]:
        if isinstance(q, Query):
            if agg is not None:
                raise ValueError(
                    "pass the AggSpec on the builder (.agg(...)) OR as the "
                    "agg= override for a raw QueryPred, not both.")
            return q.build()
        if isinstance(q, QueryPred):
            return q, agg if agg is not None else AggSpec()
        if isinstance(q, tuple) and len(q) == 2 \
                and isinstance(q[0], QueryPred) and isinstance(q[1], AggSpec):
            if agg is not None:
                raise ValueError("q already carries an AggSpec; drop agg=.")
            return q
        raise TypeError(
            f"cannot query with {type(q).__name__}: pass a Query builder, a "
            "QueryPred (e.g. make_pred(...) or Query.batch(...)), or a "
            "(QueryPred, AggSpec) pair.")

    def query(self, q: Queryish, *, agg: Optional[AggSpec] = None,
              key: Optional[jax.Array] = None
              ) -> Tuple[QueryResult, QueryInfo]:
        """Run a query batch against the deployment.

        Args:
          q:    a ``Query`` builder, a batched ``QueryPred``
                (``Query.batch`` / ``make_pred``), or a
                ``(QueryPred, AggSpec)`` pair.
          agg:  AggSpec override for a raw QueryPred (channel(s) + ops). A
                multi-channel spec (``AggSpec(channels=(0, 2))``) aggregates
                every listed channel in the SAME single scan of the log and
                widens the value aggregates to (Q, K).
          key:  explicit planner PRNG key; None draws from the session key
                (each query consumes a fresh split).

        Returns ``(QueryResult, QueryInfo)``; project the requested
        aggregates with ``result.view(agg_spec)``. A ``Query().latest()``
        builder short-circuits to :meth:`latest` and returns its
        ``LatestResult`` directly (no scan, no planner, no ``QueryInfo``).
        """
        if isinstance(q, Query) and q.want_latest:
            if agg is not None:
                raise ValueError(
                    "latest() queries take no AggSpec: the hot-cache read "
                    "returns raw (D, 3+V) records, not aggregates.")
            return self.latest()
        pred, spec = self._compile(q, agg)
        spec.validate_for(self._cfg)
        if key is None:
            self._key, key = jax.random.split(self._key)
        mask = self.effective_alive
        if self._mesh is None:
            return _ds._query(self._cfg, self._state, pred, mask, key,
                              self._use_kernel, self._interpret, spec)
        return _fed.federated_query_step(
            self._cfg, self._state, pred, mask, key, self._mesh,
            use_kernel=self._use_kernel, interpret=self._interpret, agg=spec)

    def latest(self) -> LatestResult:
        """Latest-per-drone hot-cache read (paper §4.4 near-real-time path):
        the O(drones) ``LatestResult`` — newest (max-t) record, last-seen
        ingest step, and validity per drone id — straight from the
        replicated cache state, bypassing the log scan, the index, and the
        planner. Identical on both runtimes (the cache is replicated across
        the mesh and updated identically on every device — differential
        harness coverage in ``tests/test_federation.py``); staleness bound:
        exact up to the last *completed* insert (records still in an ingest
        pipeline's pending buffer are overlaid by
        ``IngestPipeline.latest()``)."""
        if self._cfg.max_drones == 0:
            raise ValueError(
                "the latest-per-drone cache is disabled: open the session "
                "with StoreConfig.max_drones >= the fleet's highest drone id "
                "+ 1 to track an O(drones) hot cache (drone id = sid_hi).")
        seen = self._state.latest_seen
        return LatestResult(record=self._state.latest_f, last_seen=seen,
                            valid=seen >= 0)

    # -- membership / failure domains ---------------------------------------

    def _edge_ids(self, edges) -> np.ndarray:
        """Normalize + validate edge ids **eagerly** on host.

        JAX scatter semantics silently clamp out-of-range indices, so the
        historical ``.at[ids].set(...)`` membership flips turned
        ``fail_edges(cfg.n_edges)`` into "mark the LAST edge dead" instead
        of an error. Every membership id is therefore validated here against
        ``cfg.n_edges`` (negatives, overflow, duplicates all raise) before
        any device op sees it.
        """
        ids = np.asarray(
            edges[0] if len(edges) == 1 and not isinstance(edges[0], int)
            else edges, np.int64).reshape(-1)
        if ids.size == 0:
            raise ValueError("no edge ids given: pass at least one edge id "
                             "(fail_edges(3) or fail_edges([3, 5])).")
        e = self._cfg.n_edges
        bad = ids[(ids < 0) | (ids >= e)]
        if bad.size:
            raise ValueError(
                f"edge id(s) {sorted(set(bad.tolist()))} out of range: this "
                f"deployment has n_edges={e} (valid ids 0..{e - 1}); JAX "
                "scatter clamping would silently retarget them.")
        if np.unique(ids).size != ids.size:
            dup = sorted({int(i) for i in ids
                          if (ids == i).sum() > 1})
            raise ValueError(
                f"duplicate edge id(s) {dup}: membership flips take each "
                "edge at most once.")
        return ids.astype(np.int32)

    def _device_edges(self, device: int) -> np.ndarray:
        """Resolve a failure-domain id to its contiguous edge block:
        ``cfg.n_failure_domains`` blocks when configured (> 1), else the
        session mesh's device blocks (the layout contract)."""
        n = self._cfg.n_failure_domains
        if n == 1 and self._mesh is not None:
            n = mesh_edge_devices(self._mesh)
        if n == 1:
            raise ValueError(
                "no failure domains to address: open the session on an edge "
                "mesh or set StoreConfig.n_failure_domains > 1 (device-level "
                "failures flip one contiguous block of E / n_domains edges).")
        return np.asarray(device_edge_block(self._cfg.n_edges, n, device),
                          np.int32)

    def fail_edges(self, *edges) -> "AerialDB":
        """Mark edges dead (paper §4.5.3 resilience shape): subsequent
        inserts skip them, queries re-plan around them; ids are validated
        eagerly (out-of-range / duplicate ids raise). Each call opens an
        outage-epoch record ``(newly dead edges, current step)`` on the
        session ledger so the eventual repair can sweep O(outage).

        Double-open semantics are **merge**: failing an already-dead edge
        changes nothing — the edge stays covered by the epoch record its
        ORIGINAL failure opened (the earlier fail step is the one the
        outage window must date from), no second record is opened for it,
        and a call whose every id is already dead is a pure no-op. Failing
        an unreachable (partitioned) edge is legal and independent: death
        and reachability compose via :attr:`effective_alive`."""
        ids = self._edge_ids(edges)
        newly_dead = ids[np.asarray(self._alive)[ids]]
        self._alive = self._alive.at[ids].set(False)
        if newly_dead.size:
            self._open_outages.append(
                [set(int(i) for i in newly_dead), int(self._state.steps)])
        return self

    def recover_edges(self, *edges, repair: bool = True) -> "AerialDB":
        """Bring failed edges back (their state was retained while dead).

        Closes the recovered edges' outage-epoch windows at the current
        ingest step. By default a recovery then triggers the incremental
        anti-entropy :meth:`repair` pass, so shards ingested during the
        outage are re-placed onto the recovered edges and their index
        entries/tuples backfilled — without it, a recovered edge answers
        index lookups from a table that is silently missing the whole
        outage window. Pass ``repair=False`` to defer (e.g. when recovering
        several domains and repairing once): the closed windows stay on the
        ledger until a repair consumes them.

        Double-close semantics are **no-op**: recovering an edge that is
        already alive closes nothing, and a call whose every id is alive
        leaves the session bitwise untouched — no window closes AND the
        implicit repair is skipped (it would otherwise consume closed
        windows deferred by an earlier ``repair=False`` recovery as a side
        effect of a do-nothing call). Deferred windows stay on the ledger
        for an explicit :meth:`repair` or the next real recovery.
        """
        ids = self._edge_ids(edges)
        newly_alive = set(int(i) for i in ids[~np.asarray(self._alive)[ids]])
        if not newly_alive:
            return self
        self._alive = self._alive.at[ids].set(True)
        recover_step = int(self._state.steps)
        for rec in self._open_outages:
            inter = rec[0] & newly_alive
            if inter:
                self._closed_outages.append(
                    (frozenset(inter), rec[1], recover_step))
                rec[0] -= inter
                newly_alive -= inter
        self._open_outages = [r for r in self._open_outages if r[0]]
        if newly_alive:
            # Dead edges with no ledger record (defensive — adopted masks are
            # recorded by __init__): treat their history as unknown.
            self._closed_outages.append(
                (frozenset(newly_alive), -1, recover_step))
        if repair:
            self.repair()
        return self

    def fail_device(self, device: int) -> "AerialDB":
        """Kill a whole failure domain (one mesh device's contiguous edge
        block): the paper's edge-server loss, where every edge the device
        hosts disappears at once. Placement spreads replicas across domains
        (``StoreConfig.n_failure_domains``), so a single device loss leaves
        every shard reachable."""
        return self.fail_edges(self._device_edges(device))

    def recover_device(self, device: int, repair: bool = True) -> "AerialDB":
        """Bring a failed device's whole edge block back; runs the
        anti-entropy :meth:`repair` pass by default (see
        :meth:`recover_edges`)."""
        return self.recover_edges(self._device_edges(device), repair=repair)

    # -- fleet partitions (unreachable-but-intact) ---------------------------

    def partition(self, edge_groups) -> "AerialDB":
        """Open a fleet-level network partition (paper's intermittent
        cellular links): split the edges into disjoint connectivity groups;
        the session (coordinator) stays with the FIRST group, every edge in
        the other groups becomes **unreachable but intact** — a ledger state
        distinct from dead. Unreachable edges are excluded from placement,
        query planning, and repair (via :attr:`effective_alive`) but their
        state is never mutated: the data on the far side of a partition is
        not lost, merely invisible, and must never be backfilled over.

        ``edge_groups`` is a sequence of edge-id groups (a flat list of ids
        is shorthand for one group). Edges named in no group implicitly join
        the coordinator side; with a single group given, the complement
        becomes the unreachable side. Groups must be disjoint, and the split
        must actually separate something (both sides non-empty) — degenerate
        partitions raise. At most one partition is open at a time: nested
        partitions raise (``heal()`` first); :meth:`heal` on a healed
        session is a no-op, so open/close is deterministic like the
        fail/recover ledger. Dead edges may appear in any group — death and
        reachability compose.
        """
        if self._partition is not None:
            raise ValueError(
                "a fleet partition is already open (unreachable edges "
                f"{sorted(self._partition['unreachable'])}): heal() it "
                "first — nested/overlapping partitions are not modeled.")
        groups = list(edge_groups)
        if groups and isinstance(groups[0], (int, np.integer)):
            groups = [groups]                   # flat id list = one group
        if not groups:
            raise ValueError("partition() needs at least one edge group.")
        ids = [self._edge_ids((g,)) if len(g) else np.empty(0, np.int32)
               for g in groups]           # empty group: names no edges
        flat = np.concatenate(ids)
        if np.unique(flat).size != flat.size:
            dup = sorted({int(i) for i in flat if (flat == i).sum() > 1})
            raise ValueError(
                f"edge id(s) {dup} appear in more than one partition group: "
                "connectivity groups must be disjoint.")
        if len(ids) == 1:
            unreachable = np.setdiff1d(
                np.arange(self._cfg.n_edges, dtype=np.int32), ids[0])
        else:
            unreachable = np.concatenate(ids[1:])
        if unreachable.size == 0:
            raise ValueError(
                "partition separates nothing: every edge ends up on the "
                "coordinator side. Name at least one edge in a non-first "
                "group (or pass a single group that excludes some edges).")
        if unreachable.size == self._cfg.n_edges:
            raise ValueError(
                "partition leaves the coordinator no reachable edges: the "
                "first group (the session's side) must keep at least one.")
        self._reachable = self._reachable.at[unreachable].set(False)
        self._partition = {
            "unreachable": set(int(i) for i in unreachable),
            "step": int(self._state.steps),
            "groups": tuple(tuple(int(i) for i in g) for g in ids)}
        return self

    def heal(self, *, repair: bool = True) -> "AerialDB":
        """Close the open partition: every edge becomes reachable again and
        the partition's epoch window ``(open step, current step]`` closes
        onto the SAME outage ledger a recovery uses — so the default
        incremental :meth:`repair` sweeps exactly the shards ingested while
        the fleet was split (they were placed around the unreachable side
        and owe it replicas/entries) plus those whose replicas straddle any
        still-dead edges. Edges whose data never died get no backfill: a
        shard placed before the partition, with all its replicas intact on
        the far side, is a full-sweep no-op. ``repair=False`` defers, like
        :meth:`recover_edges`. Healing a healed session is a no-op."""
        if self._partition is None:
            return self
        rec = self._partition
        self._partition = None
        self._reachable = jnp.ones(self._cfg.n_edges, bool)
        self._closed_outages.append(
            (frozenset(rec["unreachable"]), rec["step"],
             int(self._state.steps)))
        if repair:
            self.repair()
        return self

    def ledger(self) -> dict:
        """Machine-readable snapshot of the session's failure ledger (the
        chaos engine's telemetry surface): open outage records, closed
        (unconsumed) epoch windows, the open partition if any, and the
        pending/dropped sweep debts. Draining the drop watch here is a
        device sync point — this is a control-plane probe, not a hot
        path."""
        self._drain_drop_watch()
        return {
            "open_outages": [(sorted(rec[0]), int(rec[1]))
                             for rec in self._open_outages],
            "closed_windows": [(sorted(eds), int(f), int(r))
                               for eds, f, r in self._closed_outages],
            "partition": (None if self._partition is None else
                          {"unreachable":
                           sorted(self._partition["unreachable"]),
                           "step": self._partition["step"]}),
            "pending_sids": len(self._pending_sids),
            "dropped_sids": len(self._dropped_sids),
        }

    def _outage_log(self) -> "_repair.OutageLog":
        """Snapshot the session ledger as the ``OutageLog`` driving the
        incremental sweep (sorted — deterministic across differential
        runtimes). ``affected_edges`` carries only the OPEN outages' edges —
        the ones still dead now: a shard whose replicas touch an edge that
        failed AND already recovered is a full-sweep no-op (its stored
        placement equals the canonical one under the restored mask), so
        selecting it would make the sweep O(store) again. Shards *ingested*
        while that edge was away are what its closed window selects. The
        pending set folds in ``_dropped_sids`` (batches whose index entries
        were dropped at ingest by a momentarily-full table) so the
        incremental sweep re-attempts them like ``repair(full=True)``.
        An OPEN partition's unreachable edges ride ``affected_edges`` just
        like still-dead ones — a mid-partition repair re-places shards
        around them under the effective mask — and its window closes onto
        the same ledger at heal, so the reachable dimension needs no new
        OutageLog field."""
        self._drain_drop_watch()
        affected = set()
        for rec in self._open_outages:
            affected |= rec[0]
        if self._partition is not None:
            affected |= self._partition["unreachable"]
        return _repair.OutageLog(
            windows=tuple(sorted((int(f), int(r))
                                 for _eds, f, r in self._closed_outages)),
            affected_edges=tuple(sorted(affected)),
            pending_sids=tuple(sorted(self._pending_sids
                                      | self._dropped_sids)))

    def repair(self, *, full: bool = False) -> dict:
        """Anti-entropy re-replication sweep (``core.repair.repair_state``):
        re-derive swept shards' canonical placement under the current alive
        mask, rewrite stale replica sets, backfill tuples onto added
        replicas from surviving copies, reclaim stale ring slots on edges
        dropped by re-placement, and backfill missing index entries (the
        recovered-edge lookup hole). By default the sweep is **incremental**
        — driven by the session's outage-epoch ledger, it touches only
        shards the recorded outages could have affected, so an empty ledger
        is a telemetry-only no-op (``shards_swept == 0``); ``full=True``
        forces the classic every-tracked-shard sweep. A completed repair
        consumes the ledger's closed windows. Host-side control-plane
        operation — deterministic, so differential runtimes stay bitwise
        identical — and **single-process only**: the host gather assumes it
        sees the whole store (ROADMAP, cross-host mesh contract), so
        multi-process sessions raise instead of silently diverging per
        process. Returns the repair telemetry dict (also kept on
        :attr:`last_repair`)."""
        if jax.process_count() > 1:
            raise NotImplementedError(
                "AerialDB.repair() is single-process only: it gathers the "
                "full store to the host, which under a multi-process mesh "
                f"(jax.process_count()={jax.process_count()}) would repair "
                "each process's addressable slice independently and diverge "
                "the replicated state. See ROADMAP 'Cross-host mesh "
                "contract' — run repair from a single-process session, or "
                "defer with recover_edges(..., repair=False).")
        outage = None if full else self._outage_log()
        # Repair sees the EFFECTIVE mask: unreachable edges are treated
        # exactly like dead ones — never read as a source, never written,
        # never reclaimed — because their intact far-side state may be the
        # only surviving copy of a shard.
        state, info = _repair.repair_state(self._cfg, self._state,
                                           self.effective_alive,
                                           outage=outage)
        self._state = (shard_store(state, self._mesh)
                       if self._mesh is not None else state)
        # Ledger consumption: closed windows are now repaired; shards swept
        # under a still-degraded mask (dead OR unreachable edges remain)
        # stay pending until a repair completes with every edge effective.
        swept_keys = info.pop("_swept_keys")
        self._closed_outages = []
        if bool(np.asarray(self.effective_alive).all()):
            self._pending_sids = set()
        else:
            self._pending_sids |= set(swept_keys)
        # Dropped-entry ledger: a sweep that re-attempted every watched sid
        # without re-dropping (tables have room again) settles the debt; a
        # sweep that dropped again keeps them pending for the next repair.
        self._drain_drop_watch()
        if info.get("entries_dropped", 0) == 0:
            self._dropped_sids = set()
        self._last_repair = info
        return info

    @property
    def last_repair(self) -> Optional[dict]:
        """Telemetry of the most recent :meth:`repair` pass (None before)."""
        return self._last_repair
