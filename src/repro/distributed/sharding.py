"""Logical-axis -> mesh-axis resolution.

Model code annotates parameters with logical axes (FSDP / TP / EXP, see
models/layers.py). This module resolves them onto the physical mesh:

  single pod  (16, 16)    axes ("data", "model")
  multi-pod (2, 16, 16)   axes ("pod", "data", "model")

Baseline mapping: FSDP -> "data" (params sharded over the data axis and
all-gathered per layer inside the scan — ZeRO-3/FSDP), TP/EXP -> "model"
(tensor/expert parallelism). Across pods the baseline is pure data
parallelism: parameters replicate, gradients all-reduce over "pod" — the
collective the multi-pod dry-run must prove out.

``fsdp_over_pod=True`` additionally shards FSDP over ("pod", "data") —
a §Perf lever trading parameter all-gather traffic for memory.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.layers import EXP, FSDP, TP


def logical_rules(multi_pod: bool, fsdp_over_pod: bool = False):
    fsdp = (("pod", "data") if (multi_pod and fsdp_over_pod) else "data")
    return {FSDP: fsdp, TP: "model", EXP: "model"}


def resolve_spec(spec: P, rules) -> P:
    out = []
    for ax in spec:
        if ax is None:
            out.append(None)
        elif isinstance(ax, tuple):
            r = []
            for a in ax:
                m = rules.get(a, a)
                r.extend(m if isinstance(m, tuple) else (m,))
            out.append(tuple(r))
        else:
            m = rules.get(ax, ax)
            out.append(m)
    return P(*out)


def resolve_tree(tree, mesh: Mesh, multi_pod: bool, fsdp_over_pod: bool = False):
    """PartitionSpec tree (logical) -> NamedSharding tree (physical)."""
    rules = logical_rules(multi_pod, fsdp_over_pod)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, rules)),
        tree, is_leaf=lambda x: isinstance(x, P))


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


def batch_sharding(mesh: Mesh, multi_pod: bool, ndim: int, *, batch_dim=0):
    spec = [None] * ndim
    spec[batch_dim] = batch_axes(multi_pod)
    return NamedSharding(mesh, P(*spec))


def activation_sharding(mesh: Mesh, multi_pod: bool):
    """(B, S, D) layer-boundary constraint: batch x sequence sharding (SP)."""
    return NamedSharding(mesh, P(batch_axes(multi_pod), "model", None))
