"""Logical-axis -> mesh-axis resolution.

Two sharding domains live here:

* the **datastore** edge axis — every ``StoreState`` array carries the
  logical edge axis E in front, partitioned over the mesh's *edge-bearing
  axes*: a 1-D ``("edge",)`` mesh (``launch.mesh.make_edge_mesh``) or a 2-D
  ``("fleet", "edge")`` mesh (``launch.mesh.make_fleet_mesh``) where each
  host (or host-group) owns one fleet partition and the logical edge axis is
  split over the *product* of both axes, fleet-major. ``mesh_edge_axes``
  resolves a mesh to its edge-bearing axis tuple (the 1-D mesh is the
  degenerate ``n_fleet == 1`` case); ``store_partition_specs`` is the
  PartitionSpec tree of that contract, used by ``distributed.federation``'s
  shard_map in/out specs and by ``shard_store`` for device placement;

* the **model** logical axes (FSDP / TP / EXP, see models/layers.py),
  resolved onto the physical training mesh:

  single pod  (16, 16)    axes ("data", "model")
  multi-pod (2, 16, 16)   axes ("pod", "data", "model")

Baseline mapping: FSDP -> "data" (params sharded over the data axis and
all-gathered per layer inside the scan — ZeRO-3/FSDP), TP/EXP -> "model"
(tensor/expert parallelism). Across pods the baseline is pure data
parallelism: parameters replicate, gradients all-reduce over "pod" — the
collective the multi-pod dry-run must prove out.

``fsdp_over_pod=True`` additionally shards FSDP over ("pod", "data") —
a §Perf lever trading parameter all-gather traffic for memory.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.layers import EXP, FSDP, TP


EDGE_AXIS = "edge"
FLEET_AXIS = "fleet"


def check_edge_partition(n_edges: int, n_blocks: int,
                         what: str = "the edge mesh") -> int:
    """The one divisibility check of the sharded-state layout contract,
    shared by both mesh factories (``launch.mesh.make_edge_mesh`` /
    ``make_fleet_mesh``), ``federation.check_edge_mesh`` and
    ``device_edge_block``: the logical edge axis splits into equal contiguous
    blocks, one per partition. Returns the block size ``n_edges // n_blocks``.
    """
    if n_blocks < 1 or n_edges % n_blocks:
        raise ValueError(
            f"n_edges={n_edges} is not divisible by {what} size {n_blocks}: "
            "every device must host the same number of edges (equal "
            "contiguous blocks of the leading E axis). Pick an edge/device "
            "count pair with n_edges % n_devices == 0.")
    return n_edges // n_blocks


def mesh_edge_axes(mesh: Mesh) -> tuple:
    """The mesh's *edge-bearing axes*, fleet-major: the logical edge axis is
    partitioned over their product. ``("edge",)`` for the 1-D datastore mesh,
    ``("fleet", "edge")`` for the 2-D cross-host fleet mesh — the 1-D mesh is
    exactly the ``n_fleet == 1`` degenerate case of the same contract."""
    axes = tuple(n for n in mesh.axis_names if n in (FLEET_AXIS, EDGE_AXIS))
    if EDGE_AXIS not in axes:
        raise ValueError(
            f"mesh axes {tuple(mesh.axis_names)} lack the '{EDGE_AXIS}' "
            "axis; build the datastore mesh with launch.mesh.make_edge_mesh "
            "or launch.mesh.make_fleet_mesh.")
    return axes


def mesh_edge_devices(mesh: Mesh) -> int:
    """Number of edge partitions a mesh carries: the product of its
    edge-bearing axis sizes (= device count for a pure datastore mesh)."""
    n = 1
    for ax in mesh_edge_axes(mesh):
        n *= mesh.shape[ax]
    return n


def store_partition_specs(edge_axes=(EDGE_AXIS,)):
    """StoreState-shaped PartitionSpec tree of the sharded-state layout
    contract: every per-edge array (leading logical-E dim, including the
    nested IndexState) is partitioned over the mesh's edge-bearing axes
    (``("edge",)``, or ``("fleet", "edge")`` for the 2-D fleet mesh — the
    leading dim splits over the axis *product*, fleet-major, so each device
    still hosts one contiguous edge block); the scalar step counter
    replicates. Dims beyond the leading one replicate — in particular the
    column-major tuple log's (field-row, lane-padded tuple) trailing dims
    live whole on each edge's device, so the contract is layout-agnostic:
    each device holds its edges' complete logs whichever axis is minor."""
    from repro.core.datastore import StoreState
    from repro.core.index import IndexState
    edge_axes = tuple(edge_axes)
    edge = P(edge_axes)
    return StoreState(
        index=IndexState(ent_f=edge, ent_i=edge, valid=edge, cursor=edge,
                         dropped=edge, retired=edge, ent_step=edge),
        tup_f=edge, tup_sid=edge, tup_count=edge, tup_pos=edge,
        tup_overwritten=edge, tup_dropped=edge, steps=P(),
        # Latest-per-drone hot cache: leading dim is DRONES, not edges —
        # replicated on every device (each computes the identical update
        # from the replicated payload; AerialDB.latest() reads any copy).
        latest_f=P(), latest_seen=P())


def device_edge_block(n_edges: int, n_devices: int, device: int) -> range:
    """Global edge ids hosted by mesh device ``device`` under the layout
    contract (contiguous blocks of ``E / n_devices`` along the leading edge
    axis) — the failure-domain resolution used by ``AerialDB.fail_device``:
    a device loss takes out exactly this block. On the 2-D fleet mesh,
    ``device`` is the flat (fleet-major) partition index and ``n_devices``
    the axis product — block d of fleet f is flat device
    ``f * n_edge_per_fleet + d``."""
    block = check_edge_partition(n_edges, n_devices, "the device block count")
    if not 0 <= device < n_devices:
        raise ValueError(
            f"device={device} out of range: the edge mesh has {n_devices} "
            f"devices (valid ids 0..{n_devices - 1}).")
    return range(device * block, (device + 1) * block)


def shard_store(state, mesh: Mesh):
    """Place a StoreState onto a datastore mesh per ``store_partition_specs``
    (leading-E dim split into contiguous per-device blocks over the mesh's
    edge-bearing axes)."""
    leaves, treedef = jax.tree.flatten(state)
    specs = jax.tree.flatten(store_partition_specs(mesh_edge_axes(mesh)),
                             is_leaf=lambda x: isinstance(x, P))[0]
    placed = [jax.device_put(x, NamedSharding(mesh, s))
              for x, s in zip(leaves, specs)]
    return jax.tree.unflatten(treedef, placed)


def logical_rules(multi_pod: bool, fsdp_over_pod: bool = False):
    fsdp = (("pod", "data") if (multi_pod and fsdp_over_pod) else "data")
    return {FSDP: fsdp, TP: "model", EXP: "model"}


def resolve_spec(spec: P, rules) -> P:
    out = []
    for ax in spec:
        if ax is None:
            out.append(None)
        elif isinstance(ax, tuple):
            r = []
            for a in ax:
                m = rules.get(a, a)
                r.extend(m if isinstance(m, tuple) else (m,))
            out.append(tuple(r))
        else:
            m = rules.get(ax, ax)
            out.append(m)
    return P(*out)


def resolve_tree(tree, mesh: Mesh, multi_pod: bool, fsdp_over_pod: bool = False):
    """PartitionSpec tree (logical) -> NamedSharding tree (physical)."""
    rules = logical_rules(multi_pod, fsdp_over_pod)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, rules)),
        tree, is_leaf=lambda x: isinstance(x, P))


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


def batch_sharding(mesh: Mesh, multi_pod: bool, ndim: int, *, batch_dim=0):
    spec = [None] * ndim
    spec[batch_dim] = batch_axes(multi_pod)
    return NamedSharding(mesh, P(*spec))


def activation_sharding(mesh: Mesh, multi_pod: bool):
    """(B, S, D) layer-boundary constraint: batch x sequence sharding (SP)."""
    return NamedSharding(mesh, P(batch_axes(multi_pod), "model", None))
