"""Logical-axis -> mesh-axis resolution.

Two sharding domains live here:

* the **datastore** edge axis — every ``StoreState`` array carries the
  logical edge axis E in front, partitioned over a 1-D ``("edge",)`` mesh
  (``launch.mesh.make_edge_mesh``); ``store_partition_specs`` is the
  PartitionSpec tree of that contract, used by ``distributed.federation``'s
  shard_map in/out specs and by ``shard_store`` for device placement;

* the **model** logical axes (FSDP / TP / EXP, see models/layers.py),
  resolved onto the physical training mesh:

  single pod  (16, 16)    axes ("data", "model")
  multi-pod (2, 16, 16)   axes ("pod", "data", "model")

Baseline mapping: FSDP -> "data" (params sharded over the data axis and
all-gathered per layer inside the scan — ZeRO-3/FSDP), TP/EXP -> "model"
(tensor/expert parallelism). Across pods the baseline is pure data
parallelism: parameters replicate, gradients all-reduce over "pod" — the
collective the multi-pod dry-run must prove out.

``fsdp_over_pod=True`` additionally shards FSDP over ("pod", "data") —
a §Perf lever trading parameter all-gather traffic for memory.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.layers import EXP, FSDP, TP


EDGE_AXIS = "edge"


def store_partition_specs():
    """StoreState-shaped PartitionSpec tree of the sharded-state layout
    contract: every per-edge array (leading logical-E dim, including the
    nested IndexState) is partitioned over the mesh "edge" axis; the scalar
    step counter replicates. Dims beyond the leading one replicate — in
    particular the column-major tuple log's (field-row, lane-padded tuple)
    trailing dims live whole on each edge's device, so the contract is
    layout-agnostic: each device holds its edges' complete logs whichever
    axis is minor."""
    from repro.core.datastore import StoreState
    from repro.core.index import IndexState
    edge = P(EDGE_AXIS)
    return StoreState(
        index=IndexState(ent_f=edge, ent_i=edge, valid=edge, cursor=edge,
                         dropped=edge, retired=edge),
        tup_f=edge, tup_sid=edge, tup_count=edge, tup_pos=edge,
        tup_overwritten=edge, tup_dropped=edge, steps=P())


def device_edge_block(n_edges: int, n_devices: int, device: int) -> range:
    """Global edge ids hosted by mesh device ``device`` under the layout
    contract (contiguous blocks of ``E / n_devices`` along the leading edge
    axis) — the failure-domain resolution used by ``AerialDB.fail_device``:
    a device loss takes out exactly this block."""
    if n_devices < 1 or n_edges % n_devices:
        raise ValueError(
            f"n_edges={n_edges} must be a positive multiple of n_devices="
            f"{n_devices} (layout contract: equal contiguous blocks).")
    if not 0 <= device < n_devices:
        raise ValueError(
            f"device={device} out of range: the edge mesh has {n_devices} "
            f"devices (valid ids 0..{n_devices - 1}).")
    block = n_edges // n_devices
    return range(device * block, (device + 1) * block)


def shard_store(state, mesh: Mesh):
    """Place a StoreState onto an edge mesh per ``store_partition_specs``
    (leading-E dim split into contiguous per-device blocks)."""
    leaves, treedef = jax.tree.flatten(state)
    specs = jax.tree.flatten(store_partition_specs(),
                             is_leaf=lambda x: isinstance(x, P))[0]
    placed = [jax.device_put(x, NamedSharding(mesh, s))
              for x, s in zip(leaves, specs)]
    return jax.tree.unflatten(treedef, placed)


def logical_rules(multi_pod: bool, fsdp_over_pod: bool = False):
    fsdp = (("pod", "data") if (multi_pod and fsdp_over_pod) else "data")
    return {FSDP: fsdp, TP: "model", EXP: "model"}


def resolve_spec(spec: P, rules) -> P:
    out = []
    for ax in spec:
        if ax is None:
            out.append(None)
        elif isinstance(ax, tuple):
            r = []
            for a in ax:
                m = rules.get(a, a)
                r.extend(m if isinstance(m, tuple) else (m,))
            out.append(tuple(r))
        else:
            m = rules.get(ax, ax)
            out.append(m)
    return P(*out)


def resolve_tree(tree, mesh: Mesh, multi_pod: bool, fsdp_over_pod: bool = False):
    """PartitionSpec tree (logical) -> NamedSharding tree (physical)."""
    rules = logical_rules(multi_pod, fsdp_over_pod)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, rules)),
        tree, is_leaf=lambda x: isinstance(x, P))


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


def batch_sharding(mesh: Mesh, multi_pod: bool, ndim: int, *, batch_dim=0):
    spec = [None] * ndim
    spec[batch_dim] = batch_axes(multi_pod)
    return NamedSharding(mesh, P(*spec))


def activation_sharding(mesh: Mesh, multi_pod: bool):
    """(B, S, D) layer-boundary constraint: batch x sequence sharding (SP)."""
    return NamedSharding(mesh, P(batch_axes(multi_pod), "model", None))
