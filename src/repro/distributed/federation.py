"""Sharded federated runtime: the datastore partitioned over a device mesh.

The paper's federation story at device scale — a datastore mesh whose
*edge-bearing axes* (``distributed.sharding.mesh_edge_axes``) partition the
logical edge axis: the 1-D ``("edge",)`` mesh (``launch.mesh.make_edge_mesh``)
where each device plays a block of ``E / n_devices`` ground edge servers, or
the 2-D ``("fleet", "edge")`` cross-host mesh (``launch.mesh.make_fleet_mesh``)
where each host owns one fleet partition and the edge axis splits over the
axis product, fleet-major. Each device holds exactly its edges' slice of
every ``StoreState`` array (leading logical-E dim; contract in
``distributed.sharding.store_partition_specs``). The shard-local bodies in
``core.datastore`` (``insert_local`` / ``query_local``) run under ``shard_map``
with the axis-parameterized ``EdgeCollectives`` bundle built here
(``make_collectives``), so the tuple scatter, the index writes, and the
per-edge predicate scan are all device-local; cross-device traffic is
tuple-volume independent:

  * insert — one (E,) all-gather of per-edge retention watermarks (entries
    name replica edges anywhere, so retirement needs every edge's watermark);
  * query  — a *hierarchical* merge of each device's local top-S candidate
    shards (``_merge_matched``): intra-fleet all-gather + top-S reduce first
    (on-host under the fleet mesh), then the inter-fleet collective over the
    already-reduced S-sized set — re-deduplicated replicated at each level
    (``index.dedup_matched``: distributed top-k, bit-identical to the
    single-device lookup), then the final (Q, E) -> (Q,) combine of per-edge
    partial aggregates. On multi-fleet meshes the query batch is split into
    double-buffered tiles (``query_local``'s ``overlap_tiles=2``): every
    tile's merge collectives are issued before any tile's log scan, so the
    cross-host exchange overlaps device-local compute — bitwise identical to
    the untiled plan (per-query folded planner keys);

everything else (placement, slice masks, planning) is metadata-scale and
recomputed replicated. ``tests/test_federation.py`` is the differential
harness proving the single-device, 1-D, and 2-D paths produce identical
results and states.

Sustained ingest goes through ``ingest_rounds`` — a fused ``lax.scan`` over
collection rounds that replaces Python-loop round-tripping (one dispatch, no
per-round host sync) and **donates** the store so the tuple ring is updated
in place instead of double-allocating (donation is a no-op on CPU backends).

Paper-scale runs (80 edges / 400 drones over 1/2/4/8 simulated devices and
1/2/4 fleets) are driven by ``benchmarks/fig7_insertion_scaling.py`` via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``; the true
multi-process cross-host path (one process per fleet,
``launch.mesh.init_fleet_processes``) by ``benchmarks/multihost_smoke.py``.
"""

from __future__ import annotations

from functools import lru_cache
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.datastore import (AggSpec, EdgeCollectives, LOCAL_COLLECTIVES,
                                  StoreConfig, StoreState, check_batch_fits,
                                  finalize_query, insert_local, query_local)
from repro.core.index import MatchedShards, dedup_matched
from repro.core.placement import ShardMeta
from repro.distributed.sharding import (check_edge_partition, mesh_edge_axes,
                                        mesh_edge_devices, shard_store,
                                        store_partition_specs)

__all__ = [
    "federated_insert_step", "federated_query_step", "ingest_rounds",
    "make_collectives", "shard_store", "store_partition_specs",
]


def check_edge_mesh(cfg: StoreConfig, mesh: Mesh) -> int:
    """Validate the mesh against the deployment; returns the number of edge
    partitions (the edge-bearing axis product — device count for a pure
    datastore mesh)."""
    n_dev = mesh_edge_devices(mesh)  # raises without an "edge" axis
    check_edge_partition(cfg.n_edges, n_dev,
                         f"the edge mesh {dict(mesh.shape)}")
    if cfg.n_failure_domains > 1 and n_dev % cfg.n_failure_domains:
        raise ValueError(
            f"n_failure_domains={cfg.n_failure_domains} is incompatible with "
            f"an edge mesh of {n_dev} devices: each failure domain must be a "
            "whole number of device blocks (n_devices % n_failure_domains "
            "== 0), or two 'spread' replicas can silently share one device "
            "and a single device loss still takes out every copy. Use "
            f"n_failure_domains == {n_dev} (one domain per device), a "
            "divisor of it, or 1 to disable spreading.")
    return n_dev


def _replicated_like(tree):
    """A pytree of replicated PartitionSpecs matching ``tree``'s structure."""
    return jax.tree.map(lambda _: P(), tree)


def _insert_info_specs(scanned: bool, axes: tuple):
    """PartitionSpec tree for the insert info dict. Per-edge telemetry is
    sharded like the state (over the edge-bearing ``axes``); replicas and the
    (post-gather) watermark are replicated. ``scanned`` adds the leading
    rounds dim of ``ingest_rounds``."""
    per_edge = P(None, axes) if scanned else P(axes)
    return {
        "replicas": P(),
        "intake_per_edge": per_edge,
        "index_writes_per_edge": per_edge,
        "tuples_overwritten": per_edge,
        "tuples_dropped": per_edge,
        "index_entries_dropped": per_edge,
        "index_entries_retired": per_edge,
        "retention_watermark": P(),
    }


def _gather_watermark(wm_local: jnp.ndarray, axes: tuple) -> jnp.ndarray:
    """(E_local,) -> (E,) over the edge-bearing axis product. A tuple-axis
    all-gather concatenates major axis outermost — exactly the fleet-major
    edge-block order of the layout contract."""
    return jax.lax.all_gather(wm_local, axes, axis=0, tiled=True)


def _merge_axis(local: MatchedShards, max_shards: int,
                axis: str) -> MatchedShards:
    """One merge level: all-gather each participant's top-S list along one
    mesh axis and re-deduplicate back down to top-S."""
    cat = lambda x: jax.lax.all_gather(x, axis, axis=1, tiled=True)
    merged = dedup_matched(cat(local.valid), cat(local.sid_hi),
                           cat(local.sid_lo), cat(local.replicas), max_shards)
    any_local_ovf = jnp.any(
        jax.lax.all_gather(local.overflow, axis, axis=0, tiled=False),
        axis=0)
    return merged._replace(overflow=merged.overflow | any_local_ovf)


def _merge_matched(local: MatchedShards, max_shards: int,
                   axes: tuple) -> MatchedShards:
    """Hierarchically merge per-device candidate lists into the global
    MatchedShards, innermost mesh axis first: on the ("fleet", "edge") mesh
    that is an intra-fleet all-gather + top-S reduce (on-host), then the
    inter-fleet collective over the already-reduced set — each level moves
    only S-sized lists, so the cross-host hop is max_shards wide regardless
    of fleet size.

    Exactness at every level: each participant contributes its top-
    ``max_shards`` distinct sids (in dedup_matched's canonical ascending
    order); gathering those lists and re-deduplicating yields exactly the
    flat-merge result — any sid missing from a contributed top list is
    preceded by >= max_shards smaller sids on that participant alone, so it
    cannot be in the merged top-``max_shards`` either; by the same argument
    the level outputs compose (distributed top-k transitivity). Overflow is
    the OR of participant overflows (a participant that clipped has
    > max_shards distinct sids globally too) and each level's merged count
    test — identical to the flat overflow bit.
    """
    for ax in reversed(axes):
        local = _merge_axis(local, max_shards, ax)
    return local


def make_collectives(axes: tuple) -> EdgeCollectives:
    """The axis-parameterized collective-hook bundle for the shard-local
    bodies: watermark all-gather over the edge-bearing axis product and the
    hierarchical candidate merge. ``axes`` comes from ``mesh_edge_axes``;
    the identity bundle (no mesh) is ``datastore.LOCAL_COLLECTIVES``."""
    axes = tuple(axes)
    return EdgeCollectives(
        gather_watermark=lambda wm: _gather_watermark(wm, axes),
        combine_matched=lambda matched, s: _merge_matched(matched, s, axes))


@lru_cache(maxsize=None)
def _insert_fn(cfg: StoreConfig, mesh: Mesh):
    axes = mesh_edge_axes(mesh)
    state_specs = store_partition_specs(axes)
    meta_specs = _replicated_like(ShardMeta(*ShardMeta._fields))
    collectives = make_collectives(axes)

    def body(state, payload, meta, alive, edge_ids):
        return insert_local(cfg, state, payload, meta, alive, edge_ids,
                            collectives=collectives)

    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(state_specs, P(), meta_specs, P(), P(axes)),
        out_specs=(state_specs, _insert_info_specs(False, axes)),
        check_rep=False)

    def step(state, payload, meta, alive):
        edge_ids = jnp.arange(cfg.n_edges, dtype=jnp.int32)
        return sharded(state, payload, meta, alive, edge_ids)

    return jax.jit(step)


def federated_insert_step(cfg: StoreConfig, state: StoreState,
                          payload: jnp.ndarray, meta: ShardMeta,
                          alive: jnp.ndarray, mesh: Mesh):
    """``insert_step`` over a datastore mesh: identical semantics, state
    sharded per ``store_partition_specs``, device-local tuple/index writes."""
    check_edge_mesh(cfg, mesh)
    check_batch_fits(cfg, payload.shape)
    return _insert_fn(cfg, mesh)(state, payload, meta, alive)


@lru_cache(maxsize=None)
def _ingest_fn(cfg: StoreConfig, mesh: Optional[Mesh]):
    meta_specs = _replicated_like(ShardMeta(*ShardMeta._fields))
    collectives = (make_collectives(mesh_edge_axes(mesh))
                   if mesh is not None else LOCAL_COLLECTIVES)

    def run(state, payloads, metas, alive, edge_ids):
        def round_body(carry, xs):
            payload, meta = xs
            return insert_local(cfg, carry, payload, meta, alive, edge_ids,
                                collectives=collectives)
        return jax.lax.scan(round_body, state, (payloads, metas))

    if mesh is None:
        def single(state, payloads, metas, alive):
            edge_ids = jnp.arange(cfg.n_edges, dtype=jnp.int32)
            return run(state, payloads, metas, alive, edge_ids)
        return jax.jit(single, donate_argnums=(0,))

    axes = mesh_edge_axes(mesh)
    state_specs = store_partition_specs(axes)
    sharded = shard_map(
        run, mesh=mesh,
        in_specs=(state_specs, P(), meta_specs, P(), P(axes)),
        out_specs=(state_specs, _insert_info_specs(True, axes)),
        check_rep=False)

    def multi(state, payloads, metas, alive):
        edge_ids = jnp.arange(cfg.n_edges, dtype=jnp.int32)
        return sharded(state, payloads, metas, alive, edge_ids)

    return jax.jit(multi, donate_argnums=(0,))


def ingest_rounds(cfg: StoreConfig, state: StoreState, payloads, metas,
                  alive: jnp.ndarray, mesh: Optional[Mesh] = None):
    """Fused multi-round ingest: a single jitted ``lax.scan`` over N
    collection rounds (replaces Python-loop round-tripping in tests and
    benchmarks). The incoming ``state`` is **donated** — do not reuse it
    after the call (sustained ingest updates the tuple ring in place rather
    than double-allocating; donation is a no-op on CPU backends).

    Args:
      payloads: (N, B, R, 3+V) — N rounds of B shards.
      metas:    ShardMeta with (N, B) fields.
      alive:    (E,) availability mask, held fixed across the N rounds.
      mesh:     optional datastore mesh; None runs the 1-device jit path.

    Returns (state, info) with every info entry stacked over the N rounds.
    """
    payloads = jnp.asarray(payloads)
    metas = ShardMeta(*[jnp.asarray(x) for x in metas])
    check_batch_fits(cfg, payloads.shape[1:])
    if mesh is not None:
        check_edge_mesh(cfg, mesh)
    return _ingest_fn(cfg, mesh)(state, payloads, metas, alive)


@lru_cache(maxsize=None)
def _query_fn(cfg: StoreConfig, mesh: Mesh, use_kernel: bool,
              interpret: Optional[bool], channels: tuple):
    axes = mesh_edge_axes(mesh)
    state_specs = store_partition_specs(axes)
    collectives = make_collectives(axes)
    # Double-buffer the query batch on multi-fleet meshes so tile t+1's
    # cross-host merge overlaps tile t's device-local log scan; single-axis
    # meshes keep the untiled plan (the merge is on-host there).
    overlap_tiles = 2 if len(axes) > 1 else 1

    def body(state, pred, alive, key_data, edge_ids):
        key = jax.random.wrap_key_data(key_data)
        partials, sublist_len, meta_info = query_local(
            cfg, state, pred, alive, key, edge_ids,
            collectives=collectives,
            use_kernel=use_kernel, interpret=interpret,
            agg=AggSpec(channels=channels), overlap_tiles=overlap_tiles)
        return partials, sublist_len, meta_info

    # Partials: channel-independent (Q, E) count + per-channel (Q, K, E)
    # value aggregates — the edge axis stays last, so the final combine's
    # reduction axis is the (edge-bearing) mesh axes in both cases.
    partial_specs = (P(None, axes),) + (P(None, None, axes),) * 3

    def outer(state, pred, alive, key_data):
        edge_ids = jnp.arange(cfg.n_edges, dtype=jnp.int32)
        sharded = shard_map(
            body, mesh=mesh,
            in_specs=(state_specs, _replicated_like(pred), P(), P(),
                      P(axes)),
            out_specs=(partial_specs, P(None, axes),
                       (P(),) * 6),
            check_rep=False)
        partials, sublist_len, meta_info = \
            sharded(state, pred, alive, key_data, edge_ids)
        # The only tuple-volume-independent cross-device reduction: the final
        # (Q, E) combine over the sharded per-edge partials. The degraded-
        # query accounting (replicas_lost / completeness_bound) rides in
        # meta_info — computed replicated next to planning, like the rest.
        return finalize_query(partials, sublist_len, *meta_info)

    return jax.jit(outer)


def federated_query_step(cfg: StoreConfig, state: StoreState, pred,
                         alive: jnp.ndarray, key: jax.Array, mesh: Mesh,
                         use_kernel: bool = False,
                         interpret: Optional[bool] = None,
                         agg: AggSpec = AggSpec()):
    """``query_step`` over a datastore mesh: device-local index match + tuple
    scan, metadata-scale hierarchical candidate merge, replicated planning,
    and a final cross-device (Q, K, E) combine. ``agg`` (static) selects the
    sensor channel tuple and aggregate set; the device-local scan produces
    per-channel per-edge partials for every requested channel in ONE pass
    over the local log, and ``finalize_query``'s combine (including the
    derived mean) stays the only cross-device reduction. Only
    ``agg.channels`` keys the compiled-function cache — varying the ops
    projection is free. Returns (QueryResult, QueryInfo)."""
    check_edge_mesh(cfg, mesh)
    agg.validate_for(cfg)
    return _query_fn(cfg, mesh, use_kernel, interpret, agg.channels)(
        state, pred, alive, jax.random.key_data(key))
