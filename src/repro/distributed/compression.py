"""Gradient compression: int8 error-feedback all-reduce.

Cross-pod gradient synchronization is the dominant multi-pod collective
(DCN-grade links between pods vs ICI within). This module provides an int8
quantized all-reduce with error feedback (1-bit-Adam / EF-SGD family): each
step quantizes (grad + carried error) to int8 with a per-tensor scale,
all-reduces the int8 payload (4x wire reduction vs f32, 2x vs bf16), and
carries the quantization residual into the next step — preserving
convergence (the residual is eventually applied).

Usable inside shard_map over the pod/data axis; the trainer exposes it via
``TrainConfig.compress_grads``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_allreduce_int8(grad, error, axis_name: str):
    """Error-feedback int8 psum of one gradient tensor.

    Returns (mean_grad, new_error). Call per-leaf under shard_map; the int8
    payload is what crosses the network.
    """
    comp = grad.astype(jnp.float32) + error
    q, scale = quantize_int8(comp)
    new_error = comp - dequantize_int8(q, scale)
    # int8 summation overflows at >= 2 participants; accumulate in int32.
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    sum_scale = jax.lax.psum(scale, axis_name)  # scales differ per device
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # communicate per-device scale-weighted payloads: q_i * s_i. Since psum
    # of q_i*s_i != (psum q_i) * s, we approximate with the mean scale —
    # error feedback absorbs the residual next step.
    mean = summed.astype(jnp.float32) * (sum_scale / n) / n
    return mean.astype(grad.dtype), new_error


def ef_allreduce_tree(grads, errors, axis_name: str):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        mg, ne = ef_allreduce_int8(g, e, axis_name)
        out_g.append(mg)
        out_e.append(ne)
    return jax.tree.unflatten(treedef, out_g), jax.tree.unflatten(treedef, out_e)


def init_error_tree(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
