"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf]. Modality frontend
is a STUB: input_specs() provides precomputed frame embeddings (DESIGN.md)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, encoder_layers=24, d_model=1024, n_heads=16, n_kv=16,
    d_head=64, d_ff=8192, vocab=256206, enc_seq_ratio=4,
))
