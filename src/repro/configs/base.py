"""Model configuration + registry for the assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab: int = 1024

    # attention details
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)   # sums to d_head//2
    attn_chunk_kv: int = 1024
    tie_embeddings: bool = False
    gather_kv: bool = False       # SP schedule: all-gather K/V per layer
                                  # instead of chunk-slicing S-sharded KV

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    first_dense: int = 0          # leading dense-FFN layers (DeepSeek-V2: 1)
    capacity_factor: float = 1.25
    moe_dispatch: str = "einsum"  # einsum (GShard baseline) | scatter
    aux_loss_weight: float = 0.01
    expert_shard: bool = True     # EP over the model axis; False => TP over
                                  # the expert FFN dim (n_experts < TP size)
    moe_group_tokens: int = 0     # 0 = ungrouped dispatch (baseline); > 0 =
                                  # GShard group dimension (see moe_apply)
    moe_ff_fsdp: bool = False     # shard expert FFN dim over the data axis
                                  # (2D expert sharding: weights stay pinned,
                                  # activations reshard — no per-micro expert
                                  # weight gathers)

    # MLA
    mla: bool = False
    kv_lora: int = 0
    mla_nope_dim: int = 128
    mla_rope_dim: int = 64
    mla_v_dim: int = 128

    # SSM
    ssm_state: int = 0
    ssm_version: int = 1
    d_conv: int = 4
    expand: int = 2
    n_groups: int = 1
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_scan: str = "associative"  # associative | sequential

    # hybrid (zamba2): one shared attention block applied every attn_every
    # mamba layers
    attn_every: int = 0

    # enc-dec
    encoder_layers: int = 0
    enc_seq_ratio: int = 4        # encoder frames = seq_len / ratio

    # modality frontend stub: inputs are precomputed embeddings
    embed_input: bool = False

    # vocab padding: embeddings/unembeddings allocate the padded size so the
    # vocab dim shards evenly; padded logits are masked (seamless: 256206).
    vocab_pad_multiple: int = 256

    # numerics / memory
    param_dtype_str: str = "float32"
    compute_dtype_str: str = "bfloat16"
    remat: str = "full"           # full | dots | none
    loss_chunk: int = 2048        # CE vocab-chunking (tokens per block)
    scan_layers: bool = True
    seq_shard_activations: bool = True  # P(batch, "model", None) at layer edges

    @property
    def param_dtype(self):
        return jnp.dtype(self.param_dtype_str)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.compute_dtype_str)

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def vocab_padded(self):
        m = self.vocab_pad_multiple
        return (self.vocab + m - 1) // m * m

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One dry-run cell: an input-shape regime (see prompt: 4 per arch)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_train(self):
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY = {}


def register(cfg: ModelConfig):
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        import importlib
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[name]


def list_configs():
    # import all arch modules for a full listing
    for mod in ARCH_MODULES:
        import importlib
        importlib.import_module(f"repro.configs.{mod}")
    return dict(_REGISTRY)


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Shrink a full config to a CPU-runnable config of the same family:
    same block structure and flags, tiny dims (smoke-test contract)."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4), d_model=128, d_ff=256 if cfg.d_ff else 0,
        vocab=512, loss_chunk=128, attn_chunk_kv=64, ssm_chunk=16,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv=min(max(cfg.n_kv * 4 // cfg.n_heads, 1), 4),
                  d_head=32)
    if cfg.mrope:
        kw.update(mrope_sections=(6, 5, 5))
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2), d_ff_expert=64,
                  n_shared=min(cfg.n_shared, 1))
    if cfg.mla:
        kw.update(kv_lora=32, mla_nope_dim=32, mla_rope_dim=16, mla_v_dim=32)
    if cfg.ssm_state:
        kw.update(ssm_state=8, ssm_headdim=16)
    if cfg.encoder_layers:
        kw.update(encoder_layers=min(cfg.encoder_layers, 3))
    if cfg.attn_every:
        kw.update(attn_every=2)
    return cfg.replace(name=cfg.name + "-smoke", **kw)


ARCH_MODULES = [
    "internlm2_1_8b", "qwen3_14b", "deepseek_7b", "stablelm_12b",
    "grok_1_314b", "deepseek_v2_236b", "seamless_m4t_large_v2",
    "zamba2_1_2b", "qwen2_vl_72b", "falcon_mamba_7b",
]
