"""deepseek-v2-236b [moe]: 60L d_model=5120 128H (GQA kv=128) d_ff=1536
vocab=102400, MoE 160e top-6 — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]. d_ff=1536 is the per-expert width; the single leading
dense layer uses the paper's 12288."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv=128, d_head=128,
    d_ff=12288, vocab=102400,
    n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536, first_dense=1,
    mla=True, kv_lora=512, mla_nope_dim=128, mla_rope_dim=64, mla_v_dim=128,
))
