"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242;
hf]. One shared attention+MLP block applied every 6 Mamba2 layers (weights
shared across applications, per the Zamba2 design)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, d_head=64,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_version=2, ssm_headdim=64, expand=2, n_groups=1,
    attn_every=6,
))
