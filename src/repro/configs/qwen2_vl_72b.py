"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf]. Vision
frontend is a STUB: input_specs() provides precomputed patch embeddings;
the backbone applies M-RoPE over (t, h, w) position streams."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_head=128,
    d_ff=29568, vocab=152064,
    mrope=True, mrope_sections=(16, 24, 24), embed_input=True,
))
