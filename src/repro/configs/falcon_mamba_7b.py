"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) d_ff=0 vocab=65024,
ssm_state=16 — mamba1 arch [arXiv:2410.05355; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv=0, d_head=0,
    d_ff=0, vocab=65024,
    ssm_state=16, ssm_version=1, expand=2, d_conv=4,
))
