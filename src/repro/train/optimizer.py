"""AdamW built from scratch (no optax in this environment), with the
distributed-memory knobs that matter at 314B scale:

  * moment dtype is configurable (bf16 moments halve optimizer HBM — the
    grok-1/deepseek-v2 cells need this to fit 16 GB/chip),
  * global-norm gradient clipping,
  * linear-warmup + cosine decay schedule,
  * optimizer state inherits the parameter PartitionSpecs (fully sharded).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype_str: str = "bfloat16"
    # Keep an fp32 master copy in the optimizer state when model params are
    # bf16 (mixed-precision training: bf16 params are what FSDP all-gathers
    # — 2x less traffic — while updates accumulate in fp32).
    keep_master: bool = False

    @property
    def moment_dtype(self):
        return jnp.dtype(self.moment_dtype_str)


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any
    master: Any = None   # fp32 master params (keep_master) or None


def lr_at(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(cfg: OptConfig, params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if cfg.keep_master else None)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params),
                    master=master)


def opt_state_pspecs(param_pspecs, keep_master: bool = False):
    from jax.sharding import PartitionSpec as P
    return OptState(step=P(), mu=param_pspecs, nu=param_pspecs,
                    master=param_pspecs if keep_master else None)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, pm):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps)
        src = pm if pm is not None else p
        decay = cfg.weight_decay * src.astype(jnp.float32) if p.ndim >= 2 else 0.0
        p32 = src.astype(jnp.float32) - lr * (step_dir + decay)
        out = (p32.astype(p.dtype), m32.astype(cfg.moment_dtype),
               v32.astype(cfg.moment_dtype))
        return out + ((p32,) if pm is not None else ())

    if state.master is None:
        out = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v, None),
                           params, grads, state.mu, state.nu)
    else:
        out = jax.tree.map(upd, params, grads, state.mu, state.nu,
                           state.master)
    is_tup = lambda x: isinstance(x, tuple)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_tup)
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=is_tup)
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=is_tup)
    new_master = (jax.tree.map(lambda t: t[3], out, is_leaf=is_tup)
                  if state.master is not None else None)
    return new_params, OptState(step, new_mu, new_nu, new_master), {
        "grad_norm": gnorm, "lr": lr}
