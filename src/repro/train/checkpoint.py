"""Fault-tolerant checkpointing: sharded npz + manifest, atomic, elastic.

Design constraints at 1000+ node scale:
  * every host writes only its own shard files (no single-writer bottleneck),
  * a checkpoint becomes visible atomically (manifest written last, then
    directory renamed from .tmp), so a mid-write failure never corrupts the
    restore point,
  * restore is *elastic*: the target mesh may differ from the save mesh —
    arrays are reassembled from shard files and re-sharded onto the new mesh
    (the checkpoint format stores logical arrays, not device tiles).

This container is single-process, so "per-host shard files" degenerate to
one file per array group; the layout and the manifest protocol are the
multi-host ones.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(ckpt_dir, step: int, tree, *, keep: int = 3) -> Path:
    """Write checkpoint atomically; returns the final directory."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat, treedef = _flatten(tree)
    # npz has no bf16 support: store raw bytes, reconstruct via the manifest
    # dtype (ml_dtypes names like "bfloat16" resolve through jnp.dtype).
    arrays = {f"a{i}": np.ascontiguousarray(np.asarray(x)).view(np.uint8).reshape(-1)
              for i, x in enumerate(flat)}
    np.savez(tmp / "shard_00000.npz", **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "n_arrays": len(flat),
        "treedef": str(treedef),
        "shapes": [list(np.shape(x)) for x in flat],
        "dtypes": [str(np.asarray(x).dtype) for x in flat],
        "shards": ["shard_00000.npz"],
    }
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic visibility
    _gc_old(ckpt_dir, keep)
    return final


def _gc_old(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p)


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(ckpt_dir.glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore_checkpoint(ckpt_dir, tree_like, *, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``tree_like``. ``shardings`` (same
    structure, NamedSharding leaves) re-shards onto the CURRENT mesh — which
    may differ from the mesh at save time (elastic restart)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())
    data = np.load(d / "shard_00000.npz")
    flat_like, treedef = _flatten(tree_like)
    if manifest["n_arrays"] != len(flat_like):
        raise ValueError("checkpoint/tree structure mismatch: "
                         f"{manifest['n_arrays']} vs {len(flat_like)} arrays")
    flat = []
    for i in range(len(flat_like)):
        dt = jnp.dtype(manifest["dtypes"][i])
        shape = tuple(manifest["shapes"][i])
        flat.append(data[f"a{i}"].view(dt).reshape(shape))
    out = jax.tree.unflatten(treedef, flat)
    if shardings is None:
        out = jax.tree.map(jnp.asarray, out)
    if shardings is not None:
        out = jax.tree.map(
            lambda x, s: jax.device_put(jnp.asarray(x), s), out, shardings)
    return out, step
