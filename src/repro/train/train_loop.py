"""Train/serve step factories: jitted, sharded, donated — the functions the
dry-run lowers and the trainer drives.

``make_train_step``: loss -> grads (with microbatch gradient accumulation)
-> AdamW update. Params/opt-state shardings come from the model's logical
specs; the batch shards over the data axes; activations get layer-boundary
constraints (SP).

``make_serve_steps``: prefill (full forward, no cache for train-style
scoring) and decode (one token against a populated cache).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.distributed import sharding as shlib
from repro.models import attention, moe, transformer
from repro.models.model import Model
from repro.train import optimizer as optlib


def loss_with_microbatch(model: Model, params, batch, n_micro: int):
    """Mean loss over n_micro microbatches (scan = gradient accumulation;
    bounds activation memory for the train_4k cells).

    The body is checkpointed: without it, every microbatch's layer-scan
    residuals stay live until the accumulation scan's backward runs —
    n_micro x the intended activation footprint."""
    if n_micro <= 1:
        return model.loss(params, batch)
    def split(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    mb = jax.tree.map(split, batch)

    @jax.checkpoint
    def body(acc, one):
        return acc + model.loss(params, one), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), mb)
    return total / n_micro


def make_train_step(model: Model, opt_cfg: optlib.OptConfig, mesh: Mesh,
                    *, multi_pod: bool = False, n_micro: int = 1,
                    fsdp_over_pod: bool = False, donate: bool = True):
    """Returns (train_step, shardings) where
    train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    pspecs = model.pspecs()
    param_sh = shlib.resolve_tree(pspecs, mesh, multi_pod, fsdp_over_pod)
    opt_sh = shlib.resolve_tree(
        optlib.opt_state_pspecs(pspecs, opt_cfg.keep_master), mesh,
        multi_pod, fsdp_over_pod)
    transformer.set_activation_sharding(
        shlib.activation_sharding(mesh, multi_pod))
    attention.set_kv_gather_sharding(
        shlib.activation_sharding(mesh, multi_pod))
    moe.set_group_sharding(shlib.activation_sharding(mesh, multi_pod))

    def batch_shardings(batch_like):
        return jax.tree.map(
            lambda x: shlib.batch_sharding(mesh, multi_pod, x.ndim), batch_like)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return loss_with_microbatch(model, p, batch, n_micro)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, metrics = optlib.adamw_update(
            opt_cfg, grads, opt_state, params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    def jit_for(batch_like):
        return jax.jit(
            train_step,
            in_shardings=(param_sh, opt_sh, batch_shardings(batch_like)),
            out_shardings=(param_sh, opt_sh, None),
            donate_argnums=(0, 1) if donate else ())

    return train_step, {
        "params": param_sh, "opt": opt_sh,
        "batch_fn": batch_shardings, "jit_for": jit_for,
    }


def make_serve_steps(model: Model, mesh: Mesh, *, multi_pod: bool = False):
    """Returns (prefill_step, decode_step, shardings)."""
    pspecs = model.pspecs()
    param_sh = shlib.resolve_tree(pspecs, mesh, multi_pod)
    cache_sh = shlib.resolve_tree(model.cache_pspecs(multi_pod), mesh,
                                  multi_pod)
    transformer.set_activation_sharding(
        shlib.activation_sharding(mesh, multi_pod))
    attention.set_kv_gather_sharding(
        shlib.activation_sharding(mesh, multi_pod))
    moe.set_group_sharding(shlib.activation_sharding(mesh, multi_pod))

    def batch_shardings(batch_like):
        return jax.tree.map(
            lambda x: shlib.batch_sharding(mesh, multi_pod, x.ndim), batch_like)

    def prefill_step(params, batch):
        hidden, _ = model.forward(params, batch)
        return model.logits(params, hidden[:, -1:, :])[:, 0]

    def decode_step(params, cache, inputs, pos):
        return model.decode_step(params, cache, inputs, pos)

    def jit_prefill(batch_like):
        return jax.jit(prefill_step,
                       in_shardings=(param_sh, batch_shardings(batch_like)))

    def jit_decode(inputs_like):
        return jax.jit(decode_step,
                       in_shardings=(param_sh, cache_sh,
                                     batch_shardings(inputs_like), None),
                       out_shardings=(cache_sh, None),
                       donate_argnums=(1,))

    return prefill_step, decode_step, {
        "params": param_sh, "cache": cache_sh,
        "jit_prefill": jit_prefill, "jit_decode": jit_decode,
    }
