"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

``input_specs(cfg, shape)`` returns the exact pytree the corresponding step
function is lowered against — weak-type-correct, shardable, zero allocation.
Modality frontends are stubs per the assignment: [audio]/[vlm] archs receive
precomputed frame/patch embeddings of the backbone width.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    """Cells that are skipped by design (documented in DESIGN.md §4)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return ("full-attention arch: 500k context needs sub-quadratic "
                "attention (run only for ssm/hybrid)")
    return None


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    batch = {}
    if cfg.family == "encdec":
        es = max(s // cfg.enc_seq_ratio, 1)
        batch["enc_embeds"] = SDS((b, es, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = SDS((b, s), jnp.int32)
    elif cfg.embed_input:
        batch["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = SDS((b, s), jnp.int32)
    batch["labels"] = SDS((b, s), jnp.int32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    batch = train_batch_specs(cfg, shape)
    batch.pop("labels")
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(cache_specs, inputs_specs, pos_spec) for one decode step over a
    populated cache of length shape.seq_len."""
    b, s = shape.global_batch, shape.seq_len
    from repro.models.model import Model
    model = Model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    if cfg.embed_input:
        inputs = {"embeds": SDS((b, 1, cfg.d_model), jnp.bfloat16)}
    else:
        inputs = {"tokens": SDS((b, 1), jnp.int32)}
    return cache, inputs, SDS((), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """The full spec bundle for a cell: dict with step kind + arg specs."""
    if shape.kind == "train":
        return {"kind": "train", "batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"kind": "prefill", "batch": prefill_batch_specs(cfg, shape)}
    cache, inputs, pos = decode_input_specs(cfg, shape)
    return {"kind": "decode", "cache": cache, "inputs": inputs, "pos": pos}


def batch_shardable(shape: ShapeConfig, multi_pod: bool) -> bool:
    dp = 32 if multi_pod else 16
    return shape.global_batch % dp == 0
