"""Structural analysis of compiled (post-SPMD, per-device) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, which silently
undercounts scan-over-layers models by ~L x. This analyzer parses the
optimized HLO module structurally instead:

  * computations + a name->shape map per computation,
  * call graph (while body/condition x trip count, fusions, calls,
    conditionals) -> per-computation execution counts,
  * dot FLOPs from operand shapes x execution count,
  * bytes-accessed at fusion granularity (result + operands of top-level
    instructions) x execution count,
  * collective bytes (result size per op kind) x execution count.

Trip counts come from the loop-condition constant (XLA lowers lax.scan to a
canonical counted while; `wide.` double-buffered wrappers nest and multiply
correctly through the call graph).

Everything here is per-device (the module is one SPMD partition); multiply by
chip count for global numbers.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
               "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
_ARR_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2|c64|c128)\[([\d,]*)\]")
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# Alias-only ops that move no data at runtime.
NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
              "after-all", "partition-id", "replica-id", "iota"}
# Control flow: operands/results are aliased through to the body (whose own
# instructions are counted); charging the full carried tuple here would
# overcount by the loop state size.
CONTROL_FLOW = {"while", "conditional", "call", "custom-call"}
# In-place slice updates: only the updated window moves.
ALIASED_UPDATE = {"dynamic-update-slice", "scatter"}
# Indexed reads: only the selected window moves.
SLICE_READ = {"dynamic-slice", "gather"}


def type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARR_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def arr_dims(type_str: str):
    m = _ARR_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: list
    line: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)
    is_fusion: bool = False


_DEF_RE = re.compile(r"^\s*(?:ROOT )?%?([\w.\-]+) = ")
_COMP_HDR = re.compile(r"^(ENTRY )?%?([\w.\-]+) \(.*\)\s*->")


def _parse_type_and_rest(s: str):
    """Split '<type> <op>(<args>)...' -> (type_str, rest)."""
    s = s.lstrip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return s[:i + 1], s[i + 1:].lstrip()
        return s, ""
    sp = s.find(" ")
    return s[:sp], s[sp + 1:]


def _parse_call_args(rest: str):
    """From '<op>(<args>), attrs' -> (op, args_str, attrs_str)."""
    par = rest.find("(")
    if par < 0:
        return rest.strip(), "", ""
    op = rest[:par].strip()
    depth = 0
    for i in range(par, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                return op, rest[par + 1:i], rest[i + 1:]
    return op, rest[par + 1:], ""


def parse_module(hlo: str):
    comps = {}
    entry = None
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.endswith("{"):
                cur = Computation(name=m.group(2))
                if m.group(1):
                    entry = cur.name
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name = m.group(1)
        after = line[m.end():]
        type_str, rest = _parse_type_and_rest(after)
        op, args, attrs = _parse_call_args(rest)
        operands = re.findall(r"%([\w.\-]+)", args)
        cur.instrs.append(Instr(name, type_str, op, operands, line))
        cur.shapes[name] = type_str
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Loop bound from the canonical counted-loop condition: the s32 constant
    compared against the induction variable. Unknown -> 1 (+warn upstream)."""
    consts = []
    for ins in cond.instrs:
        m = re.search(r"constant\((\d+)\)", ins.line)
        if m and ins.type_str.startswith("s32"):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def _call_edges(comp: Computation, comps):
    """Yield (callee_name, multiplier) edges for one computation."""
    for ins in comp.instrs:
        line = ins.line
        if ins.op == "while":
            b = re.search(r"body=%?([\w.\-]+)", line)
            c = re.search(r"condition=%?([\w.\-]+)", line)
            trips = _trip_count(comps[c.group(1)]) if c else 1
            if b:
                yield b.group(1), trips
            if c:
                yield c.group(1), trips + 1
        elif ins.op == "conditional":
            for m in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)([^},]+)", line):
                for name in re.findall(r"%?([\w.\-]+)", m.group(1)):
                    yield name, 1
        else:
            for attr in ("calls", "to_apply"):
                m = re.search(rf"{attr}=%?([\w.\-]+)", line)
                if m:
                    yield m.group(1), 1


def exec_counts(comps, entry):
    """Per-computation execution count via fixed-point over the call DAG."""
    counts = defaultdict(int)
    counts[entry] = 1
    # topological-ish: iterate until stable (call graph is a DAG)
    order = list(comps)
    for _ in range(len(order) + 2):
        new = defaultdict(int)
        new[entry] = 1
        for cname, c in comps.items():
            if counts[cname] == 0:
                continue
            for callee, mult in _call_edges(c, comps):
                if callee in comps:
                    new[callee] += counts[cname] * mult
        if dict(new) == dict(counts):
            break
        counts = new
    return counts


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_dims = arr_dims(ins.type_str)
    if out_dims is None:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if not m or not ins.operands:
        return 0.0
    lhs_shape = comp.shapes.get(ins.operands[0])
    if lhs_shape is None:
        return 0.0
    lhs_dims = arr_dims(lhs_shape)
    if lhs_dims is None:
        return 0.0
    k = 1
    for d in (int(x) for x in m.group(1).split(",") if x):
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * k


def analyze(hlo: str):
    """Returns per-device dict: flops, bytes_accessed, collectives{kind:
    bytes, counts}, loops (diagnostic)."""
    comps, entry = parse_module(hlo)
    fusion_comps = set()
    for c in comps.values():
        for ins in c.instrs:
            m = re.search(r"calls=%?([\w.\-]+)", ins.line)
            if m and ins.op == "fusion":
                fusion_comps.add(m.group(1))
    counts = exec_counts(comps, entry)

    flops = 0.0
    bytes_accessed = 0.0
    by_op = defaultdict(float)
    coll = {k: 0.0 for k in COLLECTIVES}
    coll_counts = {k: 0 for k in COLLECTIVES}
    for cname, c in comps.items():
        n = counts.get(cname, 0)
        if n == 0:
            continue
        in_fusion = cname in fusion_comps
        for ins in c.instrs:
            if ins.op in ("dot", "convolution"):
                flops += n * _dot_flops(ins, c)
            if in_fusion:
                continue  # traffic accounted at the fusion call site
            if ins.op in NO_TRAFFIC or ins.op in CONTROL_FLOW:
                continue
            if ins.op.endswith("-done"):
                continue  # async pair: count the -start only
            b = type_bytes(ins.type_str)
            op_sizes = [type_bytes(c.shapes.get(o, "")) for o in ins.operands]
            if ins.op in ALIASED_UPDATE:
                # in-place update: traffic = the update slice, not the full
                # operand/result buffer (XLA aliases the big buffer)
                upd = op_sizes[1] if len(op_sizes) > 1 else 0
                eff = 2 * upd
            elif ins.op in SLICE_READ:
                eff = 2 * b       # read the slice + write the result
            elif ins.op == "fusion":
                # Streaming model: an elementwise/slicing (kLoop) fusion
                # touches at most O(result) bytes per operand stream — an
                # operand larger than the result is being windowed (dynamic
                # slice / in-place update), not fully read. Reductions are
                # the exception: they legitimately read more than they
                # write, so reduce-rooted fusions charge full operands.
                if "reduce" in ins.name:
                    eff = b + sum(op_sizes)
                elif "dynamic-update-slice" in ins.name and op_sizes \
                        and b >= max(op_sizes):
                    eff = 2 * (sum(op_sizes) - max(op_sizes))  # aliased root
                else:
                    eff = b + sum(min(s, b) for s in op_sizes)
            else:
                eff = b + sum(op_sizes)
            bytes_accessed += n * eff
            by_op[ins.op] += n * eff
            base_op = ins.op.removesuffix("-start")
            if base_op in COLLECTIVES:
                coll[base_op] += n * b
                coll_counts[base_op] += n
    total = sum(coll.values())
    # ring-algorithm wire bytes: all-reduce moves ~2x its payload
    wire = total + coll["all-reduce"]
    top_ops = dict(sorted(by_op.items(), key=lambda kv: -kv[1])[:12])
    return {"flops": flops, "bytes_accessed": bytes_accessed,
            "bytes_by_op_top": top_ops,
            "collectives": {**{k: v for k, v in coll.items()},
                            "counts": coll_counts,
                            "total": total, "wire_bytes": wire}}


_LAYOUT_RE = re.compile(r"\{[^{}]*\}")


def collective_shapes(hlo: str):
    """Multiset of executed collectives as {(kind, result_type): count},
    execution-weighted through the call graph (a collective inside an
    N-trip scan body counts N times). Result types are layout-stripped
    (``f32[4,384]{1,0}`` -> ``f32[4,384]``), so two modules agree here iff
    they move identical cross-device tensor sets — the comparison key for
    the aeriallint tuple-capacity-independence check (ROADMAP: query
    traffic must not scale with log capacity)."""
    comps, entry = parse_module(hlo)
    counts = exec_counts(comps, entry)
    out = defaultdict(int)
    for cname, c in comps.items():
        n = counts.get(cname, 0)
        if n == 0:
            continue
        for ins in c.instrs:
            base = ins.op.removesuffix("-start")
            if base in COLLECTIVES and not ins.op.endswith("-done"):
                out[(base, _LAYOUT_RE.sub("", ins.type_str))] += n
    return dict(out)


def collective_kinds(hlo: str):
    """The set of collective op kinds the module executes at least once."""
    return {kind for (kind, _shape), n in collective_shapes(hlo).items()
            if n > 0}


def io_alias_pairs(hlo: str) -> int:
    """Number of input/output buffer aliases the module declares
    (``input_output_alias={ {0}: (1, {}, may-alias), ... }`` on the
    HloModule header). Donated arguments that XLA actually reuses appear
    here; a donation that fell back to a defensive copy does not — so this
    is the static witness that ``donate_argnums`` took effect. The block
    nests braces (``{0}: (0, {}, ...)``), so it is delimited by brace
    depth, not regex."""
    start = hlo.find("input_output_alias={")
    if start < 0:
        return 0
    i = start + len("input_output_alias=")
    depth = 0
    for j in range(i, len(hlo)):
        if hlo[j] == "{":
            depth += 1
        elif hlo[j] == "}":
            depth -= 1
            if depth == 0:
                return len(re.findall(r"\([^)]*\)", hlo[i:j + 1]))
    return 0
