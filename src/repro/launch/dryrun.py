import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend initialization. 512 host devices let jax.make_mesh
# build the production meshes (16,16) and (2,16,16) on this CPU container.

import argparse        # noqa: E402
import json            # noqa: E402
import re              # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from pathlib import Path  # noqa: E402

import jax             # noqa: E402

from repro.configs.base import SHAPES, get_config, list_configs  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch import inputs as inp  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.model import Model  # noqa: E402
from repro.train import optimizer as optlib  # noqa: E402
from repro.train.train_loop import make_serve_steps, make_train_step  # noqa: E402

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
               "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2)\[([\d,]*)\]")


def _nbytes(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def collective_bytes_per_device(hlo_text: str):
    """Sum operand bytes of every collective op in the (post-SPMD,
    per-device) optimized HLO. Returns {op_kind: bytes} + total."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        for kind in COLLECTIVES:
            # match the op invocation, e.g. "bf16[...] all-gather(bf16[...] %x)"
            m = re.search(rf"= [^=]*\b{kind}(?:-start)?\(", line)
            if not m:
                continue
            args = line[m.end():]
            depth = 1
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        args = args[:i]
                        break
            for dt, dims in _SHAPE_RE.findall(args):
                out[kind] += _nbytes(dt, dims)
            counts[kind] += 1
            break
    out["total"] = sum(out[k] for k in COLLECTIVES)
    out["counts"] = counts
    return out


def analytic_param_bytes(model: Model) -> int:
    params = model.abstract_params()
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               n_micro: int = 8, overrides: dict | None = None):
    """Lower + compile one (arch x shape x mesh) cell. Returns result dict."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    reason = inp.skip_reason(cfg, shape)
    res = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": shape.kind, "seq_len": shape.seq_len,
           "global_batch": shape.global_batch}
    if reason:
        res["status"] = "skipped"
        res["skip_reason"] = reason
        return res

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    t0 = time.time()
    with jax.set_mesh(mesh):
        params_s = model.abstract_params()
        specs = inp.input_specs(cfg, shape)
        if shape.kind == "train":
            # bf16 params (mixed precision) require an fp32 master copy
            opt_cfg = optlib.OptConfig(
                keep_master=cfg.param_dtype_str != "float32")
            opt_s = jax.eval_shape(lambda p: optlib.init_opt_state(opt_cfg, p),
                                   params_s)
            nm = n_micro if shape.global_batch % (n_micro * (32 if multi_pod else 16)) == 0 else 1
            _, sh = make_train_step(model, opt_cfg, mesh, multi_pod=multi_pod,
                                    n_micro=nm)
            jitted = sh["jit_for"](specs["batch"])
            lowered = jitted.lower(params_s, opt_s, specs["batch"])
        elif shape.kind == "prefill":
            _, _, sh = make_serve_steps(model, mesh, multi_pod=multi_pod)
            jitted = sh["jit_prefill"](specs["batch"])
            lowered = jitted.lower(params_s, specs["batch"])
        else:  # decode
            shard_b = inp.batch_shardable(shape, multi_pod)
            from repro.distributed import sharding as shlib
            from repro.models import transformer
            param_sh = shlib.resolve_tree(model.pspecs(), mesh, multi_pod)
            cache_sh = shlib.resolve_tree(
                model.cache_pspecs(multi_pod, shard_batch=shard_b), mesh,
                multi_pod)
            transformer.set_activation_sharding(None)
            in_b = (jax.tree.map(
                lambda x: shlib.batch_sharding(mesh, multi_pod, x.ndim),
                specs["inputs"]) if shard_b else None)
            jitted = jax.jit(model.decode_step,
                             in_shardings=(param_sh, cache_sh, in_b, None),
                             out_shardings=(cache_sh, None),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_s, specs["cache"], specs["inputs"],
                                   specs["pos"])
        res["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        res["compile_s"] = round(time.time() - t1, 1)

    try:
        ca = compiled.cost_analysis()
        res["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float)) and
                                k in ("flops", "bytes accessed",
                                      "bytes accessed output", "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        res["cost_analysis"] = {"error": str(e)}
    try:
        ma = compiled.memory_analysis()
        res["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        res["memory_analysis"] = {"error": str(e)}

    hlo = compiled.as_text()
    # Structural analysis with loop-trip multiplication (hlo_analysis.py):
    # cost_analysis() counts scan bodies once, so it is kept only as a
    # diagnostic; the roofline uses these numbers.
    res["hlo_analysis_per_device"] = hlo_analysis.analyze(hlo)
    res["hlo_lines"] = hlo.count("\n")
    res["param_bytes_global"] = analytic_param_bytes(model)
    res["status"] = "ok"
    return res


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run driver")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--n-micro", type=int, default=8)
    args = ap.parse_args()

    archs = list(list_configs()) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                fp = outdir / f"{tag}.json"
                if fp.exists():
                    print(f"[skip-cached] {tag}")
                    continue
                print(f"[lower+compile] {tag} ...", flush=True)
                try:
                    res = lower_cell(arch, shape, mp, n_micro=args.n_micro)
                except Exception as e:
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "failed", "error": str(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                fp.write_text(json.dumps(res, indent=1))
                status = res["status"]
                extra = ""
                if status == "ok":
                    ha = res.get("hlo_analysis_per_device", {})
                    col = ha.get("collectives", {})
                    extra = (f" flops/dev={ha.get('flops', 0):.3e}"
                             f" coll/dev={col.get('total', 0):.3e}B"
                             f" compile={res.get('compile_s')}s")
                elif status == "failed":
                    extra = " " + res["error"][:200]
                print(f"  -> {status}{extra}", flush=True)
    print(f"done; failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
