"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's forced
512-device host platform to initialize first.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever devices exist locally, as (data, model) — tests/examples."""
    n = jax.device_count()
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
