"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's forced
512-device host platform to initialize first.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever devices exist locally, as (data, model) — tests/examples."""
    n = jax.device_count()
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def make_edge_mesh(n_devices: int | None = None, n_edges: int | None = None):
    """1-D datastore mesh over the logical edge axis ("edge",): each device
    hosts a contiguous block of E / n_devices ground edge servers (the
    federation story — a device plays the role of one edge site's local
    store). ``n_devices`` defaults to every local device; it must divide the
    deployment's ``StoreConfig.n_edges`` — pass ``n_edges`` to validate that
    at construction instead of failing later inside the runtime. Simulate a
    fleet on CPU with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

    The device blocks double as *failure domains*: ``AerialDB.fail_device(d)``
    kills exactly device d's block (``distributed.sharding.device_edge_block``),
    and ``StoreConfig.n_failure_domains = n_devices`` makes placement spread
    every shard's replicas across blocks so that loss is survivable."""
    from repro.distributed.sharding import check_edge_partition
    n = jax.device_count() if n_devices is None else n_devices
    if n_edges is not None:
        check_edge_partition(n_edges, n, "the 1-D edge mesh")
    return jax.make_mesh((n,), ("edge",))


def make_fleet_mesh(n_fleet: int, n_edge_per_fleet: int | None = None,
                    n_edges: int | None = None):
    """2-D datastore mesh ("fleet", "edge"): the cross-host generalization of
    ``make_edge_mesh``. The logical edge axis is partitioned over the axis
    *product*, fleet-major — fleet f's devices host the contiguous edge
    blocks ``f * n_edge_per_fleet .. (f+1) * n_edge_per_fleet - 1`` — so each
    host (or host-group) owns one geographically-distinct fleet partition,
    intra-fleet collectives stay on-host ("edge" axis), and only the narrow
    inter-fleet merge crosses hosts ("fleet" axis). ``make_edge_mesh`` is the
    ``n_fleet == 1`` degenerate case of the same contract.

    ``n_edge_per_fleet`` defaults to ``device_count // n_fleet``. Under
    ``jax.distributed`` (one process per fleet partition — see
    ``init_fleet_processes``), the mesh spans every *global* device; jax's
    default device order enumerates processes major-to-minor, so process p's
    local devices form fleet p exactly when each process contributes
    ``n_edge_per_fleet`` devices. Pass ``n_edges`` to validate divisibility
    at construction."""
    from repro.distributed.sharding import check_edge_partition
    if n_fleet < 1:
        raise ValueError(f"n_fleet={n_fleet} must be >= 1.")
    if n_edge_per_fleet is None:
        n_dev = jax.device_count()
        if n_dev % n_fleet:
            raise ValueError(
                f"n_fleet={n_fleet} does not divide the available "
                f"{n_dev} devices; pass n_edge_per_fleet explicitly.")
        n_edge_per_fleet = n_dev // n_fleet
    if n_edges is not None:
        check_edge_partition(n_edges, n_fleet * n_edge_per_fleet,
                             "the (fleet, edge) mesh")
    return jax.make_mesh((n_fleet, n_edge_per_fleet), ("fleet", "edge"))


def init_fleet_processes(coordinator_address: str, num_processes: int,
                         process_id: int) -> None:
    """``jax.distributed.initialize`` wiring for a multi-process fleet
    runtime: one OS process per fleet partition (paper scale: one physical
    host per edge cluster). Call BEFORE any other jax API touches the
    backend. After this, ``jax.device_count()`` is global and
    ``make_fleet_mesh(num_processes)`` lays each process's local devices out
    as one fleet row, so the "edge" axis collectives stay process-local and
    only the "fleet" axis crosses hosts.

    On CPU backends (the simulated-fleet path driven by
    ``benchmarks/fed_worker.py`` / ``benchmarks/multihost_smoke.py``),
    cross-process collectives need the gloo transport, which is selected
    here; real TPU/GPU backends ignore that knob and use their native
    fabric."""
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):  # older/newer jax without the knob
        pass
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
