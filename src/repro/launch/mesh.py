"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's forced
512-device host platform to initialize first.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever devices exist locally, as (data, model) — tests/examples."""
    n = jax.device_count()
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def make_edge_mesh(n_devices: int | None = None):
    """1-D datastore mesh over the logical edge axis ("edge",): each device
    hosts a contiguous block of E / n_devices ground edge servers (the
    federation story — a device plays the role of one edge site's local
    store). ``n_devices`` defaults to every local device; it must divide the
    deployment's ``StoreConfig.n_edges``. Simulate a fleet on CPU with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

    The device blocks double as *failure domains*: ``AerialDB.fail_device(d)``
    kills exactly device d's block (``distributed.sharding.device_edge_block``),
    and ``StoreConfig.n_failure_domains = n_devices`` makes placement spread
    every shard's replicas across blocks so that loss is survivable."""
    n = jax.device_count() if n_devices is None else n_devices
    return jax.make_mesh((n,), ("edge",))
