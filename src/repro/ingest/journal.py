"""Write-ahead journal for crash-durable ingest (PR 9).

``IngestPipeline`` is host-side state: a process crash mid-flush loses every
accepted-but-unflushed record, and (because the store's device state is not
persisted either) the recovery story for an edge server is "rebuild from the
journal". This module is the minimal durable half of that contract:

* **append-before-ack** — the pipeline appends every ACCEPTED record
  (post-dedup, post-validation) before ``submit`` returns, so any record a
  producer saw acknowledged is on disk;
* **fixed-size binary records** — ``(drone int64, seq int64, row
  float32[width])`` after a magic+width header. Fixed size makes torn tails
  self-describing: a crash mid-append leaves a partial record that
  ``replay`` simply excludes (and reopen truncates) — no checksums or
  framing needed;
* **idempotent replay** — ``replay`` returns the journaled columns for
  re-submission through a fresh pipeline; the pipeline's ``(drone, seq)``
  dedup makes double-replay (or replay over a partially-recovered stream)
  converge instead of double-counting.

The journal is append-only for its lifetime (compaction/checkpointing is a
follow-up — see ROADMAP); ``fsync=True`` trades throughput for
power-loss durability, the default flushes to the OS on every append
(process-crash durable, the chaos model's fault).
"""

from __future__ import annotations

import os
import struct

import numpy as np

__all__ = ["WriteAheadJournal"]

_MAGIC = b"ADBWAL1\x00"
_HEADER = struct.Struct("<I")          # tuple width, after the magic


class WriteAheadJournal:
    """Append-only (drone, seq, row) record log with torn-tail recovery.

    Args:
      path:  journal file; created (with header) if absent, validated and
             truncated to the last whole record if it exists.
      width: the store's tuple width (``StoreConfig.tuple_width``) — the
             float32 row length per record. Reopening with a different
             width raises instead of silently mis-framing.
      fsync: fsync after every append (power-loss durability); default
             False flushes to the OS (process-crash durability).
    """

    def __init__(self, path, width: int, *, fsync: bool = False):
        self.path = os.fspath(path)
        self.width = int(width)
        self.fsync = bool(fsync)
        self._rec = np.dtype([("drone", "<i8"), ("seq", "<i8"),
                              ("row", "<f4", (self.width,))])
        header = _MAGIC + _HEADER.pack(self.width)
        size = os.path.getsize(self.path) if os.path.exists(self.path) else 0
        if size < len(header):
            # Fresh journal (or a crash tore even the header): start clean.
            with open(self.path, "wb") as f:
                f.write(header)
        else:
            with open(self.path, "rb") as f:
                head = f.read(len(header))
            if head[:len(_MAGIC)] != _MAGIC:
                raise ValueError(
                    f"{self.path} is not an AerialDB WAL (bad magic).")
            (w,) = _HEADER.unpack(head[len(_MAGIC):])
            if w != self.width:
                raise ValueError(
                    f"{self.path} was written with tuple width {w}, but "
                    f"this store has width {self.width}: replaying it here "
                    "would mis-frame every record.")
            torn = (size - len(header)) % self._rec.itemsize
            if torn:
                # Crash mid-append: drop the partial trailing record so
                # subsequent appends stay frame-aligned.
                with open(self.path, "r+b") as f:
                    f.truncate(size - torn)
        self._f = open(self.path, "ab")
        self._n = ((os.path.getsize(self.path) - len(header))
                   // self._rec.itemsize)

    @property
    def n_records(self) -> int:
        """Whole records on disk (torn tails excluded)."""
        return self._n

    @property
    def itemsize(self) -> int:
        """On-disk bytes per record (the torn-tail framing unit)."""
        return self._rec.itemsize

    def append(self, drone, seq, rows) -> int:
        """Append one batch of accepted records; returns the batch size.
        The write is flushed to the OS before returning (fsynced when the
        journal was opened with ``fsync=True``)."""
        drone = np.asarray(drone, np.int64).reshape(-1)
        n = drone.shape[0]
        buf = np.empty(n, self._rec)
        buf["drone"] = drone
        buf["seq"] = np.asarray(seq, np.int64).reshape(-1)
        buf["row"] = np.asarray(rows, np.float32).reshape(n, self.width)
        self._f.write(buf.tobytes())
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._n += n
        return n

    def replay(self):
        """Read every whole record back: ``(drone (N,), seq (N,), rows
        (N, width), info)`` — bit-exact copies of what was appended (NaN
        partial-payload channels included). A torn tail (crash mid-append)
        is excluded and reported in ``info["torn_bytes"]``; re-submitting
        the result through a pipeline is idempotent by (drone, seq)
        dedup."""
        self._f.flush()
        with open(self.path, "rb") as f:
            data = f.read()
        body = data[len(_MAGIC) + _HEADER.size:]
        item = self._rec.itemsize
        n = len(body) // item
        recs = np.frombuffer(body[:n * item], self._rec)
        return (recs["drone"].copy(), recs["seq"].copy(),
                recs["row"].copy(),
                {"records": int(n), "torn_bytes": int(len(body) - n * item)})

    def close(self) -> None:
        self._f.close()

    def __enter__(self) -> "WriteAheadJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
