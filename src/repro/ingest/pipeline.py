"""``IngestPipeline``: the streaming front door over an ``AerialDB`` session.

The paper's headline setting (§4.4, D400) is hundreds of drones offloading
telemetry to edge servers *as it arrives* — ragged per-drone records at
arbitrary rates, with duplicates, drops, and partial payloads — while the
store's runtimes want clean, device-shaped ``(B, R, 3+V)`` shard batches.
This module is the production shape between the two (ROADMAP open item 1,
the Wingxtra fleet-backend pattern):

* **submit** — validate + dedup records by ``(drone_id, seq)`` into a
  pending columnar buffer, with bounded backpressure and exact counters
  (``accepted`` / ``duplicate`` / ``partial`` / ``dropped``). Out-of-order
  and gappy seq streams are first-class: a gap leaves per-drone "holes"
  that late arrivals may still fill; re-sent seqs are duplicates.
* **flush** — coalesce pending records into shards (``coalesce.py``) and
  drive them through ``AerialDB.insert`` / ``ingest_rounds``. Dispatches
  are **asynchronous**: JAX returns control as soon as the computation is
  enqueued, so batch k+1's host-side assembly (sorting, grouping, meta
  derivation) overlaps batch k's donated-state device scan — the classic
  double buffer — and ``jax.block_until_ready`` is called once, at the
  flush boundary, which is also where per-record **ingest-to-queryable
  latency** (submit wall-time -> flush-complete wall-time) is measured.
* **latest** — the store's O(drones) hot cache (``AerialDB.latest()``)
  overlaid with still-pending records, so "newest position per drone"
  includes in-flight telemetry the device has not seen yet.

Counter reconciliation (the CI gate): ``accepted == flushed_records +
pending`` at all times, and after a drain-flush on an all-alive store,
``sum(tup_count) == flushed_records * replication`` — every accepted record
is on every replica, exactly once.

Fault tolerance (PR 9): each flush dispatch runs under bounded
**retry-with-backoff** — a ``TransientDispatchError`` (dropped RPC on the
intermittent UAV-edge link; injected by the chaos engine via
``fault_hook``) is retried up to ``max_retries`` times with exponential
backoff, and a chunk that exhausts its budget has its records returned to
the pending buffer (counters ``retries`` / ``gave_up``), so the
``accepted == flushed + pending`` invariant survives every outcome. An
optional **write-ahead journal** (``journal=``) appends accepted records
before ``submit`` acks; after a crash (``PipelineCrash`` mid-flush), a
fresh pipeline's :meth:`replay_journal` re-submits the log — idempotent by
the same ``(drone, seq)`` dedup — so no acknowledged record is ever lost.
A wall-clock **flush scheduler** (``flush_interval_s`` + :meth:`maybe_flush`)
and a non-blocking post-flush **fan-out hook** (``on_flush=``, error-
isolated) complete the production surface.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.ingest.coalesce import group_shards, plan_chunks
from repro.ingest.journal import WriteAheadJournal
from repro.ingest.latest import overlay_latest

__all__ = ["IngestPipeline", "PipelineCrash", "TransientDispatchError"]


class TransientDispatchError(RuntimeError):
    """A flush dispatch failed BEFORE mutating the store (dropped RPC,
    momentary link loss): safe to retry. Raised by transports or injected
    by the chaos engine through ``IngestPipeline.fault_hook``."""


class PipelineCrash(RuntimeError):
    """Injected mid-flush process crash (chaos engine): deliberately NOT
    caught by the retry loop — it propagates out of ``flush`` and leaves
    the pipeline in the torn state a real crash would. Recovery is a fresh
    pipeline + :meth:`IngestPipeline.replay_journal`."""

# Per-drone seq gaps leave "holes" a late arrival may still fill. Hole sets
# are bounded per drone: a gap wider than this is treated as permanent loss
# (later arrivals inside it count as duplicates) instead of unbounded state.
_MAX_HOLES_PER_DRONE = 4096


class IngestPipeline:
    """Async telemetry queue + coalescer + latest overlay over one session.

    Args:
      db: the ``AerialDB`` session to feed (either runtime).
      max_pending: backpressure bound on buffered records; a ``submit``
        whose batch would exceed it has its tail dropped (counted).
      batch_shards: device batch size B for full shards; defaults to the
        largest power of two with ``B * records_per_shard <=
        tuple_capacity`` (capped at 256) so a batch can never wrap an
        edge ring within one insert step.
      journal: optional write-ahead journal — a path (opened as a
        ``WriteAheadJournal`` with the store's tuple width) or an already-
        open journal. Accepted records are appended before ``submit``
        returns; ``replay_journal`` on a fresh pipeline recovers them.
      journal_fsync: fsync the journal on every append (power-loss
        durability) when ``journal`` is given as a path.
      flush_interval_s: arm the wall-clock flush scheduler — see
        :meth:`maybe_flush`. None (default) leaves flushing fully manual.
      on_flush: post-flush fan-out callback ``cb(summary_dict)``, invoked
        after local storage whenever a flush shipped records. Error-
        isolated: a raising callback increments ``on_flush_errors`` and
        never poisons the flush.
      max_retries: bounded retry budget per dispatch on
        ``TransientDispatchError`` (0 disables retry).
      backoff_s / backoff_factor: exponential backoff schedule between
        retries (``backoff_s * backoff_factor**attempt``).
      sleep: injectable sleep (tests/chaos pass a no-op to keep seeded
        runs deterministic and fast).
    """

    def __init__(self, db, max_pending: int = 1 << 20,
                 batch_shards: Optional[int] = None, *,
                 journal=None, journal_fsync: bool = False,
                 flush_interval_s: Optional[float] = None,
                 on_flush: Optional[Callable[[dict], None]] = None,
                 max_retries: int = 4, backoff_s: float = 0.01,
                 backoff_factor: float = 2.0,
                 sleep: Callable[[float], None] = time.sleep):
        cfg = db.cfg
        self.db = db
        self.width = cfg.tuple_width
        self.r_full = cfg.records_per_shard
        self.max_pending = max_pending
        if batch_shards is None:
            batch_shards = 1
            while (batch_shards * 2 * self.r_full <= cfg.tuple_capacity
                   and batch_shards * 2 <= 256):
                batch_shards *= 2
        if batch_shards * self.r_full > cfg.tuple_capacity:
            raise ValueError(
                f"batch_shards={batch_shards} x records_per_shard="
                f"{self.r_full} exceeds tuple_capacity={cfg.tuple_capacity}: "
                "one edge could wrap its ring within a single insert step. "
                "Lower batch_shards or raise tuple_capacity.")
        self.batch_shards = batch_shards
        # Pending columnar buffer: list of (drone, seq, rows, t_submit).
        self._pend: list = []
        self._n_pending = 0
        # Dedup state: per-drone max accepted seq (grown on demand) + holes.
        self._max_seq = np.full(0, -1, np.int64)
        self._holes: Dict[int, set] = {}
        self._shard_seq: Dict[int, int] = {}
        self.counters = {"accepted": 0, "duplicate": 0, "partial": 0,
                         "dropped": 0, "dropped_malformed": 0,
                         "dropped_backpressure": 0, "flushed_records": 0,
                         "flushed_shards": 0, "flushes": 0,
                         "retries": 0, "gave_up": 0, "replayed": 0,
                         "on_flush_errors": 0}
        self.last_flush: Optional[dict] = None
        self.journal = (WriteAheadJournal(journal, self.width,
                                          fsync=journal_fsync)
                        if journal is not None
                        and not isinstance(journal, WriteAheadJournal)
                        else journal)
        self.flush_interval_s = flush_interval_s
        self.on_flush = on_flush
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.backoff_factor = float(backoff_factor)
        self._sleep = sleep
        # Chaos/transport injection point: ``hook(pipeline, attempt)`` runs
        # before every device dispatch attempt; raising
        # TransientDispatchError exercises the retry path, PipelineCrash
        # the crash path. None in production with a reliable local device.
        self.fault_hook: Optional[Callable] = None
        self._replaying = False
        # maybe_flush deadline — armed lazily from the first call's clock,
        # so callers driving a synthetic ``now`` never mix clocks.
        self._flush_deadline: Optional[float] = None

    # -- submit --------------------------------------------------------------

    def _grow(self, n: int) -> None:
        if n > self._max_seq.shape[0]:
            grown = np.full(max(n, 2 * self._max_seq.shape[0]), -1, np.int64)
            grown[:self._max_seq.shape[0]] = self._max_seq
            self._max_seq = grown

    def submit(self, records) -> dict:
        """Queue ragged per-drone records; returns the live counters dict.

        ``records`` is a sequence of ``(drone_id, seq, t, lat, lon,
        values...)`` tuples (trailing values may be missing or None ->
        NaN-filled, counted ``partial``) or dicts with those keys (``values``
        a sequence). For bulk submission use :meth:`submit_arrays`.
        """
        n = len(records)
        v = self.width - 3
        drone = np.empty(n, np.int64)
        seq = np.empty(n, np.int64)
        cols = np.full((n, self.width), np.nan, np.float64)
        for i, rec in enumerate(records):
            if isinstance(rec, dict):
                flat = (rec["drone_id"], rec["seq"], rec["t"], rec["lat"],
                        rec["lon"], *(rec.get("values") or ()))
            else:
                flat = tuple(rec)
            if len(flat) > 5 + v:
                raise ValueError(
                    f"record {i} carries {len(flat) - 5} values but the "
                    f"store is configured for n_values={v}.")
            try:
                drone[i] = int(flat[0])
                seq[i] = int(flat[1])
                cols[i, :len(flat) - 2] = [float(x) for x in flat[2:]]
            except (TypeError, ValueError):
                drone[i] = -1        # malformed -> dropped below
        return self.submit_arrays(drone, seq, cols[:, 0], cols[:, 1],
                                  cols[:, 2], cols[:, 3:])

    def submit_arrays(self, drone, seq, t, lat, lon, values=None) -> dict:
        """Vectorized submit: (N,) id/seq/t/lat/lon arrays + optional
        (N, <=V) values (missing columns NaN-fill -> ``partial``)."""
        drone = np.asarray(drone, np.int64).reshape(-1)
        n = drone.shape[0]
        seq = np.asarray(seq, np.int64).reshape(-1)
        rows = np.full((n, self.width), np.nan, np.float32)
        rows[:, 0] = np.asarray(t, np.float32)
        rows[:, 1] = np.asarray(lat, np.float32)
        rows[:, 2] = np.asarray(lon, np.float32)
        if values is not None:
            values = np.asarray(values, np.float32).reshape(n, -1)
            if values.shape[1] > self.width - 3:
                raise ValueError(
                    f"values has {values.shape[1]} channels but the store is "
                    f"configured for n_values={self.width - 3}.")
            rows[:, 3:3 + values.shape[1]] = values

        # Malformed: broken id/seq or non-finite coordinates (value-channel
        # NaNs are partial payloads and fine; a NaN t/lat/lon would poison
        # placement + slicing).
        well = ((drone >= 0) & (seq >= 0)
                & np.isfinite(rows[:, :3]).all(axis=1))
        self.counters["dropped_malformed"] += int(n - well.sum())

        # Backpressure: bounded pending buffer; the batch's tail past the
        # budget is dropped (conservatively — duplicates in the kept head
        # still count against it).
        room = self.max_pending - self._n_pending
        kept = np.nonzero(well)[0]
        if kept.size > room:
            self.counters["dropped_backpressure"] += int(kept.size - room)
            kept = kept[:room]
        self.counters["dropped"] = (self.counters["dropped_malformed"]
                                    + self.counters["dropped_backpressure"])
        if kept.size == 0:
            return dict(self.counters)
        drone, seq, rows = drone[kept], seq[kept], rows[kept]
        self._grow(int(drone.max()) + 1)

        # Dedup by (drone, seq). Sorted view; within-batch re-sends keep the
        # first occurrence. Fast path: a drone whose batch records are
        # exactly the contiguous run max_seq+1.. needs no hole bookkeeping.
        order = np.lexsort((seq, drone))
        d_s, s_s = drone[order], seq[order]
        first = np.r_[True, d_s[1:] != d_s[:-1]]
        prev = np.where(first, self._max_seq[d_s], np.r_[np.int64(-1), s_s[:-1]])
        contig = s_s == prev + 1
        grp = np.cumsum(first) - 1
        all_contig = np.logical_and.reduceat(contig, np.nonzero(first)[0])
        accept = np.zeros(d_s.shape[0], bool)
        fast = all_contig[grp]
        accept[fast] = True
        np.maximum.at(self._max_seq, d_s[fast], s_s[fast])
        for i in np.nonzero(~fast)[0]:    # slow path: dups / gaps / refills
            did, s = int(d_s[i]), int(s_s[i])
            top = int(self._max_seq[did])
            if s > top:
                holes = self._holes.setdefault(did, set())
                gap = s - top - 1
                if gap and len(holes) + gap <= _MAX_HOLES_PER_DRONE:
                    holes.update(range(top + 1, s))
                self._max_seq[did] = s
                accept[i] = True
            elif s in self._holes.get(did, ()):
                self._holes[did].discard(s)
                accept[i] = True
            else:
                self.counters["duplicate"] += 1
        acc_idx = order[accept]
        if acc_idx.size:
            a_rows = rows[acc_idx]
            if self.journal is not None and not self._replaying:
                # Durability ordering: on disk BEFORE the ack (the returned
                # counters). Replayed records are already journaled.
                self.journal.append(drone[acc_idx], seq[acc_idx], a_rows)
            self.counters["partial"] += int(
                np.isnan(a_rows[:, 3:]).any(axis=1).sum())
            self._pend.append((drone[acc_idx], seq[acc_idx], a_rows,
                               np.full(acc_idx.size, time.monotonic())))
            self._n_pending += acc_idx.size
            self.counters["accepted"] += int(acc_idx.size)
        return dict(self.counters)

    # -- flush ---------------------------------------------------------------

    @property
    def pending(self) -> int:
        return self._n_pending

    def _dispatch(self, fn, *args) -> bool:
        """One device dispatch under the bounded retry-with-backoff loop.

        ``TransientDispatchError`` (from ``fault_hook`` or a raising
        transport) is retried up to ``max_retries`` times, sleeping
        ``backoff_s * backoff_factor**attempt`` between attempts; the retry
        contract assumes the failed dispatch did NOT mutate the store (the
        chaos injector raises before the device call; a real transport must
        fail atomically). Returns False when the budget is exhausted
        (``gave_up`` counted — the caller returns the chunk's records to
        pending). ``PipelineCrash`` is deliberately not caught."""
        attempt = 0
        while True:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(self, attempt)
                fn(*args)
                return True
            except TransientDispatchError:
                if attempt >= self.max_retries:
                    self.counters["gave_up"] += 1
                    return False
                self.counters["retries"] += 1
                self._sleep(self.backoff_s * self.backoff_factor ** attempt)
                attempt += 1

    def flush(self, drain: bool = False, block: bool = True) -> dict:
        """Coalesce pending records into shards and ingest them.

        Full ``records_per_shard`` groups always ship; ``drain=True`` also
        ships trailing partial groups (batched by size). Device dispatches
        are async — host assembly of chunk k+1 overlaps chunk k's scan —
        and ``block=True`` ends with one ``jax.block_until_ready`` at the
        flush boundary, stamping per-record ingest-to-queryable latency.
        Each dispatch runs under :meth:`_dispatch` retry; a chunk that
        exhausts its retry budget has its records returned to the pending
        buffer (``accepted == flushed + pending`` holds through give-ups;
        a later flush re-coalesces them).

        Returns a summary dict (also kept on ``last_flush``): shards/records
        flushed, dispatch count, this flush's ``retries`` / ``gave_up`` /
        ``returned_records``, and (when blocking) ``latency_s`` — the
        flushed records' submit->queryable wall times. ``on_flush`` fires
        (error-isolated) after local storage whenever records shipped.
        """
        retries0 = self.counters["retries"]
        gave0 = self.counters["gave_up"]
        if not self._pend:
            out = {"flushed_shards": 0, "flushed_records": 0,
                   "dispatches": 0, "retries": 0, "gave_up": 0,
                   "returned_records": 0, "latency_s": np.empty(0)}
            self.last_flush = out
            return out
        drone = np.concatenate([p[0] for p in self._pend])
        seq = np.concatenate([p[1] for p in self._pend])
        rows = np.concatenate([p[2] for p in self._pend])
        tsub = np.concatenate([p[3] for p in self._pend])
        batches, leftover = group_shards(drone, seq, rows, self.r_full,
                                         self._shard_seq, drain)
        n_shards = n_records = dispatches = 0
        flushed_tsub = []
        failed_idx = []
        for k, (pay, meta, idx) in sorted(batches.items()):
            b_total = pay.shape[0]
            b_max = max(self.batch_shards * self.r_full // max(k, 1), 1)
            off = 0
            sizes = plan_chunks(b_total, b_max)
            i = 0
            while i < len(sizes):
                # Equal-size run -> ONE fused multi-round scan dispatch.
                j = i
                while j < len(sizes) and sizes[j] == sizes[i]:
                    j += 1
                nb, b = j - i, sizes[i]
                sl = slice(off, off + nb * b)
                pays = pay[sl].reshape(nb, b, k, self.width)
                metas = type(meta)(*(np.asarray(f)[sl].reshape(nb, b)
                                     for f in meta))
                if nb == 1:
                    ok = self._dispatch(
                        self.db.insert, pays[0],
                        type(meta)(*(f[0] for f in metas)))
                else:
                    ok = self._dispatch(self.db.ingest_rounds, pays, metas)
                dispatches += 1
                chunk_idx = np.asarray(idx)[sl].reshape(-1)
                if ok:
                    n_shards += nb * b
                    n_records += chunk_idx.size
                    flushed_tsub.append(tsub[chunk_idx])
                else:
                    failed_idx.append(chunk_idx)
                off += nb * b
                i = j
        # Keep the leftover (sub-shard) tails AND any gave-up chunks'
        # records pending. (Gave-up shards already consumed their sid_lo
        # numbers — the re-flush assigns fresh ones, which only needs sids
        # to stay unique, not dense.)
        keep = (np.concatenate([leftover] + failed_idx)
                if failed_idx else leftover)
        self._pend = ([(drone[keep], seq[keep], rows[keep], tsub[keep])]
                      if keep.size else [])
        self._n_pending = int(keep.size)
        self.counters["flushed_shards"] += n_shards
        self.counters["flushed_records"] += n_records
        self.counters["flushes"] += 1
        out = {"flushed_shards": n_shards, "flushed_records": n_records,
               "dispatches": dispatches,
               "retries": self.counters["retries"] - retries0,
               "gave_up": self.counters["gave_up"] - gave0,
               "returned_records": int(sum(f.size for f in failed_idx)),
               "latency_s": np.empty(0)}
        if block:
            jax.block_until_ready(self.db.state.tup_count)
            done = time.monotonic()
            if flushed_tsub:
                out["latency_s"] = done - np.concatenate(flushed_tsub)
        self.last_flush = out
        if self.on_flush is not None and n_records:
            # Fan-out AFTER local storage; error-isolated — a raising
            # subscriber never poisons the flush.
            try:
                self.on_flush(out)
            except Exception:
                self.counters["on_flush_errors"] += 1
        return out

    def maybe_flush(self, now: Optional[float] = None, *,
                    drain: bool = False, block: bool = True
                    ) -> Optional[dict]:
        """Wall-clock flush scheduler: flush iff ``now`` has passed the
        armed deadline, then re-arm ``flush_interval_s`` ahead.

        The deadline arms lazily on the first call (from ITS clock), so
        callers driving a synthetic ``now`` never race the constructor's
        wall clock; ``now=None`` reads ``time.monotonic()``. Returns the
        flush summary — with the triggering ``deadline`` and ``late_s``
        stamped into it (and thus into ``last_flush``) — when a flush ran,
        else None. Requires ``flush_interval_s``."""
        if self.flush_interval_s is None:
            raise ValueError(
                "maybe_flush() needs a flush interval: open the pipeline "
                "with IngestPipeline(db, flush_interval_s=...) — or call "
                "flush() directly for manual control.")
        if now is None:
            now = time.monotonic()
        if self._flush_deadline is None:
            self._flush_deadline = now + self.flush_interval_s
        if now < self._flush_deadline:
            return None
        deadline = self._flush_deadline
        out = self.flush(drain=drain, block=block)
        out["deadline"] = deadline
        out["late_s"] = now - deadline
        self._flush_deadline = now + self.flush_interval_s
        return out

    # -- journal recovery ----------------------------------------------------

    def replay_journal(self, batch: int = 8192) -> dict:
        """Re-submit every journaled record through the normal ``submit``
        path (crash recovery: fresh pipeline + fresh/rebuilt session +
        replay). Idempotent: the ``(drone, seq)`` dedup absorbs records
        that already made it in (double replay accepts nothing twice).
        Replay respects backpressure by flushing whenever the pending
        buffer could not absorb the next batch. Returns a summary dict;
        the accepted delta is also counted in ``counters['replayed']``."""
        if self.journal is None:
            raise ValueError(
                "no journal to replay: open the pipeline with journal=... "
                "(a path or WriteAheadJournal).")
        d, s, r, info = self.journal.replay()
        acc0 = self.counters["accepted"]
        self._replaying = True
        try:
            for i in range(0, d.shape[0], batch):
                if self._n_pending + batch > self.max_pending:
                    self.flush()
                j = min(i + batch, d.shape[0])
                self.submit_arrays(d[i:j], s[i:j], r[i:j, 0], r[i:j, 1],
                                   r[i:j, 2], r[i:j, 3:])
        finally:
            self._replaying = False
        accepted = self.counters["accepted"] - acc0
        self.counters["replayed"] += accepted
        return {"journal_records": info["records"],
                "torn_bytes": info["torn_bytes"], "accepted": accepted,
                "already_seen": info["records"] - accepted}

    def close(self) -> None:
        """Close the journal file handle (the pipeline itself is
        stateless on disk beyond it)."""
        if self.journal is not None:
            self.journal.close()

    # -- latest overlay ------------------------------------------------------

    def latest(self):
        """``(record (D, W), valid (D,))`` numpy — the store's hot cache
        with still-pending (in-flight) records overlaid, so the answer is
        exact over everything ever *submitted*, not just flushed."""
        res = self.db.latest()
        record = np.array(res.record)
        valid = np.array(res.valid)
        for d, _s, rows, _t in self._pend:
            overlay_latest(record, valid, d, rows[:, 0], rows)
        return record, valid

    # -- reconciliation ------------------------------------------------------

    def reconcile(self) -> dict:
        """Exact counter reconciliation (the fig18/fig19 CI gate).

        Two legs, reported separately so chaos runs can gate each where it
        holds:

        * ``counters_ok`` — ``accepted == flushed_records + pending``.
          Holds at EVERY step, through retries, give-ups (gave-up chunks
          return to pending), journal replay, partitions, and outages.
        * ``stored_ok`` — ``sum(tup_count) == flushed_records *
          replication``. Holds at convergence points: an all-effective
          store that never wrapped, reclaimed mid-degradation, or dropped —
          including after a full heal/recover + repair, where every shard
          is back to exactly ``replication`` canonical copies. Mid-outage
          it can legitimately over-count (stale frozen copies on dead
          edges await reclamation).

        ``ok`` is their conjunction. Returns the evidence dict; raises
        nothing (callers assert)."""
        c = self.counters
        stored = int(np.asarray(self.db.state.tup_count).sum())
        expect = c["flushed_records"] * self.db.cfg.replication
        counters_ok = c["accepted"] == c["flushed_records"] + self._n_pending
        stored_ok = stored == expect
        return {"ok": counters_ok and stored_ok, "counters_ok": counters_ok,
                "stored_ok": stored_ok, "accepted": c["accepted"],
                "flushed_records": c["flushed_records"],
                "pending": self._n_pending, "stored_tuples": stored,
                "expected_tuples": expect,
                "duplicate": c["duplicate"], "partial": c["partial"],
                "dropped": c["dropped"], "retries": c["retries"],
                "gave_up": c["gave_up"], "replayed": c["replayed"]}
