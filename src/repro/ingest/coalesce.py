"""Batch coalescing for the streaming ingest pipeline.

Accepted telemetry records are ragged — per-drone, arbitrary rates, gaps —
but the device runtimes want shards: ``(B, R, 3+V)`` payloads plus a
``ShardMeta`` per shard, at a *small set of static shapes* (every distinct
``(B, R)`` is a separate XLA compilation). This module owns that reshaping:

* ``group_shards``: stable-sort pending records by ``(drone, seq)`` and cut
  each drone's run into consecutive ``records_per_shard``-sized groups —
  one shard each, ``sid = (drone, per-drone emitted-shard counter)``, bbox
  and time range derived from the group. Seq gaps inside a group are
  tolerated (drops are data loss, not shard loss); the trailing partial
  group per drone stays pending unless draining, in which case partial
  groups are emitted batched BY SIZE (one ``(B_k, k, W)`` payload per
  distinct group size k, keeping the compile-cache bounded).
* ``plan_chunks``: split B shards into device batches — full
  ``batch_shards``-sized chunks (stacked into ONE fused ``ingest_rounds``
  scan) plus a descending powers-of-two tail, so a streaming session
  compiles O(log B) insert shapes total instead of one per flush size.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.api import ShardMeta

__all__ = ["plan_chunks", "group_shards"]


def plan_chunks(n: int, b_max: int) -> List[int]:
    """Batch sizes covering ``n`` shards: ``n // b_max`` full chunks, then a
    descending powers-of-two decomposition of the remainder — every size
    emitted is ``b_max`` or a power of two < ``b_max``, so the set of
    compiled insert shapes stays O(log b_max) across a whole session."""
    if n < 0 or b_max < 1:
        raise ValueError(f"plan_chunks needs n >= 0, b_max >= 1 "
                         f"(got n={n}, b_max={b_max}).")
    sizes = [b_max] * (n // b_max)
    rem = n % b_max
    p = 1 << max(rem.bit_length() - 1, 0)
    while rem:
        if p <= rem:
            sizes.append(p)
            rem -= p
        p >>= 1
    return sizes


def group_shards(drone, seq, rows, records_per_shard: int,
                 shard_seq: Dict[int, int], drain: bool):
    """Cut sorted pending records into shard groups.

    Args:
      drone / seq: (N,) int arrays (any order; stably sorted here).
      rows:        (N, W) float32 records.
      records_per_shard: full-shard group size R.
      shard_seq:   per-drone emitted-shard counter, MUTATED as sids are
                   assigned (sid_lo must stay unique per drone across
                   flushes).
      drain:       emit trailing partial (< R) groups too.

    Returns ``(batches, leftover)``: ``batches`` maps group size k to a
    ``(payload (B_k, k, W) float32, ShardMeta numpy fields, submit_order
    (B_k, k) int)`` triple (``submit_order`` carries each record's original
    position, for latency accounting); ``leftover`` is the index array of
    records kept pending (empty when draining).
    """
    drone = np.asarray(drone)
    seq = np.asarray(seq)
    n = drone.shape[0]
    order = np.lexsort((seq, drone))
    d_s = drone[order]
    # Group boundaries: starts of each drone's run.
    starts = np.r_[0, np.nonzero(d_s[1:] != d_s[:-1])[0] + 1, n]
    per_size: Dict[int, List[Tuple[np.ndarray, int]]] = {}
    leftover: List[np.ndarray] = []
    r = records_per_shard
    for a, b in zip(starts[:-1], starts[1:]):
        did = int(d_s[a])
        run = order[a:b]
        n_full = (b - a) // r
        for g in range(n_full):
            per_size.setdefault(r, []).append((run[g * r:(g + 1) * r], did))
        tail = run[n_full * r:]
        if tail.size == 0:
            continue
        if drain:
            per_size.setdefault(tail.size, []).append((tail, did))
        else:
            leftover.append(tail)
    batches = {}
    for k, groups in sorted(per_size.items()):
        idx = np.stack([g for g, _ in groups])                   # (B_k, k)
        dids = np.asarray([d for _, d in groups], np.int32)
        pay = rows[idx].astype(np.float32)                       # (B_k, k, W)
        lo = np.empty(len(groups), np.int32)
        for i, did in enumerate(dids):
            lo[i] = shard_seq.get(int(did), 0)
            shard_seq[int(did)] = int(lo[i]) + 1
        meta = ShardMeta(
            sid_hi=dids, sid_lo=lo,
            lat0=pay[:, :, 1].min(1).astype(np.float32),
            lat1=pay[:, :, 1].max(1).astype(np.float32),
            lon0=pay[:, :, 2].min(1).astype(np.float32),
            lon1=pay[:, :, 2].max(1).astype(np.float32),
            t0=pay[:, :, 0].min(1).astype(np.float32),
            t1=pay[:, :, 0].max(1).astype(np.float32))
        batches[k] = (pay, meta, idx)
    left = (np.concatenate(leftover) if leftover
            else np.empty(0, np.int64))
    return batches, left
