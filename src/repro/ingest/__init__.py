"""Streaming ingest subsystem (PR 8): the host-side front door that turns
ragged, unreliable per-drone telemetry into the store's device-shaped shard
batches — async submit queue with (drone, seq) dedup and backpressure,
double-buffered batch coalescing over ``AerialDB.insert``/``ingest_rounds``,
and the latest-per-drone overlay completing the O(drones) hot path.

Layering contract: this package sits strictly ABOVE ``repro.api`` (it only
ever drives the facade) and is pure host-side numpy + dispatch — no jit
bodies of its own, so the differential harness covering the facade covers
every pipeline flush too.

    from repro.api import AerialDB
    from repro.ingest import IngestPipeline

    pipe = IngestPipeline(AerialDB.open(cfg, max_drones=D))
    pipe.submit([(drone_id, seq, t, lat, lon, *values), ...])
    pipe.flush()                       # full shards -> device, async
    record, valid = pipe.latest()      # store cache ∪ in-flight records
"""

from repro.ingest.coalesce import group_shards, plan_chunks
from repro.ingest.journal import WriteAheadJournal
from repro.ingest.latest import latest_oracle, overlay_latest
from repro.ingest.pipeline import (IngestPipeline, PipelineCrash,
                                   TransientDispatchError)

__all__ = ["IngestPipeline", "PipelineCrash", "TransientDispatchError",
           "WriteAheadJournal", "group_shards", "plan_chunks",
           "latest_oracle", "overlay_latest"]
