"""Host-side latest-per-drone oracle + overlay for the streaming pipeline.

The device-side hot cache (``core.datastore._update_latest``, served by
``AerialDB.latest()``) answers "newest record per drone" in O(drones) from
replicated state. This module is its *specification*: a brute-force numpy
oracle over an explicit record set, used by the property tests to prove the
cache equals "max-t tuple per drone over the retained window ∪ in-flight
records", and by ``IngestPipeline.latest()`` to overlay still-pending
(in-flight) records onto the store's cache answer.

Tie rule (shared with the device cache): among records of one drone with the
same maximal ``t``, the **latest arrival wins** — last position in the
record stream for the oracle, highest flat batch index for the device
scatter, pending-over-stored for the overlay.
"""

from __future__ import annotations

import numpy as np

__all__ = ["latest_oracle", "overlay_latest"]


def latest_oracle(drone_ids, t, rows, max_drones: int):
    """Brute-force latest-per-drone over an explicit record set.

    Args:
      drone_ids: (N,) int drone id per record.
      t:         (N,) float timestamp per record.
      rows:      (N, W) float full records (t, lat, lon, values...).
      max_drones: cache size D; ids outside [0, D) are ignored.

    Returns ``(record (D, W) float32, valid (D,) bool)`` — for each drone,
    the max-t record (later stream position wins t ties; non-finite t
    excluded), zeros where the drone never appears.
    """
    drone_ids = np.asarray(drone_ids).reshape(-1)
    t = np.asarray(t, np.float32).reshape(-1)
    rows = np.asarray(rows, np.float32).reshape(t.shape[0], -1)
    record = np.zeros((max_drones, rows.shape[1]), np.float32)
    valid = np.zeros((max_drones,), bool)
    best_t = np.full((max_drones,), -np.inf, np.float32)
    ok = np.isfinite(t) & (drone_ids >= 0) & (drone_ids < max_drones)
    for i in np.nonzero(ok)[0]:
        d = int(drone_ids[i])
        if t[i] >= best_t[d]:
            best_t[d] = t[i]
            record[d] = rows[i]
            valid[d] = True
    return record, valid


def overlay_latest(record, valid, drone_ids, t, rows):
    """Overlay in-flight records onto a store cache answer, IN PLACE.

    ``record``/``valid`` are host copies of ``LatestResult.record`` /
    ``.valid``; pending records win ties against stored ones (they are the
    later arrival by definition — still unflushed). Returns (record, valid).
    """
    d_max = record.shape[0]
    pend_rec, pend_valid = latest_oracle(drone_ids, t, rows, d_max)
    stored_t = np.where(valid, record[:, 0], -np.inf)
    pend_t = np.where(pend_valid, pend_rec[:, 0], -np.inf)
    win = pend_valid & (pend_t >= stored_t)
    record[win] = pend_rec[win]
    valid |= win
    return record, valid
