"""Batched serving engine: prefill + decode loop over a shared KV cache.

Drives Model.decode_step for a batch of requests with greedy or temperature
sampling. Single-controller; the jitted steps are the same ones the dry-run
lowers for the decode_* cells, so what serves here is what scales there.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    max_seq: int = 256
    temperature: float = 0.0     # 0 => greedy
    seed: int = 0


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step)

    def generate(self, prompts: np.ndarray, enc_embeds=None):
        """prompts: (B, P) int32 token ids (right-aligned, no padding).
        Returns (B, max_new_tokens) generated ids."""
        model, cfg = self.model, self.cfg
        b, p = prompts.shape
        cache = model.init_cache(
            b, cfg.max_seq,
            enc_seq=enc_embeds.shape[1] if enc_embeds is not None else 0)
        if model.cfg.family == "encdec":
            _, xk, xv = model.prefill_encoder(self.params, jnp.asarray(enc_embeds))
            cache = dict(cache, xk=xk, xv=xv)

        # prefill by stepping the decoder over prompt tokens (cache fills
        # incrementally; prefill-as-decode keeps one jitted path)
        logits = None
        for t in range(p):
            cache, logits = self._decode(
                self.params, cache, {"tokens": jnp.asarray(prompts[:, t:t + 1])},
                jnp.int32(t))

        key = jax.random.key(cfg.seed)
        out = np.zeros((b, cfg.max_new_tokens), np.int32)
        tok = self._sample(logits, key, 0)
        for i in range(cfg.max_new_tokens):
            out[:, i] = np.asarray(tok)
            cache, logits = self._decode(
                self.params, cache, {"tokens": jnp.asarray(tok)[:, None]},
                jnp.int32(p + i))
            tok = self._sample(logits, key, i + 1)
        return out

    def _sample(self, logits, key, i):
        if self.cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(
            k, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)
