"""Paged KV cache with AerialDB content-hash block placement (beyond-paper).

vLLM-style paged caches use a host-side allocator for block tables. Here the
paper's placement machinery is reused instead: cache block (seq_id, block_idx)
keys are placed into the physical slot pool by ``H_i`` (lane-split xxHash64)
with AerialDB's deterministic successor probing on collision — i.e. the
block table is an open-addressing hash table whose probe sequence is exactly
the paper's replica-fallback rule. Benefits on TPU:

  * allocation is a pure jittable function of the key (no host round-trip),
  * eviction/failure of a slot range degrades gracefully (successor probing
    finds the surviving copy when replication > 1, mirroring §3.5.3).

The block TABLE is tiny and replicated; the slot POOL shards over devices.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import hashing


class PagedCache(NamedTuple):
    pool_k: jnp.ndarray     # (n_slots, block, KV, dh)
    pool_v: jnp.ndarray
    slot_key: jnp.ndarray   # (n_slots, 2) int32 owner (seq_id, block_idx); -1 free
    table: jnp.ndarray      # (max_seqs, max_blocks) int32 slot of each block


def init_paged(n_slots: int, block: int, kv: int, dh: int, max_seqs: int,
               max_blocks: int, dtype=jnp.bfloat16) -> PagedCache:
    return PagedCache(
        pool_k=jnp.zeros((n_slots, block, kv, dh), dtype),
        pool_v=jnp.zeros((n_slots, block, kv, dh), dtype),
        slot_key=jnp.full((n_slots, 2), -1, jnp.int32),
        table=jnp.full((max_seqs, max_blocks), -1, jnp.int32))


def _probe_slots(seq_id, block_idx, slot_key, n_probe: int = 16):
    """Candidate slots for a (seq, block) key: H_i start + successor probes.

    Returns (slot, found_free_or_own): the first slot that is free or already
    owned by this key, following the deterministic successor sequence.
    """
    n_slots = slot_key.shape[0]
    start = hashing.mod_u64(
        hashing.xxh64_u64(hashing.u64(jnp.asarray(seq_id, jnp.uint32),
                                      jnp.asarray(block_idx, jnp.uint32))),
        n_slots)
    offs = jnp.arange(n_probe, dtype=jnp.int32)
    cand = (start + offs) % n_slots                       # (P,)
    keys = slot_key[cand]                                 # (P, 2)
    free = keys[:, 0] < 0
    own = (keys[:, 0] == seq_id) & (keys[:, 1] == block_idx)
    ok = free | own
    first = jnp.argmax(ok)
    return cand[first], jnp.any(ok)


def append_token(cache: PagedCache, seq_id, pos, k_new, v_new, block: int):
    """Append one token's K/V for one sequence at absolute position ``pos``.

    k_new/v_new: (KV, dh). Allocates the block slot on first touch via
    content-hash probing; returns (cache, ok flag).
    """
    block_idx = pos // block
    off = pos % block
    slot, ok = _probe_slots(seq_id, block_idx, cache.slot_key)
    slot_key = cache.slot_key.at[slot].set(
        jnp.where(ok, jnp.stack([jnp.asarray(seq_id, jnp.int32),
                                 jnp.asarray(block_idx, jnp.int32)]),
                  cache.slot_key[slot]))
    table = cache.table.at[seq_id, block_idx].set(
        jnp.where(ok, slot, cache.table[seq_id, block_idx]))
    pool_k = cache.pool_k.at[slot, off].set(
        jnp.where(ok, k_new.astype(cache.pool_k.dtype), cache.pool_k[slot, off]))
    pool_v = cache.pool_v.at[slot, off].set(
        jnp.where(ok, v_new.astype(cache.pool_v.dtype), cache.pool_v[slot, off]))
    return PagedCache(pool_k, pool_v, slot_key, table), ok


def gather_sequence(cache: PagedCache, seq_id, max_blocks: int):
    """(S_max, KV, dh) contiguous view of one sequence's K and V."""
    slots = cache.table[seq_id, :max_blocks]              # (NB,)
    safe = jnp.maximum(slots, 0)
    k = cache.pool_k[safe]                                # (NB, block, KV, dh)
    v = cache.pool_v[safe]
    valid = slots >= 0
    k = jnp.where(valid[:, None, None, None], k, 0)
    v = jnp.where(valid[:, None, None, None], v, 0)
    nb, blk = k.shape[0], k.shape[1]
    return (k.reshape(nb * blk, *k.shape[2:]),
            v.reshape(nb * blk, *v.shape[2:]))
