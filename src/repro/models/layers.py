"""Shared model layers: norms, rotary embeddings, MLPs, embeddings.

Parameters are plain nested dicts of jnp arrays. Every init_* function has a
matching spec_* function returning the same tree of logical PartitionSpecs
(see repro.distributed.sharding for the logical-axis -> mesh-axis rules).
Compute runs in ``cfg.compute_dtype`` (bf16), params live in
``cfg.param_dtype`` (fp32 by default, the optimizer's master copy).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Logical axis names (mapped to mesh axes in distributed/sharding.py):
#   "fsdp"  — parameter shards gathered per-layer (data axis)
#   "tp"    — tensor-parallel dimension (model axis)
#   "exp"   — expert dimension (folded onto model axis)
#   "layers"— scan-stacked layer dimension (never sharded)
FSDP, TP, EXP = "fsdp", "tp", "exp"


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def init_rms(key, d, dtype):
    return jnp.ones((d,), dtype)


def spec_rms():
    return P(None)


def dense_init(key, shape, dtype, in_axis=0):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(dh: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                         # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Multimodal RoPE (Qwen2-VL): head_dim/2 frequency slots are split into
    ``sections`` (t, h, w) groups, each rotated by its own position stream.

    x: (B, S, H, dh); positions3: (3, B, S) int32; sum(sections) == dh // 2.
    """
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    freqs = rope_freqs(dh, theta)                          # (dh/2,)
    # Select the position stream per frequency slot.
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=dh // 2)       # (dh/2,)
    ang_all = positions3[..., None].astype(jnp.float32) * freqs  # (3, B, S, dh/2)
    onehot = jax.nn.one_hot(sec_id, 3, dtype=jnp.float32)  # (dh/2, 3)
    ang = jnp.einsum("tbsf,ft->bsf", ang_all, onehot)      # (B, S, dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d, f, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi": dense_init(k1, (d, f), dtype),
            "wg": dense_init(k2, (d, f), dtype),
            "wo": dense_init(k3, (f, d), dtype, in_axis=0)}


def spec_mlp():
    return {"wi": P(FSDP, TP), "wg": P(FSDP, TP), "wo": P(TP, FSDP)}


def mlp_apply(p, x, compute_dtype):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(compute_dtype))
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(compute_dtype))
    h = jax.nn.silu(g) * h
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(compute_dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab, d, dtype):
    return {"tok": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def spec_embed():
    return {"tok": P(TP, FSDP)}


def embed_apply(p, tokens, compute_dtype):
    return jnp.take(p["tok"].astype(compute_dtype), tokens, axis=0)
