"""Attention: GQA (+qk-norm, RoPE/M-RoPE) and MLA, with a memory-bounded
chunked flash implementation in pure jnp.

The chunked path (lax.scan over KV blocks with online softmax) is the
XLA-compiled implementation used by the dry-run — it never materializes the
full (S, S) score matrix, which is what makes the 32k-prefill shapes fit
HBM. ``repro.kernels.flash_attention`` provides the Pallas TPU kernel with
the same semantics (validated against naive attention in tests); flip
``use_pallas`` on real TPUs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.layers import FSDP, TP, apply_mrope, apply_rope, rms_norm

NEG_INF = -1e30

# Launcher-installed NamedSharding for gathered K/V under sequence
# parallelism: (B, S, KV, dh) with batch on the data axes and S/KV/dh
# replicated. With activations S-sharded (SP), slicing KV chunks out of an
# S-sharded tensor makes XLA assemble every chunk with ring
# collective-permutes (O(layers x chunks x shards) tiny collectives);
# gathering K/V once per layer — cheap for GQA — replaces them with one
# all-gather (Megatron-SP schedule). Enabled per-config via cfg.gather_kv.
_KV_GATHER_SHARDING = [None]


def set_kv_gather_sharding(sharding):
    _KV_GATHER_SHARDING[0] = sharding


def _maybe_gather_kv(k, v, cfg):
    sh = _KV_GATHER_SHARDING[0]
    if sh is None or not getattr(cfg, "gather_kv", False):
        return k, v
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = P(sh.spec[0], *([None] * (k.ndim - 1)))
    ns = NamedSharding(sh.mesh, spec)
    return (jax.lax.with_sharding_constraint(k, ns),
            jax.lax.with_sharding_constraint(v, ns))


# ---------------------------------------------------------------------------
# Flash attention (chunked online softmax) — pure jnp
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool, q_offset=0, chunk_kv: int = 1024):
    """q: (B, Sq, H, dh), k/v: (B, Skv, KV, dh) with H % KV == 0.

    ``q_offset``: absolute position of q[0] (decode: Skv - Sq). Scans KV in
    chunks, carrying (m, l, acc) — the online-softmax running max / sum /
    accumulator. Memory: O(Sq * chunk_kv) per head instead of O(Sq * Skv).

    Decode (Sq == 1) takes the single-einsum path: the KV cache is
    sequence-sharded under SP, and the chunk-scan's (S -> nck, ck) reshape
    would split the sharded dim (XLA falls back to full rematerialization of
    the cache). Contracting S in one einsum lets SPMD keep the cache sharded
    and emit a partial-softmax all-reduce instead.
    """
    b, sq, h, dh = q.shape
    if sq == 1:
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset)
    skv, kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                 # may differ from dh (MLA)
    g = h // kv
    qr = q.reshape(b, sq, kv, g, dh)
    scale = dh ** -0.5
    nck = max(skv // chunk_kv, 1)
    ck = skv // nck
    kc = k.reshape(b, nck, ck, kv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nck, ck, kv, dv).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        s = jnp.einsum("bqkgd,bckd->bqkgc", qr, kb).astype(jnp.float32) * scale
        if causal:
            k_pos = ci * ck + jnp.arange(ck)
            mask = q_pos[:, None] >= k_pos[None, :]            # (Sq, ck)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])                      # fp32
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr.astype(acc.dtype)[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(vb.dtype), vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kv, g, dv), q.dtype)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(nck)))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.reshape(b, sq, h, dv)


def naive_attention(q, k, v, *, causal: bool, q_offset=0):
    """Reference O(S^2)-memory attention (oracle for flash + Pallas kernel)."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    qr = q.reshape(b, sq, kv, h // kv, dh)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qr, k) * dh ** -0.5
    if causal:
        q_pos = q_offset + jnp.arange(sq)
        mask = q_pos[:, None] >= jnp.arange(k.shape[1])[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(v.dtype)
    return jnp.einsum("bqkgc,bckd->bqkgd", p, v).reshape(b, sq, h, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def init_gqa(key, cfg):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    ks = jax.random.split(key, 6)
    p = {"wq": layers.dense_init(ks[0], (d, h * dh), cfg.param_dtype),
         "wk": layers.dense_init(ks[1], (d, kv * dh), cfg.param_dtype),
         "wv": layers.dense_init(ks[2], (d, kv * dh), cfg.param_dtype),
         "wo": layers.dense_init(ks[3], (h * dh, d), cfg.param_dtype)}
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rms(ks[4], dh, cfg.param_dtype)
        p["k_norm"] = layers.init_rms(ks[5], dh, cfg.param_dtype)
    return p


def spec_gqa(cfg):
    p = {"wq": P(FSDP, TP), "wk": P(FSDP, TP), "wv": P(FSDP, TP),
         "wo": P(TP, FSDP)}
    if cfg.qk_norm:
        p["q_norm"] = P(None)
        p["k_norm"] = P(None)
    return p


def gqa_project_qkv(p, x, cfg, positions):
    """x: (B, S, D) -> q (B,S,H,dh), k/v (B,S,KV,dh) with rope applied."""
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    cd = cfg.compute_dtype
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(cd)).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(cd)).reshape(b, s, kv, dh)
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(cd)).reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_apply(p, x, cfg, positions, *, causal=True, kv_override=None,
              q_offset=0):
    """Full-sequence GQA. ``kv_override=(k, v)`` serves cross-attention and
    decode-from-cache."""
    b, s, _ = x.shape
    cd = cfg.compute_dtype
    q, k, v = gqa_project_qkv(p, x, cfg, positions)
    if kv_override is not None:
        k, v = kv_override
    k, v = _maybe_gather_kv(k, v, cfg)
    out = flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                          chunk_kv=cfg.attn_chunk_kv)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1),
                      p["wo"].astype(cd))


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent-compressed KV with decoupled RoPE
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    nope, rph, vdim = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    kvl = cfg.kv_lora
    ks = jax.random.split(key, 6)
    return {
        "wq": layers.dense_init(ks[0], (d, h * (nope + rph)), cfg.param_dtype),
        "wkv_a": layers.dense_init(ks[1], (d, kvl + rph), cfg.param_dtype),
        "kv_norm": layers.init_rms(ks[2], kvl, cfg.param_dtype),
        "wkv_b": layers.dense_init(ks[3], (kvl, h * (nope + vdim)), cfg.param_dtype),
        "wo": layers.dense_init(ks[4], (h * vdim, d), cfg.param_dtype),
    }


def spec_mla(cfg):
    return {"wq": P(FSDP, TP), "wkv_a": P(FSDP, None), "kv_norm": P(None),
            "wkv_b": P(FSDP, TP), "wo": P(TP, FSDP)}


def mla_latent(p, x, cfg, positions):
    """Compress x into the MLA latent cache: (c_kv (B,S,kvl), k_rope (B,S,1,rph))."""
    cd = cfg.compute_dtype
    kvl, rph = cfg.kv_lora, cfg.mla_rope_dim
    a = jnp.einsum("bsd,de->bse", x, p["wkv_a"].astype(cd))
    c_kv = rms_norm(a[..., :kvl], p["kv_norm"])
    k_rope = apply_rope(a[..., kvl:][..., None, :], positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_attend(p, x, cfg, positions, c_kv, k_rope, *, causal=True, q_offset=0):
    """Attention over the latent cache (expanded per-head K/V)."""
    b, s, _ = x.shape
    cd = cfg.compute_dtype
    h = cfg.n_heads
    nope, rph, vdim = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim

    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(cd)).reshape(b, s, h, nope + rph)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kvb = jnp.einsum("bsl,le->bse", c_kv, p["wkv_b"].astype(cd))
    kvb = kvb.reshape(b, c_kv.shape[1], h, nope + vdim)
    k_nope, v = kvb[..., :nope], kvb[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (rph,))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    k, v = _maybe_gather_kv(k, v, cfg)
    out = flash_attention(qf, k, v, causal=causal, q_offset=q_offset,
                          chunk_kv=cfg.attn_chunk_kv)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p["wo"].astype(cd))


def mla_apply(p, x, cfg, positions, *, causal=True):
    c_kv, k_rope = mla_latent(p, x, cfg, positions)
    return mla_attend(p, x, cfg, positions, c_kv, k_rope, causal=causal)


def mla_decode_absorbed(p, x, cfg, positions, c_kv, k_rope, pos):
    """Decode-time MLA with the w_kv_b absorption trick (DeepSeek-V2 §2.1.2
    serving form): attention runs directly in the latent space, so the cache
    stays (S, kv_lora + rope_dim) and is never expanded to per-head K/V.

    x: (B, 1, D); c_kv: (B, S, kvl); k_rope: (B, S, 1, rph); pos: scalar.
    """
    b, s1, _ = x.shape
    cd = cfg.compute_dtype
    h = cfg.n_heads
    nope, rph, vdim = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    kvl = cfg.kv_lora
    smax = c_kv.shape[1]

    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(cd)).reshape(b, s1, h, nope + rph)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    wkv_b = p["wkv_b"].astype(cd).reshape(kvl, h, nope + vdim)
    wk_b, wv_b = wkv_b[..., :nope], wkv_b[..., nope:]
    # absorb K expansion into the query
    q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, wk_b)        # (B,1,H,kvl)
    scores = (jnp.einsum("bshl,btl->bhst", q_lat, c_kv) +
              jnp.einsum("bshr,btr->bhst", q_rope, k_rope[:, :, 0, :]))
    scores = scores * (nope + rph) ** -0.5
    mask = jnp.arange(smax)[None, None, None, :] <= pos
    scores = jnp.where(mask, scores, NEG_INF)
    attn = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(cd)
    ctx_lat = jnp.einsum("bhst,btl->bshl", attn, c_kv)         # (B,1,H,kvl)
    out = jnp.einsum("bshl,lhv->bshv", ctx_lat, wv_b)          # (B,1,H,v)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s1, h * vdim),
                      p["wo"].astype(cd))
