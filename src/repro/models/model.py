"""Top-level model API: build, shard, train-forward, loss, prefill, decode.

``Model`` wraps a ModelConfig with pure functions:
  init(key) -> params                  (jit/eval_shape friendly)
  pspecs() -> matching PartitionSpec tree
  forward(params, batch) -> (hidden, aux_loss)
  loss(params, batch) -> scalar        (chunked-vocab CE + MoE aux)
  init_cache(batch_size, max_seq) -> cache
  prefill(params, batch) -> (cache, hidden_last)
  decode_step(params, cache, inputs, pos) -> (cache, logits)

Decode caches are O(S) KV (attention archs), O(1) latent (MLA) or O(1) state
(SSM/hybrid) — the per-family difference the roofline table surfaces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention, layers, mamba, moe, transformer
from repro.models.layers import FSDP, TP
from repro.models.transformer import (apply_decoder_stack, apply_encdec_stack,
                                      apply_hybrid_stack, apply_ssm_stack,
                                      hybrid_attn_sites, init_decoder_stack,
                                      init_encdec_stack, init_hybrid_stack,
                                      init_ssm_stack, spec_decoder_stack,
                                      spec_encdec_stack, spec_hybrid_stack,
                                      spec_ssm_stack)

STACKS = {
    "dense": (init_decoder_stack, spec_decoder_stack),
    "moe": (init_decoder_stack, spec_decoder_stack),
    "ssm": (init_ssm_stack, spec_ssm_stack),
    "hybrid": (init_hybrid_stack, spec_hybrid_stack),
    "encdec": (init_encdec_stack, spec_encdec_stack),
}


def _attn_decode_layer(lp, x, cfg, pos, pos_arr, cache_slices, *, use_moe):
    """One decoder layer at decode time: update cache at ``pos``, attend over
    the populated prefix, apply FFN. cache_slices: (c_kv, k_rope) for MLA or
    (k, v) for GQA. Returns (x, new_cache_slices)."""
    cd = cfg.compute_dtype
    h = layers.rms_norm(x, lp["ln1"])
    if cfg.mla:
        c_kv_l, k_rope_l = cache_slices
        c_new, kr_new = attention.mla_latent(lp["attn"], h, cfg, pos_arr)
        c_kv_l = jax.lax.dynamic_update_slice_in_dim(c_kv_l, c_new.astype(c_kv_l.dtype), pos, 1)
        k_rope_l = jax.lax.dynamic_update_slice_in_dim(k_rope_l, kr_new.astype(k_rope_l.dtype), pos, 1)
        a = attention.mla_decode_absorbed(lp["attn"], h, cfg, pos_arr,
                                          c_kv_l, k_rope_l, pos)
        new_cache = (c_kv_l, k_rope_l)
    else:
        k_l, v_l = cache_slices
        q, k, v = attention.gqa_project_qkv(lp["attn"], h, cfg, pos_arr)
        k_l = jax.lax.dynamic_update_slice_in_dim(k_l, k.astype(k_l.dtype), pos, 1)
        v_l = jax.lax.dynamic_update_slice_in_dim(v_l, v.astype(v_l.dtype), pos, 1)
        o = attention.flash_attention(q, k_l, v_l, causal=True, q_offset=pos,
                                      chunk_kv=cfg.attn_chunk_kv)
        a = jnp.einsum("bse,ed->bsd", o.reshape(*h.shape[:2], -1),
                       lp["attn"]["wo"].astype(cd))
        new_cache = (k_l, v_l)
    x = x + a
    h = layers.rms_norm(x, lp["ln2"])
    if use_moe:
        f, _ = moe.moe_apply(lp["moe"], h, cfg)
    else:
        f = layers.mlp_apply(lp["mlp"], h, cd)
    return x + f, new_cache


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- params ------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        init_stack = STACKS[cfg.family][0]
        p = {"stack": init_stack(k2, cfg),
             "final_ln": layers.init_rms(k3, cfg.d_model, cfg.param_dtype)}
        p["embed"] = layers.init_embed(k1, cfg.vocab_padded, cfg.d_model,
                                       cfg.param_dtype)
        if not cfg.tie_embeddings:
            p["out"] = layers.dense_init(k4, (cfg.d_model, cfg.vocab_padded),
                                         cfg.param_dtype)
        return p

    def pspecs(self):
        cfg = self.cfg
        spec_stack = STACKS[cfg.family][1]
        p = {"stack": spec_stack(cfg), "final_ln": layers.spec_rms(),
             "embed": layers.spec_embed()}
        if not cfg.tie_embeddings:
            p["out"] = P(FSDP, TP)
        return p

    def abstract_params(self):
        return jax.eval_shape(self.init, jax.random.key(0))

    # -- forward -----------------------------------------------------------
    def _positions(self, b, s, offset=0):
        pos = offset + jnp.arange(s, dtype=jnp.int32)[None, :]
        pos = jnp.broadcast_to(pos, (b, s))
        if self.cfg.mrope:  # text-degenerate M-RoPE: all three streams equal
            return jnp.broadcast_to(pos[None], (3, b, s))
        return pos

    def _embed_in(self, params, batch):
        cfg = self.cfg
        if cfg.embed_input and "embeds" in batch:
            return batch["embeds"].astype(cfg.compute_dtype)
        return layers.embed_apply(params["embed"], batch["tokens"],
                                  cfg.compute_dtype)

    def forward(self, params, batch):
        """-> (hidden (B, S, D), aux_loss)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            enc_x = batch["enc_embeds"].astype(cfg.compute_dtype)
            dec_x = layers.embed_apply(params["embed"], batch["tokens"],
                                       cfg.compute_dtype)
            eb, es = enc_x.shape[:2]
            db, ds = dec_x.shape[:2]
            h, aux = apply_encdec_stack(params["stack"], enc_x, dec_x, cfg,
                                        self._positions(eb, es),
                                        self._positions(db, ds))
        else:
            x = self._embed_in(params, batch)
            b, s = x.shape[:2]
            pos = self._positions(b, s)
            if cfg.family in ("dense", "moe"):
                h, aux = apply_decoder_stack(params["stack"], x, cfg, pos)
            elif cfg.family == "ssm":
                h, aux = apply_ssm_stack(params["stack"], x, cfg, pos)
            elif cfg.family == "hybrid":
                h, aux = apply_hybrid_stack(params["stack"], x, cfg, pos)
            else:
                raise ValueError(cfg.family)
        return layers.rms_norm(h, params["final_ln"]), aux

    def _unembed(self, params):
        cfg = self.cfg
        w = params["embed"]["tok"].T if cfg.tie_embeddings else params["out"]
        return w.astype(cfg.compute_dtype)          # (D, V_padded)

    def _mask_pad_vocab(self, logits):
        cfg = self.cfg
        if cfg.vocab_padded == cfg.vocab:
            return logits
        pad = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        return logits - pad.astype(logits.dtype) * 1e9

    def logits(self, params, hidden):
        return self._mask_pad_vocab(
            jnp.einsum("bsd,dv->bsv", hidden, self._unembed(params)))

    def loss(self, params, batch):
        """Chunked-vocab causal-LM cross entropy (never materializes the full
        (T, V) logit tensor — scan over token blocks with remat)."""
        cfg = self.cfg
        hidden, aux = self.forward(params, batch)
        labels = batch["labels"]
        b, s, d = hidden.shape
        t = b * s
        h2 = hidden.reshape(t, d)
        l2 = labels.reshape(t)
        w = self._unembed(params)
        chunk = min(cfg.loss_chunk, t)
        n_chunks = max(t // chunk, 1)
        h3 = h2[: n_chunks * chunk].reshape(n_chunks, chunk, d)
        l3 = l2[: n_chunks * chunk].reshape(n_chunks, chunk)

        def block(carry, xs):
            hc, lc = xs
            logits = self._mask_pad_vocab((hc @ w).astype(jnp.float32))
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
            mask = lc >= 0
            return carry + jnp.sum((logz - gold) * mask), None

        total, _ = jax.lax.scan(jax.checkpoint(block), jnp.float32(0.0),
                                (h3, l3))
        n_tok = jnp.maximum(jnp.sum(l2 >= 0), 1)
        ce = total / n_tok
        if cfg.n_experts:
            ce = ce + cfg.aux_loss_weight * aux / max(cfg.n_layers, 1)
        return ce

    # -- serving -----------------------------------------------------------
    def init_cache(self, b: int, max_seq: int, enc_seq: int = 0):
        cfg = self.cfg
        cd = cfg.compute_dtype
        l = cfg.n_layers
        if cfg.family in ("dense", "moe"):
            if cfg.mla:
                return {
                    "c_kv": jnp.zeros((l, b, max_seq, cfg.kv_lora), cd),
                    "k_rope": jnp.zeros((l, b, max_seq, 1, cfg.mla_rope_dim), cd)}
            return {"k": jnp.zeros((l, b, max_seq, cfg.n_kv, cfg.d_head), cd),
                    "v": jnp.zeros((l, b, max_seq, cfg.n_kv, cfg.d_head), cd)}
        if cfg.family == "ssm":
            di = cfg.d_inner
            return {"conv": jnp.zeros((l, b, cfg.d_conv - 1, di), cd),
                    "h": jnp.zeros((l, b, di, cfg.ssm_state), jnp.float32)}
        if cfg.family == "hybrid":
            di = cfg.d_inner
            nh = di // cfg.ssm_headdim
            n_sites = len(hybrid_attn_sites(cfg))
            cw = di + 2 * cfg.n_groups * cfg.ssm_state
            return {"conv": jnp.zeros((l, b, cfg.d_conv - 1, cw), cd),
                    "h": jnp.zeros((l, b, nh, cfg.ssm_state, cfg.ssm_headdim),
                                   jnp.float32),
                    "k": jnp.zeros((n_sites, b, max_seq, cfg.n_kv, cfg.d_head), cd),
                    "v": jnp.zeros((n_sites, b, max_seq, cfg.n_kv, cfg.d_head), cd)}
        if cfg.family == "encdec":
            es = enc_seq or max(max_seq // cfg.enc_seq_ratio, 1)
            return {"k": jnp.zeros((l, b, max_seq, cfg.n_kv, cfg.d_head), cd),
                    "v": jnp.zeros((l, b, max_seq, cfg.n_kv, cfg.d_head), cd),
                    "xk": jnp.zeros((l, b, es, cfg.n_kv, cfg.d_head), cd),
                    "xv": jnp.zeros((l, b, es, cfg.n_kv, cfg.d_head), cd)}
        raise ValueError(cfg.family)

    def cache_pspecs(self, multi_pod: bool = False, shard_batch: bool = True):
        """Shard caches. With a shardable batch: batch over the data axes and
        KV sequence over the model axis (SP). Small-batch long-context cells
        (long_500k, B=1) replicate batch and shard the sequence over ALL mesh
        axes instead — sequence parallelism is what makes a 500k cache fit."""
        cfg = self.cfg
        all_ax = ("pod", "data", "model") if multi_pod else ("data", "model")
        if shard_batch:
            dp = ("pod", "data") if multi_pod else "data"
            seq = "model"
            feat = "model"
        else:
            dp = None
            seq = all_ax
            feat = "model"
        kvspec = P(None, dp, seq, None, None)
        if cfg.family in ("dense", "moe"):
            if cfg.mla:
                return {"c_kv": P(None, dp, seq, None),
                        "k_rope": P(None, dp, seq, None, None)}
            return {"k": kvspec, "v": kvspec}
        if cfg.family == "ssm":
            return {"conv": P(None, dp, None, feat),
                    "h": P(None, dp, feat, None)}
        if cfg.family == "hybrid":
            return {"conv": P(None, dp, None, feat),
                    "h": P(None, dp, feat, None, None),
                    "k": kvspec, "v": kvspec}
        if cfg.family == "encdec":
            return {"k": kvspec, "v": kvspec, "xk": kvspec, "xv": kvspec}
        raise ValueError(cfg.family)

    # ---- decode: one token with a populated cache ------------------------
    def decode_step(self, params, cache, inputs, pos):
        """inputs: tokens (B, 1) or embeds (B, 1, D); pos: scalar int32
        (current absolute position). Returns (cache, logits (B, V))."""
        cfg = self.cfg
        cd = cfg.compute_dtype
        x = self._embed_in(params, inputs)
        b = x.shape[0]
        pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (b, 1))
        if cfg.mrope:
            pos_arr = jnp.broadcast_to(pos_arr[None], (3, b, 1))

        if cfg.family in ("dense", "moe"):
            cache, h = self._decode_attn_stack(params, cache, x, pos, pos_arr)
        elif cfg.family == "ssm":
            cache, h = self._decode_ssm_stack(params, cache, x)
        elif cfg.family == "hybrid":
            cache, h = self._decode_hybrid_stack(params, cache, x, pos, pos_arr)
        elif cfg.family == "encdec":
            cache, h = self._decode_encdec_stack(params, cache, x, pos, pos_arr)
        else:
            raise ValueError(cfg.family)
        h = layers.rms_norm(h, params["final_ln"])
        return cache, self.logits(params, h)[:, 0]

    def _decode_attn_stack(self, params, cache, x, pos, pos_arr):
        cfg = self.cfg

        def body(x, xs):
            lp, *cs = xs
            x, new_cs = _attn_decode_layer(lp, x, cfg, pos, pos_arr, tuple(cs),
                                           use_moe=cfg.n_experts > 0)
            return x, new_cs

        stack = params["stack"]
        fd = cfg.first_dense
        cache_keys = ("c_kv", "k_rope") if cfg.mla else ("k", "v")
        head = {k: cache[k][:fd] for k in cache_keys}
        tail = {k: cache[k][fd:] for k in cache_keys}
        if fd:
            # Leading dense-FFN layers (DeepSeek-V2) differ in pytree
            # structure; run them in a (tiny) python loop over cache[:fd].
            for i in range(fd):
                lp = jax.tree.map(lambda a: a[i], stack["first"])
                cs = tuple(head[k][i] for k in cache_keys)
                x, new_cs = _attn_decode_layer(lp, x, cfg, pos, pos_arr, cs,
                                               use_moe=False)
                for k, nc in zip(cache_keys, new_cs):
                    head[k] = head[k].at[i].set(nc)
        x, new_tail = jax.lax.scan(
            body, x, (stack["layers"],) + tuple(tail[k] for k in cache_keys))
        out = {k: jnp.concatenate([head[k], nt], axis=0) if fd else nt
               for k, nt in zip(cache_keys, new_tail)}
        return out, x

    def _decode_ssm_stack(self, params, cache, x):
        cfg = self.cfg

        def body(x, xs):
            lp, conv_l, h_l = xs
            h = layers.rms_norm(x, lp["ln"])
            y, (conv_n, h_n) = mamba.mamba1_apply(lp["mamba"], h, cfg,
                                                  state=(conv_l, h_l))
            return x + y, (conv_n, h_n)

        x, (conv, hs) = jax.lax.scan(body, x,
                                     (params["stack"]["layers"],
                                      cache["conv"], cache["h"]))
        return {"conv": conv, "h": hs}, x

    def _decode_hybrid_stack(self, params, cache, x, pos, pos_arr):
        cfg = self.cfg
        groups, n_sites = transformer.hybrid_groups(cfg)
        shared = params["stack"]["shared_attn"]
        cd = cfg.compute_dtype
        kc, vc = cache["k"], cache["v"]
        conv_out, h_out = cache["conv"], cache["h"]

        def body(x, xs):
            lp, conv_l, h_l = xs
            h = layers.rms_norm(x, lp["ln"])
            y, (conv_n, h_n) = mamba.mamba2_apply(lp["mamba"], h, cfg,
                                                  state=(conv_l, h_l))
            return x + y, (conv_n, h_n)

        for gi, (lo, hi) in enumerate(groups):
            grp = jax.tree.map(lambda a: a[lo:hi], params["stack"]["layers"])
            x, (conv_n, h_n) = jax.lax.scan(
                body, x, (grp, cache["conv"][lo:hi], cache["h"][lo:hi]))
            conv_out = jax.lax.dynamic_update_slice_in_dim(conv_out, conv_n, lo, 0)
            h_out = jax.lax.dynamic_update_slice_in_dim(h_out, h_n, lo, 0)
            if gi < n_sites:
                h = layers.rms_norm(x, shared["ln"])
                q, k, v = attention.gqa_project_qkv(shared["attn"], h, cfg,
                                                    pos_arr)
                k_l = jax.lax.dynamic_update_slice(kc, k[None].astype(kc.dtype),
                                                   (gi, 0, pos, 0, 0))
                v_l = jax.lax.dynamic_update_slice(vc, v[None].astype(vc.dtype),
                                                   (gi, 0, pos, 0, 0))
                kc, vc = k_l, v_l
                o = attention.flash_attention(q, kc[gi], vc[gi], causal=True,
                                              q_offset=pos,
                                              chunk_kv=cfg.attn_chunk_kv)
                a = jnp.einsum("bse,ed->bsd", o.reshape(*h.shape[:2], -1),
                               shared["attn"]["wo"].astype(cd))
                x = x + a
                h2 = layers.rms_norm(x, shared["ln2"])
                x = x + layers.mlp_apply(shared["mlp"], h2, cd)
        return {"conv": conv_out, "h": h_out, "k": kc, "v": vc}, x

    def _decode_encdec_stack(self, params, cache, x, pos, pos_arr):
        cfg = self.cfg

        def body(x, xs):
            lp, k_l, v_l, xk_l, xv_l = xs
            h = layers.rms_norm(x, lp["ln1"])
            q, k, v = attention.gqa_project_qkv(lp["attn"], h, cfg, pos_arr)
            k_l = jax.lax.dynamic_update_slice_in_dim(k_l, k, pos, 1)
            v_l = jax.lax.dynamic_update_slice_in_dim(v_l, v, pos, 1)
            o = attention.flash_attention(q, k_l, v_l, causal=True,
                                          q_offset=pos,
                                          chunk_kv=cfg.attn_chunk_kv)
            a = jnp.einsum("bse,ed->bsd", o.reshape(*h.shape[:2], -1),
                           lp["attn"]["wo"].astype(cfg.compute_dtype))
            x = x + a
            h = layers.rms_norm(x, lp["ln_x"])
            qx, _, _ = attention.gqa_project_qkv(lp["xattn"], h, cfg, pos_arr)
            ox = attention.flash_attention(qx, xk_l, xv_l, causal=False,
                                           chunk_kv=cfg.attn_chunk_kv)
            ax = jnp.einsum("bse,ed->bsd", ox.reshape(*h.shape[:2], -1),
                            lp["xattn"]["wo"].astype(cfg.compute_dtype))
            x = x + ax
            h = layers.rms_norm(x, lp["ln2"])
            f = layers.mlp_apply(lp["mlp"], h, cfg.compute_dtype)
            return x + f, (k_l, v_l)

        dec = params["stack"]["decoder"]
        x, (k, v) = jax.lax.scan(body, x, (dec, cache["k"], cache["v"],
                                           cache["xk"], cache["xv"]))
        return dict(cache, k=k, v=v), x

    def prefill_encoder(self, params, enc_embeds):
        """Encode + per-layer cross-KV projection (fills xk/xv cache)."""
        cfg = self.cfg
        enc_x = enc_embeds.astype(cfg.compute_dtype)
        b, s = enc_x.shape[:2]
        pos = self._positions(b, s)

        def enc_body(x, lp):
            y, _ = transformer.apply_decoder_layer(lp, x, cfg, pos,
                                                   use_moe=False, causal=False)
            return y, None

        enc_out, _ = jax.lax.scan(enc_body, enc_x, params["stack"]["encoder"])

        def proj(lp):
            _, k, v = attention.gqa_project_qkv(lp["xattn"], enc_out, cfg, pos)
            return k, v

        xk, xv = jax.vmap(proj)(params["stack"]["decoder"])  # (L, B, S, KV, dh)
        return enc_out, xk, xv
