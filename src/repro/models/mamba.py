"""Mamba SSM blocks: Mamba1 (selective scan) and Mamba2 (SSD), TPU-native.

Hardware adaptation (DESIGN.md §2): the reference CUDA kernels fuse the
recurrence into a single-SM scan with shared-memory staging. On TPU we use:

  * Mamba1 — the recurrence ``h_t = a_t * h_{t-1} + b_t`` is a first-order
    linear recurrence, i.e. associative under (a, b) composition, so it maps
    onto ``jax.lax.associative_scan`` (log-depth, fully vectorized on the
    VPU). Sequences are processed in chunks (outer ``lax.scan`` carrying the
    boundary state) to bound the materialized (B, Q, Di, N) working set —
    the TPU analogue of the CUDA kernel's tiling. A sequential inner path
    exists for validation (`ssm_scan="sequential"`).
  * Mamba2 — the SSD chunked matmul formulation: scalar-per-head decay makes
    the intra-chunk term a (Q, Q) masked-decay attention-like matmul (MXU)
    and the inter-chunk term a tiny state scan.

Decode carries (conv_state (B, d_conv-1, Di), ssm_state (B, Di, N) or
(B, H, N, P)) — O(1) in sequence length, which is why the ssm/hybrid archs
are the ones that run the ``long_500k`` cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.layers import FSDP, TP


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------

def init_mamba1(key, cfg):
    d = cfg.d_model
    di = cfg.expand * d
    n, dtr, dc = cfg.ssm_state, max(d // 16, 1), cfg.d_conv
    ks = jax.random.split(key, 7)
    return {
        "in_proj": layers.dense_init(ks[0], (d, 2 * di), cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) / jnp.sqrt(dc)).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((di,), cfg.param_dtype),
        "x_proj": layers.dense_init(ks[2], (di, dtr + 2 * n), cfg.param_dtype),
        "dt_proj": layers.dense_init(ks[3], (dtr, di), cfg.param_dtype),
        "dt_bias": jnp.zeros((di,), cfg.param_dtype),
        "a_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                          (di, n))).astype(jnp.float32),
        "d_skip": jnp.ones((di,), cfg.param_dtype),
        "out_proj": layers.dense_init(ks[4], (di, d), cfg.param_dtype),
    }


def spec_mamba1(cfg):
    return {"in_proj": P(FSDP, TP), "conv_w": P(None, TP), "conv_b": P(TP),
            "x_proj": P(TP, None), "dt_proj": P(None, TP), "dt_bias": P(TP),
            "a_log": P(TP, None), "d_skip": P(TP), "out_proj": P(TP, FSDP)}


def _causal_conv(x, w, b, init_state=None):
    """Depthwise causal conv over seq. x: (B, S, Di), w: (dc, Di)."""
    dc = w.shape[0]
    if init_state is None:
        pad = jnp.zeros(x.shape[:1] + (dc - 1,) + x.shape[2:], x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(dc))
    new_state = xp[:, -(dc - 1):] if dc > 1 else None
    return jax.nn.silu(out + b.astype(x.dtype)), new_state


def _ssm_params(p, xc, cfg):
    """Input-dependent (dt, B, C) projections. xc: (B, S, Di)."""
    cd = cfg.compute_dtype
    n = cfg.ssm_state
    dtr = p["dt_proj"].shape[0]
    proj = jnp.einsum("bsi,ir->bsr", xc, p["x_proj"].astype(cd))
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", proj[..., :dtr], p["dt_proj"].astype(cd)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                     # (B,S,Di)
    b_mat = proj[..., dtr:dtr + n].astype(jnp.float32)          # (B,S,N)
    c_mat = proj[..., dtr + n:].astype(jnp.float32)
    return dt, b_mat, c_mat


def selective_scan(dt, b_mat, c_mat, xc, a_log, h0=None, *, chunk: int = 128,
                   mode: str = "associative"):
    """h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t.

    dt: (B,S,Di) fp32, b/c: (B,S,N), xc: (B,S,Di), a_log: (Di,N).
    Returns (y (B,S,Di), h_final (B,Di,N)).
    """
    bsz, s, di = dt.shape
    n = b_mat.shape[-1]
    a = -jnp.exp(a_log)                                          # (Di,N)
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)
    nch = max(s // chunk, 1)
    q = s // nch

    def chunk_step(h, xs):
        dt_c, b_c, c_c, x_c = xs                                 # (B,Q,...)
        decay = jnp.exp(dt_c[..., None] * a)                     # (B,Q,Di,N)
        inp = (dt_c * x_c.astype(jnp.float32))[..., None] * b_c[:, :, None, :]
        if mode == "associative":
            def comb(l, r):
                return (l[0] * r[0], r[0] * l[1] + r[1])
            aa, bb = jax.lax.associative_scan(comb, (decay, inp), axis=1)
            hs = aa * h[:, None] + bb                            # (B,Q,Di,N)
        else:
            def step(hh, z):
                d_, i_ = z
                hh = d_ * hh + i_
                return hh, hh
            _, hs = jax.lax.scan(step, h,
                                 (decay.swapaxes(0, 1), inp.swapaxes(0, 1)))
            hs = hs.swapaxes(0, 1)
        y = jnp.einsum("bqin,bqn->bqi", hs, c_c)
        return hs[:, -1], y

    dt_r = dt.reshape(bsz, nch, q, di).swapaxes(0, 1)
    b_r = b_mat.reshape(bsz, nch, q, n).swapaxes(0, 1)
    c_r = c_mat.reshape(bsz, nch, q, n).swapaxes(0, 1)
    x_r = xc.reshape(bsz, nch, q, di).swapaxes(0, 1)
    h_fin, ys = jax.lax.scan(chunk_step, h0, (dt_r, b_r, c_r, x_r))
    y = ys.swapaxes(0, 1).reshape(bsz, s, di)
    return y, h_fin


def mamba1_apply(p, x, cfg, *, state=None):
    """x: (B, S, D) -> (B, S, D). ``state=(conv_state, ssm_state)`` enables
    O(1) decode; pass state=None for full-sequence training."""
    cd = cfg.compute_dtype
    di = cfg.expand * cfg.d_model
    zx = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    xin, z = zx[..., :di], zx[..., di:]
    conv_state = state[0] if state is not None else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    dt, b_mat, c_mat = _ssm_params(p, xc, cfg)
    h0 = state[1] if state is not None else None
    y, h_fin = selective_scan(dt, b_mat, c_mat, xc, p["a_log"], h0,
                              chunk=cfg.ssm_chunk, mode=cfg.ssm_scan)
    y = y.astype(cd) + xc * p["d_skip"].astype(cd)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cd))
    return out, (new_conv, h_fin)


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg):
    d = cfg.d_model
    di = cfg.expand * d
    n, g, hd = cfg.ssm_state, cfg.n_groups, cfg.ssm_headdim
    nh = di // hd
    dc = cfg.d_conv
    ks = jax.random.split(key, 5)
    # in_proj emits [z (di), x (di), B (g*n), C (g*n), dt (nh)]
    return {
        "in_proj": layers.dense_init(ks[0], (d, 2 * di + 2 * g * n + nh), cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di + 2 * g * n)) / jnp.sqrt(dc)).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((di + 2 * g * n,), cfg.param_dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), cfg.param_dtype),
        "norm": layers.init_rms(ks[2], di, cfg.param_dtype),
        "out_proj": layers.dense_init(ks[3], (di, d), cfg.param_dtype),
    }


def spec_mamba2(cfg):
    return {"in_proj": P(FSDP, TP), "conv_w": P(None, TP), "conv_b": P(TP),
            "a_log": P(None), "dt_bias": P(None), "d_skip": P(None),
            "norm": P(None), "out_proj": P(TP, FSDP)}


def _segsum(x):
    """(..., Q) -> (..., Q, Q) lower-tri cumulative sums: out[t,s] = sum_{s<i<=t} x_i."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(xh, dt, a, b_mat, c_mat, h0, chunk: int):
    """SSD forward. xh: (B,S,H,P), dt: (B,S,H) fp32, a: (H,) negative,
    b/c: (B,S,G,N). Returns (y (B,S,H,P), h_final (B,H,N,P))."""
    bsz, s, h, p_dim = xh.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    nch = max(s // chunk, 1)
    q = s // nch

    def rc(t):  # (B,S,...) -> (nch, B, Q, ...)
        return t.reshape(bsz, nch, q, *t.shape[2:]).swapaxes(0, 1)

    xs, dts = rc(xh), rc(dt)
    bs, cs = rc(b_mat), rc(c_mat)

    def chunk_step(hprev, z):
        x_c, dt_c, b_c, c_c = z                       # (B,Q,H,P), (B,Q,H), (B,Q,G,N)
        da = dt_c * a                                  # (B,Q,H)
        # intra-chunk: decay matrix L (B,H,Q,Q)
        l = jnp.exp(_segsum(da.transpose(0, 2, 1)))    # (B,H,Q,Q)
        bh = jnp.repeat(b_c, rep, axis=2)              # (B,Q,H,N)
        ch = jnp.repeat(c_c, rep, axis=2)
        scores = jnp.einsum("bqhn,bshn->bhqs", ch, bh) * l
        xdt = x_c * dt_c[..., None]                    # (B,Q,H,P)
        y_intra = jnp.einsum("bhqs,bshp->bqhp", scores, xdt)
        # inter-chunk: contribution of carried state
        cum = jnp.cumsum(da, axis=1)                   # (B,Q,H)
        y_inter = jnp.einsum("bqhn,bhnp->bqhp", ch, hprev) * jnp.exp(cum)[..., None]
        # state update
        decay_tail = jnp.exp(cum[:, -1:, :] - cum)     # (B,Q,H)
        h_new = jnp.exp(cum[:, -1])[..., None, None] * hprev + \
            jnp.einsum("bqhn,bqhp->bhnp", bh * decay_tail[..., None], xdt)
        return h_new, y_intra + y_inter

    h_fin, ys = jax.lax.scan(chunk_step, h0, (xs, dts, bs, cs))
    return ys.swapaxes(0, 1).reshape(bsz, s, h, p_dim), h_fin


def mamba2_apply(p, x, cfg, *, state=None):
    """Mamba2/SSD block. x: (B, S, D)."""
    cd = cfg.compute_dtype
    d = cfg.d_model
    di = cfg.expand * d
    g, n, hd = cfg.n_groups, cfg.ssm_state, cfg.ssm_headdim
    nh = di // hd
    bsz, s, _ = x.shape

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * g * n]
    dt_in = zxbcdt[..., -nh:]
    conv_state = state[0] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xh = xbc[..., :di].reshape(bsz, s, nh, hd)
    b_mat = xbc[..., di:di + g * n].reshape(bsz, s, g, n).astype(jnp.float32)
    c_mat = xbc[..., di + g * n:].reshape(bsz, s, g, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    h0 = state[1] if state is not None else jnp.zeros((bsz, nh, n, hd), jnp.float32)
    y, h_fin = ssd_chunked(xh.astype(jnp.float32), dt, a, b_mat, c_mat, h0,
                           chunk=cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, s, di).astype(cd)
    y = layers.rms_norm(y * jax.nn.silu(z), p["norm"])
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cd)), (new_conv, h_fin)
