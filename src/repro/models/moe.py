"""Mixture-of-Experts FFN: top-k routing, shared experts, two dispatch modes.

Dispatch modes (selectable per config; a §Perf hillclimb axis):

  * ``einsum``  — GShard-style one-hot dispatch/combine matmuls. Faithful to
    the classic TPU formulation, fully dense and MXU-mapped, but the dispatch
    einsums cost O(T·E·C·D) FLOPs — comparable to the expert matmuls
    themselves at high expert counts (visible in cost_analysis as a low
    useful-FLOP ratio).
  * ``scatter`` — sort-based: tokens are ordered by expert, gathered into
    (E, C, D) expert buffers with take/scatter (no FLOPs), processed with a
    batched expert matmul, and scattered back. Same semantics, removes the
    dispatch-matmul FLOPs entirely.

The expert axis is sharded over the ``exp`` logical axis (folded onto the
mesh "model" axis) — expert parallelism.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.layers import EXP, FSDP, TP

# Launcher-installed NamedSharding constraint for grouped-token tensors
# (G, Tg, D): groups ride the data axes so dispatch/combine einsums are
# device-local (the GShard group dimension IS the data-parallel shard).
_GROUP_SHARDING = [None]


def set_group_sharding(sharding):
    _GROUP_SHARDING[0] = sharding


def _shard_groups(xg):
    sh = _GROUP_SHARDING[0]
    if sh is None:
        return xg
    from jax.sharding import NamedSharding, PartitionSpec as P
    ns = NamedSharding(sh.mesh, P(sh.spec[0], *([None] * (xg.ndim - 1))))
    return jax.lax.with_sharding_constraint(xg, ns)


def init_moe(key, cfg):
    d, fe, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {"gate": layers.dense_init(ks[0], (d, e), cfg.param_dtype),
         "wi": (jax.random.normal(ks[1], (e, d, fe)) / jnp.sqrt(d)).astype(cfg.param_dtype),
         "wg": (jax.random.normal(ks[2], (e, d, fe)) / jnp.sqrt(d)).astype(cfg.param_dtype),
         "wo": (jax.random.normal(ks[3], (e, fe, d)) / jnp.sqrt(fe)).astype(cfg.param_dtype)}
    if cfg.n_shared:
        p["shared"] = layers.init_mlp(ks[4], d, fe * cfg.n_shared, cfg.param_dtype)
    return p


def spec_moe(cfg):
    if cfg.expert_shard and cfg.moe_ff_fsdp:
        # 2D expert sharding: EP over model x expert-FFN dim over data.
        # Expert weights are fully sharded yet never all-gathered — the
        # (much smaller) dispatched activations reshard instead.
        p = {"gate": P(FSDP, None),
             "wi": P(EXP, None, FSDP), "wg": P(EXP, None, FSDP),
             "wo": P(EXP, FSDP, None)}
    elif cfg.expert_shard:   # EP: expert dim over the model axis
        p = {"gate": P(FSDP, None),
             "wi": P(EXP, FSDP, None), "wg": P(EXP, FSDP, None),
             "wo": P(EXP, None, FSDP)}
    else:                  # few experts: TP over the expert FFN dim instead
        p = {"gate": P(FSDP, None),
             "wi": P(None, FSDP, TP), "wg": P(None, FSDP, TP),
             "wo": P(None, TP, FSDP)}
    if cfg.n_shared:
        p["shared"] = layers.spec_mlp()
    return p


def _route(p, x, cfg):
    """Top-k routing: returns (idx (T,k), weights (T,k), aux_loss)."""
    cd = cfg.compute_dtype
    logits = jnp.einsum("td,de->te", x, p["gate"].astype(cd)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # Switch-style load-balance auxiliary loss.
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return idx, w.astype(cd), aux


def _capacity(t: int, cfg) -> int:
    c = int(t * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, (c + 127) // 128 * 128)  # lane-aligned


def moe_apply_einsum(p, x2d, cfg):
    """GShard one-hot dispatch. x2d: (T, D) -> (T, D)."""
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.top_k
    cd = cfg.compute_dtype
    idx, w, aux = _route(p, x2d, cfg)
    cap = _capacity(t, cfg)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)            # (T, k, E)
    pos = jnp.cumsum(onehot.sum(1), axis=0) - onehot.sum(1)     # (T, E) rank
    keep = pos < cap
    disp = (onehot * keep[:, None, :]).astype(cd)               # (T, k, E)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=cd)                 # (T, E, C)
    dispatch = jnp.einsum("tke,tec->tec", disp, pos_oh)         # (T, E, C)
    combine = jnp.einsum("tke,tk,tec->tec", disp, w, pos_oh)

    xin = jnp.einsum("tec,td->ecd", dispatch, x2d)              # (E, C, D)
    h = jnp.einsum("ecd,edf->ecf", xin, p["wi"].astype(cd))
    g = jnp.einsum("ecd,edf->ecf", xin, p["wg"].astype(cd))
    ho = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"].astype(cd))
    y = jnp.einsum("tec,ecd->td", combine, ho)
    return y, aux


def moe_apply_scatter(p, x2d, cfg):
    """Sort-based dispatch: no one-hot matmuls; gather/scatter + grouped GEMM."""
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.top_k
    cd = cfg.compute_dtype
    idx, w, aux = _route(p, x2d, cfg)
    cap = _capacity(t, cfg)

    flat_e = idx.reshape(-1)                                    # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = w.reshape(-1)
    # Rank of each (token, slot) within its expert, via sort-free cumsum.
    onehot = flat_e[:, None] == jnp.arange(e)                   # (T*k, E)
    rank = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(t * k), flat_e]
    keep = rank < cap
    slot = jnp.where(keep, rank, cap)                           # cap row is a trap

    # Gather tokens into expert buffers (scatter with drop on overflow).
    xin = jnp.zeros((e, cap, d), cd).at[flat_e, slot].set(
        x2d[flat_t].astype(cd), mode="drop")
    h = jnp.einsum("ecd,edf->ecf", xin, p["wi"].astype(cd))
    g = jnp.einsum("ecd,edf->ecf", xin, p["wg"].astype(cd))
    ho = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"].astype(cd))
    # Gather back and weight.
    y_tok = ho[flat_e, jnp.minimum(slot, cap - 1)] * (flat_w * keep)[:, None]
    y = jnp.zeros((t, d), cd).at[flat_t].add(y_tok)
    return y, aux


def moe_apply_grouped(p, xg, cfg):
    """GShard grouped dispatch: xg (G, Tg, D) with G riding the data axes
    (see _shard_groups) so the one-hot dispatch/combine einsums are local.
    Capacity scales with Tg, turning the ungrouped O(T^2 k cf/E) dispatch
    cost into O(T * Tg * k * cf/E)."""
    g, tg, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    cd = cfg.compute_dtype
    idx, w, aux = _route(p, xg.reshape(g * tg, d), cfg)
    idx = idx.reshape(g, tg, k)
    w = w.reshape(g, tg, k)
    cap = _capacity(tg, cfg)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)              # (G,T,k,E)
    pos = jnp.cumsum(onehot.sum(2), axis=1) - onehot.sum(2)       # (G,T,E)
    keep = pos < cap
    disp = (onehot * keep[:, :, None, :]).astype(cd)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=cd)                   # (G,T,E,C)
    dispatch = jnp.einsum("gtke,gtec->gtec", disp, pos_oh)
    combine = jnp.einsum("gtke,gtk,gtec->gtec", disp, w, pos_oh)

    xin = jnp.einsum("gtec,gtd->gecd", dispatch, xg)              # (G,E,C,D)
    h = jnp.einsum("gecd,edf->gecf", xin, p["wi"].astype(cd))
    hg = jnp.einsum("gecd,edf->gecf", xin, p["wg"].astype(cd))
    ho = jnp.einsum("gecf,efd->gecd", jax.nn.silu(hg) * h,
                    p["wo"].astype(cd))
    y = jnp.einsum("gtec,gecd->gtd", combine, ho)
    return y, aux


def moe_apply(p, x, cfg):
    """x: (B, S, D) -> (B, S, D). Routed experts + optional shared experts.

    ``cfg.moe_group_tokens`` > 0 selects the GShard grouped path."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    t = x2d.shape[0]
    gt = cfg.moe_group_tokens
    if gt and t > gt and t % gt == 0:
        xg = _shard_groups(x2d.reshape(t // gt, gt, d))
        if cfg.moe_dispatch == "scatter":
            yg, aux = jax.vmap(lambda xi: moe_apply_scatter(p, xi, cfg))(xg)
            aux = jnp.mean(aux)
        else:
            yg, aux = moe_apply_grouped(p, xg, cfg)
        y = _shard_groups(yg).reshape(t, d)
    else:
        fn = (moe_apply_scatter if cfg.moe_dispatch == "scatter"
              else moe_apply_einsum)
        y, aux = fn(p, x2d, cfg)
    y = y.reshape(b, s, d)
    if cfg.n_shared:
        y = y + layers.mlp_apply(p["shared"], x, cfg.compute_dtype)
    return y, aux
