"""Layer stacks: decoder-only (dense/MoE), hybrid (Mamba+shared-attn), and
encoder-decoder — all scan-over-layers with configurable remat.

Scan-over-layers keeps the HLO a single layer body regardless of depth
(essential for 512-device dry-run compiles) and matches how production JAX
frameworks (MaxText et al.) stack transformers. Per-layer params are stacked
along a leading L axis; PartitionSpecs gain a leading None.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention, layers, mamba, moe


def stack_spec(tree):
    """Prepend the scanned-layer axis (never sharded) to every spec."""
    return jax.tree.map(lambda s: P(None, *s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = None if cfg.remat == "full" else \
        jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint(fn, policy=policy)


# Launcher-installed NamedSharding for (B, S, D) activations at layer
# boundaries (batch over data axes, sequence over the model axis — the
# Megatron-SP analogue; XLA inserts gather/scatter around attention).
# None (default, e.g. single-device tests) disables the constraint.
_ACTIVATION_SHARDING = [None]


def set_activation_sharding(sharding):
    _ACTIVATION_SHARDING[0] = sharding


def _shard_seq(x, cfg):
    sh = _ACTIVATION_SHARDING[0]
    if sh is None or not cfg.seq_shard_activations or x.ndim != 3 \
            or x.shape[1] == 1:
        return x
    return jax.lax.with_sharding_constraint(x, sh)


# ---------------------------------------------------------------------------
# Decoder layer (dense or MoE FFN)
# ---------------------------------------------------------------------------

def init_decoder_layer(key, cfg, *, use_moe: bool):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": layers.init_rms(k1, cfg.d_model, cfg.param_dtype),
         "ln2": layers.init_rms(k2, cfg.d_model, cfg.param_dtype)}
    if cfg.mla:
        p["attn"] = attention.init_mla(k3, cfg)
    else:
        p["attn"] = attention.init_gqa(k3, cfg)
    if use_moe:
        p["moe"] = moe.init_moe(k4, cfg)
    else:
        p["mlp"] = layers.init_mlp(k4, cfg.d_model, cfg.d_ff, cfg.param_dtype)
    return p


def spec_decoder_layer(cfg, *, use_moe: bool):
    p = {"ln1": layers.spec_rms(), "ln2": layers.spec_rms()}
    p["attn"] = attention.spec_mla(cfg) if cfg.mla else attention.spec_gqa(cfg)
    if use_moe:
        p["moe"] = moe.spec_moe(cfg)
    else:
        p["mlp"] = layers.spec_mlp()
    return p


def apply_decoder_layer(p, x, cfg, positions, *, use_moe: bool, causal=True):
    """Returns (x, aux_loss)."""
    h = layers.rms_norm(x, p["ln1"])
    if cfg.mla:
        a = attention.mla_apply(p["attn"], h, cfg, positions, causal=causal)
    else:
        a = attention.gqa_apply(p["attn"], h, cfg, positions, causal=causal)
    x = _shard_seq(x + a, cfg)
    h = layers.rms_norm(x, p["ln2"])
    if use_moe:
        f, aux = moe.moe_apply(p["moe"], h, cfg)
    else:
        f, aux = layers.mlp_apply(p["mlp"], h, cfg.compute_dtype), 0.0
    return _shard_seq(x + f, cfg), aux


# ---------------------------------------------------------------------------
# Decoder-only stack (dense / moe families)
# ---------------------------------------------------------------------------

def init_decoder_stack(key, cfg):
    n_moe = cfg.n_layers - cfg.first_dense if cfg.n_experts else 0
    n_dense_scan = cfg.n_layers - n_moe - cfg.first_dense
    ks = jax.random.split(key, 3)
    p = {}
    if cfg.first_dense:
        fk = jax.random.split(ks[0], cfg.first_dense)
        p["first"] = jax.vmap(
            lambda k: init_decoder_layer(k, cfg, use_moe=False))(fk)
    main_moe = cfg.n_experts > 0
    mk = jax.random.split(ks[1], cfg.n_layers - cfg.first_dense)
    p["layers"] = jax.vmap(
        lambda k: init_decoder_layer(k, cfg, use_moe=main_moe))(mk)
    return p


def spec_decoder_stack(cfg):
    p = {}
    if cfg.first_dense:
        p["first"] = stack_spec(spec_decoder_layer(cfg, use_moe=False))
    p["layers"] = stack_spec(spec_decoder_layer(cfg, use_moe=cfg.n_experts > 0))
    return p


def apply_decoder_stack(p, x, cfg, positions, *, causal=True):
    aux_total = 0.0

    def body_dense(x, lp):
        y, _ = apply_decoder_layer(lp, x, cfg, positions, use_moe=False,
                                   causal=causal)
        return y, 0.0

    def body_main(x, lp):
        y, aux = apply_decoder_layer(lp, x, cfg, positions,
                                     use_moe=cfg.n_experts > 0, causal=causal)
        return y, aux

    if cfg.first_dense:
        x, _ = jax.lax.scan(_remat(body_dense, cfg), x, p["first"])
    x, auxs = jax.lax.scan(_remat(body_main, cfg), x, p["layers"])
    aux_total = jnp.sum(auxs) if cfg.n_experts else 0.0
    return x, aux_total


# ---------------------------------------------------------------------------
# Hybrid stack (zamba2): Mamba2 layers + one shared attention block applied
# every ``attn_every`` layers (weights shared across applications).
# ---------------------------------------------------------------------------

def init_hybrid_stack(key, cfg):
    ks = jax.random.split(key, 3)
    lk = jax.random.split(ks[0], cfg.n_layers)
    p = {"layers": jax.vmap(lambda k: {
            "ln": layers.init_rms(k, cfg.d_model, cfg.param_dtype),
            "mamba": mamba.init_mamba2(k, cfg)})(lk),
         "shared_attn": {
            "ln": layers.init_rms(ks[1], cfg.d_model, cfg.param_dtype),
            "attn": attention.init_gqa(ks[1], cfg),
            "ln2": layers.init_rms(ks[2], cfg.d_model, cfg.param_dtype),
            "mlp": layers.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.param_dtype)}}
    return p


def spec_hybrid_stack(cfg):
    return {"layers": stack_spec({"ln": layers.spec_rms(),
                                  "mamba": mamba.spec_mamba2(cfg)}),
            "shared_attn": {"ln": layers.spec_rms(),
                            "attn": attention.spec_gqa(cfg),
                            "ln2": layers.spec_rms(),
                            "mlp": layers.spec_mlp()}}


def hybrid_attn_sites(cfg):
    """Layer indices after which the shared attention block runs."""
    if not cfg.attn_every:
        return []
    return [l for l in range(cfg.n_layers) if (l + 1) % cfg.attn_every == 0]


def hybrid_groups(cfg):
    """Split n_layers into contiguous groups, each followed by one shared-
    attention application (except a trailing remainder group). Grouped form
    keeps the HLO free of lax.cond — exact FLOP accounting + site-indexed
    caches — while preserving 'shared attn every attn_every layers'."""
    sites = hybrid_attn_sites(cfg)
    bounds = [0] + [s + 1 for s in sites]
    if bounds[-1] != cfg.n_layers:
        bounds.append(cfg.n_layers)
    return list(zip(bounds[:-1], bounds[1:])), len(sites)


def _shared_attn_block(shared, x, cfg, positions):
    h = layers.rms_norm(x, shared["ln"])
    a = attention.gqa_apply(shared["attn"], h, cfg, positions, causal=True)
    x = x + a
    h = layers.rms_norm(x, shared["ln2"])
    return x + layers.mlp_apply(shared["mlp"], h, cfg.compute_dtype)


def apply_hybrid_stack(p, x, cfg, positions):
    groups, n_sites = hybrid_groups(cfg)
    shared = p["shared_attn"]

    def body(x, lp):
        h = layers.rms_norm(x, lp["ln"])
        y, _ = mamba.mamba2_apply(lp["mamba"], h, cfg)
        return _shard_seq(x + y, cfg), None

    body = _remat(body, cfg)
    attn_fn = _remat(lambda x: _shared_attn_block(shared, x, cfg, positions),
                     cfg)
    for gi, (lo, hi) in enumerate(groups):
        grp = jax.tree.map(lambda a: a[lo:hi], p["layers"])
        x, _ = jax.lax.scan(body, x, grp)
        if gi < n_sites:
            x = _shard_seq(attn_fn(x), cfg)
    return x, 0.0


# ---------------------------------------------------------------------------
# SSM stack (falcon-mamba)
# ---------------------------------------------------------------------------

def init_ssm_stack(key, cfg):
    lk = jax.random.split(key, cfg.n_layers)
    return {"layers": jax.vmap(lambda k: {
        "ln": layers.init_rms(k, cfg.d_model, cfg.param_dtype),
        "mamba": mamba.init_mamba1(k, cfg)})(lk)}


def spec_ssm_stack(cfg):
    return {"layers": stack_spec({"ln": layers.spec_rms(),
                                  "mamba": mamba.spec_mamba1(cfg)})}


def apply_ssm_stack(p, x, cfg, positions):
    def body(x, lp):
        h = layers.rms_norm(x, lp["ln"])
        y, _ = mamba.mamba1_apply(lp["mamba"], h, cfg)
        return _shard_seq(x + y, cfg), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, p["layers"])
    return x, 0.0


# ---------------------------------------------------------------------------
# Encoder-decoder stack (seamless-m4t backbone)
# ---------------------------------------------------------------------------

def init_encdec_stack(key, cfg):
    k1, k2 = jax.random.split(key)
    ek = jax.random.split(k1, cfg.encoder_layers)
    dk = jax.random.split(k2, cfg.n_layers)
    enc = jax.vmap(lambda k: init_decoder_layer(k, cfg, use_moe=False))(ek)

    def dec_layer(k):
        ka, kb = jax.random.split(k)
        p = init_decoder_layer(ka, cfg, use_moe=False)
        p["ln_x"] = layers.init_rms(kb, cfg.d_model, cfg.param_dtype)
        p["xattn"] = attention.init_gqa(kb, cfg)
        return p

    dec = jax.vmap(dec_layer)(dk)
    return {"encoder": enc, "decoder": dec}


def spec_encdec_stack(cfg):
    dec = spec_decoder_layer(cfg, use_moe=False)
    dec["ln_x"] = layers.spec_rms()
    dec["xattn"] = attention.spec_gqa(cfg)
    return {"encoder": stack_spec(spec_decoder_layer(cfg, use_moe=False)),
            "decoder": stack_spec(dec)}


def apply_encdec_stack(p, enc_x, dec_x, cfg, enc_pos, dec_pos):
    def enc_body(x, lp):
        y, _ = apply_decoder_layer(lp, x, cfg, enc_pos, use_moe=False,
                                   causal=False)
        return y, None

    enc_out, _ = jax.lax.scan(_remat(enc_body, cfg), enc_x, p["encoder"])

    def dec_body(x, lp):
        y, _ = apply_decoder_layer(lp, x, cfg, dec_pos, use_moe=False,
                                   causal=True)
        h = layers.rms_norm(y, lp["ln_x"])
        # cross-attention: kv from encoder output (non-causal)
        _, k, v = attention.gqa_project_qkv(lp["xattn"], enc_out, cfg, enc_pos)
        a = attention.gqa_apply(lp["xattn"], h, cfg, dec_pos, causal=False,
                                kv_override=(k, v))
        return _shard_seq(y + a, cfg), None

    dec_out, _ = jax.lax.scan(_remat(dec_body, cfg), dec_x, p["decoder"])
    return dec_out, 0.0
