"""Distributed in-memory shard index with slicing + retention (paper §3.4.3).

Every edge keeps a fixed-capacity table of index entries
``{shardID, bbox, trange, replicas[3]}``. An entry for a shard is written to
*every* edge owning one of the shard's spatial/temporal slices (over-
replication), so that any overlapping range query — which slices its own
predicate with the same grid — finds the shard on at least one lookup edge.

Static-shape storage (TPU adaptation):
  ent_f:  (E, CAP, 6)  float32  lat0, lat1, lon0, lon1, t0, t1
  ent_i:  (E, CAP, 5)  int32    sid_hi, sid_lo, r0, r1, r2
  valid:  (E, CAP)     bool
  cursor: (E,)         int32    append position
  dropped:(E,)         int32    entries lost to capacity overflow (telemetry)
  retired:(E,)         int32    entries invalidated by retention or repair
                                entry reclamation (telemetry)
  ent_step:(E, CAP)    int32    ingest step that wrote the entry (epoch clock
                                for the incremental-repair outage windows —
                                see ``core/repair.py``)

Retention (sustained ingest): the tuple log is a ring buffer, so an edge only
retains a sliding window of recent tuples. ``retire_entries`` invalidates
entries whose newest timestamp (t1) has fallen behind the per-edge retention
watermark — their tuples have been overwritten and a lookup hit would only
produce an empty sub-query. ``compact_index`` then squashes the surviving
entries to the front of the table so the append cursor is reusable; together
they keep the index serving indefinitely instead of saturating at CAP. The
datastore wires both into ``insert_step`` on a configurable cadence
(``StoreConfig.retention_every``).

The leading E axis is the *logical edge axis* — sharded over the device mesh
by the datastore; every operation here is batched dense array math so the
whole index is pjit-compatible.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.placement import ShardMeta


class IndexState(NamedTuple):
    ent_f: jnp.ndarray
    ent_i: jnp.ndarray
    valid: jnp.ndarray
    cursor: jnp.ndarray
    dropped: jnp.ndarray
    retired: jnp.ndarray
    ent_step: jnp.ndarray


class QueryPred(NamedTuple):
    """A spatio-temporal query predicate (paper Fig 6).

    ``has_*`` flags select which filters participate; ``is_and`` picks the
    boolean combination (§3.5.1). All fields are batched (Q,).
    """
    lat0: jnp.ndarray
    lat1: jnp.ndarray
    lon0: jnp.ndarray
    lon1: jnp.ndarray
    t0: jnp.ndarray
    t1: jnp.ndarray
    sid_hi: jnp.ndarray
    sid_lo: jnp.ndarray
    has_spatial: jnp.ndarray   # bool
    has_temporal: jnp.ndarray  # bool
    has_sid: jnp.ndarray       # bool
    is_and: jnp.ndarray        # bool


class MatchedShards(NamedTuple):
    """Index-lookup result: the shards a query must touch (paper §3.5.1)."""
    sid_hi: jnp.ndarray    # (Q, S)
    sid_lo: jnp.ndarray    # (Q, S)
    replicas: jnp.ndarray  # (Q, S, 3)
    valid: jnp.ndarray     # (Q, S)
    overflow: jnp.ndarray  # (Q,) — more than S distinct shards matched


def init_index(n_edges: int, capacity: int) -> IndexState:
    return IndexState(
        ent_f=jnp.zeros((n_edges, capacity, 6), jnp.float32),
        ent_i=jnp.full((n_edges, capacity, 5), -1, jnp.int32),
        valid=jnp.zeros((n_edges, capacity), jnp.bool_),
        cursor=jnp.zeros((n_edges,), jnp.int32),
        dropped=jnp.zeros((n_edges,), jnp.int32),
        retired=jnp.zeros((n_edges,), jnp.int32),
        ent_step=jnp.zeros((n_edges, capacity), jnp.int32),
    )


def insert_entries(state: IndexState, meta: ShardMeta, replicas: jnp.ndarray,
                   edge_mask: jnp.ndarray, step: jnp.ndarray = 0) -> IndexState:
    """Write index entries for B shards onto all edges in their slice mask.

    Args:
      meta:      ShardMeta of B shards.
      replicas:  (B, 3) replica edges.
      edge_mask: (B, E) bool — edges that must index each shard (slice owners
                 plus the replica edges themselves).
      step:      scalar int32 — the store's ingest step performing the write,
                 recorded per entry in ``ent_step`` (the epoch clock the
                 incremental repair sweep keys outage windows against).
    """
    e, cap = state.valid.shape
    b = edge_mask.shape[0]
    # Append position of shard b on edge e: cursor[e] + (rank of b among
    # shards targeting e). Dense cumsum keeps this scatter-free until the end.
    rank = jnp.cumsum(edge_mask, axis=0) - 1                      # (B, E)
    pos = state.cursor[None, :] + rank                            # (B, E)
    ok = edge_mask & (pos < cap)
    n_dropped = jnp.sum(edge_mask & (pos >= cap), axis=0)

    ee = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32), (b, e))
    # Out-of-bounds rows are dropped by scatter mode='drop'.
    pp = jnp.where(ok, pos, cap)

    vals_f = jnp.stack([meta.lat0, meta.lat1, meta.lon0, meta.lon1,
                        meta.t0, meta.t1], axis=-1)               # (B, 6)
    vals_i = jnp.concatenate([meta.sid_hi[:, None], meta.sid_lo[:, None],
                              replicas.astype(jnp.int32)], axis=-1)  # (B, 5)
    vals_f = jnp.broadcast_to(vals_f[:, None, :], (b, e, 6))
    vals_i = jnp.broadcast_to(vals_i[:, None, :], (b, e, 5))

    ent_f = state.ent_f.at[ee, pp].set(vals_f, mode="drop")
    ent_i = state.ent_i.at[ee, pp].set(vals_i, mode="drop")
    valid = state.valid.at[ee, pp].set(ok, mode="drop")
    steps = jnp.broadcast_to(jnp.asarray(step, jnp.int32), (b, e))
    ent_step = state.ent_step.at[ee, pp].set(steps, mode="drop")
    cursor = jnp.minimum(state.cursor + jnp.sum(edge_mask, axis=0), cap).astype(jnp.int32)
    return IndexState(ent_f, ent_i, valid, cursor, state.dropped + n_dropped,
                      state.retired, ent_step)


def retire_entries(state: IndexState, t_watermark: jnp.ndarray) -> IndexState:
    """Invalidate entries whose tuples have left the retention window.

    Args:
      t_watermark: (E,) float32 — per-edge oldest retained tuple timestamp
          (``-inf`` until that edge's ring buffer has wrapped).

    An entry's data lives on its *replica* edges (``ent_i[..., 2:5]``), not on
    the slice-owner edge holding the entry, so the test is replica-aware: an
    entry is retired only when its newest timestamp ``t1`` is behind the
    watermark of **every** replica edge — every tuple of the shard has
    t <= t1 < watermark[r] <= all timestamps retained on replica r, i.e. the
    shard is gone from everywhere it was stored. Entries whose data may
    survive on a slower replica edge are kept. Keeping a stale entry costs
    occupancy, not result quality: a fully-overwritten shard's id matches no
    tuple (empty sub-query), a partially-overwritten one still surfaces its
    surviving tuples. Exactness guarantees are scoped to query windows
    retained on every replica — see the retention notes in ``datastore.py``.
    """
    reps = state.ent_i[..., 2:5]                                  # (E, CAP, 3)
    rep_wm = t_watermark[jnp.clip(reps, 0, t_watermark.shape[0] - 1)]
    rep_wm = jnp.where(reps >= 0, rep_wm, jnp.inf)                # unused slots
    gone_everywhere = state.ent_f[..., 5] < jnp.min(rep_wm, axis=-1)
    stale = state.valid & gone_everywhere
    return state._replace(
        valid=state.valid & ~stale,
        retired=state.retired + jnp.sum(stale, axis=1).astype(jnp.int32))


def compact_index(state: IndexState) -> IndexState:
    """Squash valid entries to the front of each edge's table (stable order)
    and rewind the append cursor, making slots freed by ``retire_entries``
    writable again. Pure fixed-shape gather — jit/pjit compatible."""
    order = jnp.argsort(~state.valid, axis=1, stable=True)   # valid-first
    ent_f = jnp.take_along_axis(state.ent_f, order[..., None], axis=1)
    ent_i = jnp.take_along_axis(state.ent_i, order[..., None], axis=1)
    valid = jnp.take_along_axis(state.valid, order, axis=1)
    ent_step = jnp.take_along_axis(state.ent_step, order, axis=1)
    cursor = jnp.sum(state.valid, axis=1).astype(jnp.int32)
    return IndexState(ent_f, ent_i, valid, cursor, state.dropped, state.retired,
                      ent_step)


def entry_matches(state: IndexState, pred: QueryPred) -> jnp.ndarray:
    """(Q, E, CAP) bool — which index entries satisfy each query predicate."""
    f = state.ent_f  # (E, CAP, 6)
    i = state.ent_i
    def bc(x):  # (Q,) -> (Q, 1, 1)
        return x[:, None, None]
    sp = ~((bc(pred.lat1) < f[None, :, :, 0]) | (f[None, :, :, 1] < bc(pred.lat0)) |
           (bc(pred.lon1) < f[None, :, :, 2]) | (f[None, :, :, 3] < bc(pred.lon0)))
    tp = ~((bc(pred.t1) < f[None, :, :, 4]) | (f[None, :, :, 5] < bc(pred.t0)))
    ip = (i[None, :, :, 0] == bc(pred.sid_hi)) & (i[None, :, :, 1] == bc(pred.sid_lo))
    hs, ht, hi = bc(pred.has_spatial), bc(pred.has_temporal), bc(pred.has_sid)
    is_and = bc(pred.is_and)
    m_and = (sp | ~hs) & (tp | ~ht) & (ip | ~hi)
    m_or = (sp & hs) | (tp & ht) | (ip & hi)
    return jnp.where(is_and, m_and, m_or) & state.valid[None]


def dedup_matched(matched: jnp.ndarray, sid_hi: jnp.ndarray, sid_lo: jnp.ndarray,
                  replicas: jnp.ndarray, max_shards: int) -> MatchedShards:
    """Deduplicate candidate shard ids, batched over queries.

    Sorts matched-first by (sid_hi, sid_lo), keeps the first occurrence of
    each distinct sid, and compacts the distinct matches to the front (so the
    valid slots hold the ``max_shards`` smallest distinct sids in ascending
    order — a canonical form). ``overflow`` flags queries with more distinct
    matches than fit.

    Used by ``lookup`` over the whole index, and by the federated runtime to
    merge per-device candidate lists: because the valid slots are the
    lexicographically smallest distinct sids, merging each device's local
    top-``max_shards`` and re-deduplicating yields exactly the single-device
    result (any sid excluded from a local top list has >= max_shards smaller
    sids locally, hence globally — the distributed top-k argument).

    Args:
      matched:  (Q, N) bool — candidate participates.
      sid_hi:   (Q, N) int32.
      sid_lo:   (Q, N) int32.
      replicas: (Q, N, 3) int32.
    """
    def one_query(m, hi, lo, rep):
        order = jnp.lexsort((lo, hi, ~m))
        m_s, hi_s, lo_s = m[order], hi[order], lo[order]
        rep_s = rep[order]
        prev_same = jnp.concatenate([jnp.array([False]),
                                     (hi_s[1:] == hi_s[:-1]) & (lo_s[1:] == lo_s[:-1]) & m_s[:-1]])
        is_new = m_s & ~prev_same
        n_unique = jnp.sum(is_new)
        order2 = jnp.lexsort((jnp.arange(m.shape[0]), ~is_new))[:max_shards]
        return (hi_s[order2], lo_s[order2], rep_s[order2],
                is_new[order2], n_unique > max_shards)

    hi2, lo2, rep2, val2, ovf = jax.vmap(one_query)(matched, sid_hi, sid_lo,
                                                    replicas)
    return MatchedShards(hi2, lo2, rep2, val2, ovf)


def match_candidates(state: IndexState, pred: QueryPred,
                     lookup_mask: jnp.ndarray):
    """Flatten this index slice's entries into per-query candidate lists for
    ``dedup_matched``: (matched, sid_hi, sid_lo, replicas), each (Q, E*CAP).
    ``state`` may be a shard-local slice of the edge axis; ``lookup_mask`` is
    (Q, E_local) over the same slice."""
    q = pred.lat0.shape[0]
    e, cap = state.valid.shape
    match = entry_matches(state, pred) & lookup_mask[:, :, None]   # (Q, E, CAP)
    flat_m = match.reshape(q, e * cap)
    sid_hi = jnp.broadcast_to(state.ent_i[None, :, :, 0], (q, e, cap)).reshape(q, -1)
    sid_lo = jnp.broadcast_to(state.ent_i[None, :, :, 1], (q, e, cap)).reshape(q, -1)
    reps = jnp.broadcast_to(state.ent_i[None, :, :, 2:5], (q, e, cap, 3)).reshape(q, -1, 3)
    return flat_m, sid_hi, sid_lo, reps


def lookup(state: IndexState, pred: QueryPred, lookup_mask: jnp.ndarray,
           max_shards: int) -> MatchedShards:
    """Index lookup (paper §3.5.1): match entries on the selected lookup
    edges, deduplicate shard ids across edges, return up to ``max_shards``.

    Args:
      lookup_mask: (Q, E) bool — edges whose index each query consults.
    """
    flat_m, sid_hi, sid_lo, reps = match_candidates(state, pred, lookup_mask)
    return dedup_matched(flat_m, sid_hi, sid_lo, reps, max_shards)
