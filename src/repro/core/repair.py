"""Anti-entropy repair: epoch-scoped recovery re-replication after outages.

The durability story (paper §3.4.2 + §4.5.3) assumes every shard keeps
``replication`` live copies. An outage breaks that in two ways:

* shards placed **before** the outage lose live replicas while their edges
  are down (the data survives on the dead edge's frozen state, but the
  replication factor is degraded until it recovers);
* shards placed **during** the outage were placed *around* the dead edges —
  their replica sets and index entries never touch them — so a recovered
  edge comes back with an index that is silently missing every shard
  ingested while it was away. If a later query selects that edge as its only
  index-lookup edge (a narrow window whose slice grid maps to exactly that
  edge), the missing entries become silently-incomplete results.

``repair_state`` is the control-plane fix: it re-derives the canonical
placement under the *current* alive mask (the placement a shard would have
received had the outage never happened — ``place_replicas`` is deterministic
given the mask) and converges the store to it, one swept shard at a time:

  1. **re-placement** — where the canonical replica set differs from the
     stored one AND a surviving replica still holds the shard's tuples,
     every index entry of the shard is rewritten to the new set (a shard
     with no live copy left is counted unrepairable and its entries keep
     naming the dead replicas, so the degraded-query accounting keeps
     reporting the loss instead of being laundered into an empty all-clear);
  2. **tuple backfill** — for shards whose placement changed, every member
     of the new replica set that does not hold the shard's tuples (edges
     *added* by re-placement, or retained replicas whose own ring already
     overwrote the copy) receives them from the surviving replica holding
     the most (appended through the normal ring-buffer cursor in source-
     chronological order, clamped to the newest ``tuple_capacity`` tuples,
     with exact overwrite telemetry);
  3. **ring reclamation** — alive edges *outside* a swept (repairable)
     shard's canonical replica set hold copies no index entry will ever
     name again; their slots are retired eagerly (the ring is re-packed in
     chronological order, freed slots reset to the never-written sentinel)
     instead of bleeding capacity until wraparound. The re-pack rewinds
     ``tup_count`` below ``tuple_capacity``; the retention watermark stays
     live anyway — ``tup_overwritten > 0`` marks the edge as having aged
     out tuples, so the epoch-aware watermark keeps retiring from the
     re-packed (chronologically ordered) ring instead of pausing until
     re-wrap. Copies stranded on an edge that was *dead* at re-placement
     time (repair never touches dead edges, whose frozen rings may be the
     only surviving source) are reclaimed by the sweep that runs once the
     edge returns — the session's pending-sweep ledger re-selects every
     shard repaired under a degraded mask, placement re-changed or not;
  4. **index backfill** — every edge that should hold a swept shard's entry
     under the slicing contract (slice owners + replica edges,
     ``_index_edge_mask``) but does not, gets the entry appended — this is
     what plugs the recovered edge's lookup hole, including for shards
     whose replicas never changed;
  5. **entry reclamation** — the index-side mirror of step 3: alive edges
     that hold a swept shard's entry but are *outside* its canonical holder
     set (replicas moved away, or slice ownership drifted while placement
     ran under a degraded mask — e.g. shards ingested during a partition)
     have those entries retired, so the healed index converges bit-for-bit
     to the never-faulted one instead of accumulating stale lookup rows.
     Unrepairable shards are exempt, for the same keep-the-loss-visible
     reason as step 1.

Outage epochs — the O(outage) sweep contract
--------------------------------------------

Every index entry records the ingest step that wrote it (``ent_step``); the
session facade keeps a host-side ledger of failure events, each an epoch
window ``(fail_step, recover_step]`` plus the dead edge set. Passing that
ledger as ``outage=OutageLog(...)`` turns the sweep incremental: a tracked
shard is swept iff

* one of its entries was written inside a closed outage window
  (``fail_step < ent_step <= recover_step`` — it was placed around the dead
  edges and must be re-placed / re-indexed now that they are back), or
* its stored replica set intersects the affected (still-dead) edge set —
  it must be re-placed around the edges that are down right now, or
* its sid is in ``pending_sids`` — swept by an earlier repair that ran
  while some edges were still dead, so it was normalized to a *degraded*
  canonical placement and must be revisited once the mask changes again.

Everything else is provably untouched by the full sweep — placement is
deterministic, so a shard ingested under the current mask with entries on
every slice-owner edge is already canonical — and is skipped without
computing its placement, which is what makes repair cost scale with the
outage, not the store. The incremental sweep is bitwise-identical to the
full sweep (property-tested in ``tests/test_repair_incremental.py``).
Entries dropped at ingest because an index table was momentarily full
(``index.dropped``) are covered too: the session facade watches the
per-insert ``index_entries_dropped`` telemetry and folds the affected
batches' sids into ``pending_sids``, so an incremental sweep re-attempts
them exactly like ``repair(full=True)`` would. ``outage=None`` always runs
the full sweep.

The sweep is **host-side numpy** by design: repair is a rare, metadata-scale
control-plane event (like an operator-triggered rebalance), not a hot path.
It is deterministic, so the single-device and sharded runtimes — which hold
bitwise-identical states by the differential harness — stay bitwise
identical after repairing through ``AerialDB.recover_edges`` on both.
Callers on a mesh re-shard the returned state (``shard_store``).

Scope / caveats: repair needs the index (``use_index=False`` stores track no
shards — the sweep is a no-op); copies are best-effort under retention — the
source is the surviving replica holding the MOST of the shard's tuples, but
a replica that retains only a partial remnant is left as-is (appending the
full copy next to the remnant would double-count in scans, and per-tuple
dedup is not worth a control-plane path; this is the same replica retention
skew the query-exactness notes in ``datastore.py`` already scope); a shard
whose live replicas ALL died before repair is unrepairable until one of
them recovers (counted in the info dict, and surfaced per query as the
``completeness_bound`` / ``replicas_lost`` keys every ``QueryResult.view``
now carries).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.datastore import (StoreConfig, StoreState, _COUNT_SAT,
                                  _index_edge_mask)
from repro.core.index import IndexState
from repro.core.placement import ShardMeta, place_replicas

__all__ = ["OutageLog", "repair_state", "sid_key"]


def sid_key(hi, lo) -> int:
    """Pack a (sid_hi, sid_lo) pair into the sweep's 64-bit shard key."""
    return (int(hi) << 32) | (int(lo) & 0xFFFFFFFF)


class OutageLog(NamedTuple):
    """Host-side outage ledger driving the incremental sweep (see module
    docstring). Built by ``AerialDB`` from its fail/recover call history;
    hand-construct one only for direct ``repair_state`` experiments.

    windows:        closed epoch windows ``(fail_step, recover_step)`` —
                    membership is ``fail_step < ent_step <= recover_step``.
                    A window with ``fail_step == -1`` covers every entry
                    (used for adopted states with unknown outage history).
    affected_edges: union of the dead edge sets of the outages still OPEN
                    (edges dead right now) — shards whose stored replicas
                    intersect it must be re-placed around them. Edges that
                    already recovered do NOT belong here: shards placed
                    before their outage are full-sweep no-ops under the
                    restored mask, and shards placed during it are selected
                    by the closed window instead.
    pending_sids:   64-bit shard keys swept by an earlier repair that ran
                    under a degraded mask; re-swept until a repair completes
                    with every edge alive.
    """
    windows: Tuple[Tuple[int, int], ...] = ()
    affected_edges: Tuple[int, ...] = ()
    pending_sids: Tuple[int, ...] = ()


def _shard_table(ent_i, ent_f, valid):
    """Flatten valid index entries into a deduplicated shard table.

    Returns (ev, ec, entry_key, uniq_keys, first_idx): entry coordinates,
    each entry's 64-bit sid key, the ascending unique keys, and the index of
    each unique shard's first (representative) entry.
    """
    ev, ec = np.nonzero(valid)
    hi = ent_i[ev, ec, 0].astype(np.int64)
    lo = ent_i[ev, ec, 1].astype(np.int64) & 0xFFFFFFFF
    key = (hi << 32) | lo
    uniq, first = np.unique(key, return_index=True)
    return ev, ec, key, uniq, first


def _chrono_order(slots: np.ndarray, count: int, pos: int, cap: int):
    """Sort ring slot indices into write-chronological (oldest-first) order.

    Unwrapped rings (``count <= cap``) fill slots 0..count-1 in write order,
    so ascending slot IS chronological; wrapped rings start their window at
    ``pos`` (the next-overwrite = oldest slot)."""
    if count <= cap:
        return np.sort(slots)
    return slots[np.argsort((slots - pos) % cap, kind="stable")]


def _backfill_copy(tup_f, tup_sid, tup_count, tup_pos, tup_over,
                   src, dst, hit_chrono, hi, lo, cap: int) -> int:
    """Append shard (hi, lo)'s tuples from ``src``'s ring slots
    ``hit_chrono`` (chronological order) onto ``dst``'s ring through the
    normal cursor. Copies are clamped to the NEWEST ``cap`` tuples: a hit
    larger than the destination ring would scatter onto itself (duplicate
    slot ids — last write wins nondeterministically by position) and inflate
    ``tup_count`` / ``tup_overwritten`` past what the ring actually holds.
    Returns the number of tuples copied; telemetry counters are exact for
    any hit size, including ``hit == cap`` (full-ring overwrite) and
    ``hit > cap``."""
    n_copy = min(int(hit_chrono.size), cap)
    take = hit_chrono[hit_chrono.size - n_copy:]
    slots = (int(tup_pos[dst]) + np.arange(n_copy)) % cap
    tup_f[dst][:, slots] = tup_f[src][:, take]
    tup_sid[dst][0, slots] = hi
    tup_sid[dst][1, slots] = lo
    before = min(int(tup_count[dst]), cap)
    tup_count[dst] = min(int(tup_count[dst]) + n_copy, _COUNT_SAT)
    after = min(int(tup_count[dst]), cap)
    tup_over[dst] = min(int(tup_over[dst]) + before + n_copy - after,
                        _COUNT_SAT)
    tup_pos[dst] = (int(tup_pos[dst]) + n_copy) % cap
    return n_copy


def repair_state(cfg: StoreConfig, state: StoreState, alive,
                 outage: Optional[OutageLog] = None
                 ) -> Tuple[StoreState, dict]:
    """Run the anti-entropy sweep (module docstring) against ``state``.

    Args:
      cfg:    deployment config (placement + slicing geometry).
      state:  StoreState — may be sharded; leaves are pulled to host.
      alive:  (E,) bool — the CURRENT availability mask (recovered edges
              already alive; still-dead edges never receive copies/entries
              and are never mutated — their frozen rings may be the only
              surviving source).
      outage: optional ``OutageLog``. ``None`` sweeps every tracked shard
              (the full sweep); a ledger restricts the sweep to shards the
              outage could have touched — O(outage), not O(store).

    Returns (new_state, info): a host-materialized StoreState (callers on a
    mesh re-shard it) and a telemetry dict — ``shards_tracked``,
    ``shards_swept`` (placement re-derived), ``shards_replaced`` (replica
    set rewritten), ``shards_unrepairable`` (no surviving source),
    ``tuples_copied``, ``slots_reclaimed`` (stale copies retired by ring
    reclamation), ``entries_rewritten``, ``entries_backfilled``,
    ``entries_reclaimed`` (stale entries retired from non-holder edges),
    ``entries_dropped`` (backfill hit a full table), ``mode``
    (``full``/``incremental``), and ``_swept_keys`` — the swept shards' sid
    keys, consumed by the session facade's pending-sweep bookkeeping (not
    part of the stable telemetry surface).
    """
    e = state.tup_f.shape[0]
    cap = cfg.tuple_capacity
    alive_np = np.asarray(alive, bool)

    info = {"shards_tracked": 0, "shards_swept": 0, "shards_replaced": 0,
            "shards_unrepairable": 0, "tuples_copied": 0,
            "slots_reclaimed": 0, "entries_rewritten": 0,
            "entries_backfilled": 0, "entries_reclaimed": 0,
            "entries_dropped": 0,
            "mode": "full" if outage is None else "incremental",
            "_swept_keys": ()}

    ent_f = np.array(state.index.ent_f)
    ent_i = np.array(state.index.ent_i)
    valid = np.array(state.index.valid)

    ev, ec, key, uniq, first = _shard_table(ent_i, ent_f, valid)
    n = uniq.shape[0]
    info["shards_tracked"] = int(n)
    if n == 0:
        return state, info

    # Representative meta + stored replicas per tracked shard (cheap O(N)
    # gathers — placement itself is only derived for the swept subset).
    f0 = ent_f[ev[first], ec[first]]                       # (N, 6)
    old3 = ent_i[ev[first], ec[first], 2:5]                # (N, 3)

    # --- sweep selection: the O(outage) filter -------------------------
    if outage is None:
        sel = np.ones(n, bool)
    else:
        inv = np.searchsorted(uniq, key)                   # entry -> shard
        ent_step = np.asarray(state.index.ent_step)[ev, ec]
        in_win = np.zeros(ev.shape[0], bool)
        for fail_step, recover_step in outage.windows:
            in_win |= (ent_step > fail_step) & (ent_step <= recover_step)
        win_sel = np.zeros(n, bool)
        np.logical_or.at(win_sel, inv, in_win)
        aff = np.zeros(e, bool)
        if len(outage.affected_edges):
            aff[np.asarray(outage.affected_edges, int)] = True
        rep_sel = np.any((old3 >= 0) & aff[np.clip(old3, 0, e - 1)], axis=1)
        pend_sel = np.isin(
            uniq, np.asarray(outage.pending_sids, np.int64))
        sel = win_sel | rep_sel | pend_sel
    sel_idx = np.nonzero(sel)[0]
    info["shards_swept"] = int(sel_idx.size)
    info["_swept_keys"] = tuple(int(k) for k in uniq[sel_idx])
    if sel_idx.size == 0:
        # Nothing the outage could have touched — telemetry-only no-op.
        return state, info

    cursor = np.array(state.index.cursor)
    dropped = np.array(state.index.dropped)
    retired = np.array(state.index.retired)
    ent_step_tab = np.array(state.index.ent_step)
    tup_f = np.array(state.tup_f)
    tup_sid = np.array(state.tup_sid)
    tup_count = np.array(state.tup_count)
    tup_pos = np.array(state.tup_pos)
    tup_over = np.array(state.tup_overwritten)
    step_now = int(state.steps)

    # Canonical placement under the current mask (deterministic — equals the
    # never-failed placement once every edge is back). ``place_replicas`` is
    # row-independent, so deriving it for the swept subset yields exactly the
    # rows a full-store batch would.
    meta = ShardMeta(
        sid_hi=jnp.asarray(ent_i[ev[first[sel_idx]], ec[first[sel_idx]], 0]),
        sid_lo=jnp.asarray(ent_i[ev[first[sel_idx]], ec[first[sel_idx]], 1]),
        lat0=jnp.asarray(f0[sel_idx, 0]), lat1=jnp.asarray(f0[sel_idx, 1]),
        lon0=jnp.asarray(f0[sel_idx, 2]), lon1=jnp.asarray(f0[sel_idx, 3]),
        t0=jnp.asarray(f0[sel_idx, 4]), t1=jnp.asarray(f0[sel_idx, 5]))
    new = np.asarray(place_replicas(meta, cfg.sites_array(),
                                    jnp.asarray(alive_np), cfg.tau,
                                    n_domains=cfg.n_failure_domains))
    new3 = np.full((sel_idx.size, 3), -1, np.int32)
    new3[:, : cfg.replication] = new[:, : cfg.replication]

    # Where every edge should hold the swept entries: slice owners + new
    # replicas, restricted to alive edges.
    want = np.asarray(_index_edge_mask(cfg, meta, jnp.asarray(new3),
                                       cfg.sites_array(),
                                       jnp.asarray(alive_np)))  # (n_sel, E)
    # Where entries currently exist, per shard x edge.
    present = np.zeros((n, e), bool)
    present[np.searchsorted(uniq, key), ev] = True

    # Entry groups per shard, precomputed once: entries of shard i are
    # order[starts[i]:ends[i]] (avoids an O(entries) rescan per shard).
    order = np.argsort(key, kind="stable")
    starts = np.searchsorted(key, uniq, side="left", sorter=order)
    ends = np.searchsorted(key, uniq, side="right", sorter=order)

    def live_window(edge):
        """Live ring slots on ``edge`` right now (backfills grow it)."""
        return min(int(tup_count[edge]), cap)

    def holds_tuples(edge, hi, lo):
        w = live_window(edge)
        return bool(np.any((tup_sid[edge, 0, :w] == hi)
                           & (tup_sid[edge, 1, :w] == lo)))

    reclaim = {}   # edge -> set of 64-bit sid keys to retire from its ring

    for j, i in enumerate(sel_idx):
        old_set = {int(r) for r in old3[i] if r >= 0}
        new_set = {int(r) for r in new3[j] if r >= 0}
        hi = int(ent_i[ev[first[i]], ec[first[i]], 0])
        lo = int(ent_i[ev[first[i]], ec[first[i]], 1])
        unrepairable = False

        if new_set != old_set:
            # The copy source is the alive replica holding the MOST of the
            # shard's tuples: rings wrap at independent rates, so a
            # lower-id survivor may hold only a partial remnant while a
            # fuller copy lives elsewhere — propagating the remnant would
            # cement the loss.
            hit = np.empty(0, np.int64)
            src = -1
            for cand in sorted(old_set):
                if not alive_np[cand]:
                    continue
                w = live_window(cand)
                h = np.nonzero((tup_sid[cand, 0, :w] == hi)
                               & (tup_sid[cand, 1, :w] == lo))[0]
                if h.size > hit.size:
                    hit, src = h, cand
            if hit.size == 0:
                # Unrepairable: every live copy is gone. Do NOT rewrite the
                # entries — replacing the dead replica ids with fresh (empty)
                # alive ones would launder the loss and reset the degraded-
                # query accounting (replicas_lost / completeness_bound) to a
                # fabricated all-clear. Keep the stored set so queries keep
                # reporting the shard as unreachable until a copy returns
                # (step 4 below still backfills missing entries — naming the
                # dead replicas — so the loss stays VISIBLE on recovered
                # lookup edges too, instead of vanishing from their index).
                info["shards_unrepairable"] += 1
                unrepairable = True
                new3[j] = old3[i]
            else:
                # 1. rewrite every entry of this shard to the canonical set
                # (the entry's write epoch is preserved — it still dates the
                # shard's ingest, which is what outage windows test).
                idx = order[starts[i]:ends[i]]
                ent_i[ev[idx], ec[idx], 2:5] = new3[j]
                info["entries_rewritten"] += int(idx.size)
                info["shards_replaced"] += 1

                # 2. backfill tuples from the surviving copy onto every
                # member of the new replica set that does not hold them —
                # replicas *added* by re-placement, and retained replicas
                # whose own ring already overwrote the copy (verified via
                # holds_tuples, so replicas with the data are never touched).
                chrono = _chrono_order(hit, int(tup_count[src]),
                                       int(tup_pos[src]), cap)
                for dst in sorted(new_set):
                    if not alive_np[dst] or holds_tuples(dst, hi, lo):
                        continue
                    info["tuples_copied"] += _backfill_copy(
                        tup_f, tup_sid, tup_count, tup_pos, tup_over,
                        src, dst, chrono, hi, lo, cap)

        # 3. ring reclamation: alive edges outside the canonical set hold
        # copies no entry names anymore — retire their slots eagerly
        # (batched per edge after the sweep; keyed by sid so interleaved
        # backfill wraps can never be mis-dropped). Runs for unchanged-
        # placement shards too: a copy stranded on an edge that was DEAD
        # when an earlier degraded repair moved the shard away is only
        # discovered once that edge is back — by which point the stored
        # replica set already equals the canonical one. Unrepairable
        # shards are exempt (an orphan may be the last copy left).
        if not unrepairable:
            for dst in range(e):
                if alive_np[dst] and dst not in new_set:
                    reclaim.setdefault(dst, set()).add(sid_key(hi, lo))

        # 4. backfill missing index entries (slice owners + replicas) — this
        # runs for unchanged shards too: the recovered edge missed every
        # entry written while it was down, replicas moved or not.
        for dst in np.nonzero(want[j] & ~present[i])[0]:
            c = int(cursor[dst])
            if c >= valid.shape[1]:
                dropped[dst] += 1
                info["entries_dropped"] += 1
                continue
            ent_f[dst, c] = f0[i]
            ent_i[dst, c, 0] = hi
            ent_i[dst, c, 1] = lo
            ent_i[dst, c, 2:5] = new3[j]
            valid[dst, c] = True
            ent_step_tab[dst, c] = step_now
            cursor[dst] = c + 1
            info["entries_backfilled"] += 1

        # 5. entry reclamation (step 3's index mirror) — alive edges holding
        # an entry for this shard but outside its canonical holder set stop
        # indexing it. Runs for unchanged-replica shards too: slice owners
        # drift when placement ran under a degraded mask (partition-time
        # ingest), leaving extra lookup rows the reference never wrote.
        # Unrepairable shards keep every entry so the loss stays visible.
        if not unrepairable:
            idx = order[starts[i]:ends[i]]
            stale = idx[alive_np[ev[idx]] & ~want[j, ev[idx]]]
            if stale.size:
                valid[ev[stale], ec[stale]] = False
                np.add.at(retired, ev[stale], 1)
                info["entries_reclaimed"] += int(stale.size)

    # Ring reclamation re-pack (step 3, batched per edge): drop every live
    # slot whose sid was retired from this edge, squash survivors to the
    # front in chronological order, reset freed slots to the never-written
    # sentinel. Rewinding tup_count below cap is watermark-safe: the bumped
    # tup_overwritten keeps the epoch-aware retention watermark live on the
    # re-packed ring (see module docstring).
    for dst in sorted(reclaim):
        w = live_window(dst)
        if w == 0:
            continue
        chrono = _chrono_order(np.arange(w, dtype=np.int64),
                               int(tup_count[dst]), int(tup_pos[dst]), cap)
        k = ((tup_sid[dst, 0, chrono].astype(np.int64) << 32)
             | (tup_sid[dst, 1, chrono].astype(np.int64) & 0xFFFFFFFF))
        drop = np.isin(k, np.fromiter(reclaim[dst], np.int64,
                                      len(reclaim[dst])))
        n_drop = int(np.sum(drop))
        if n_drop == 0:
            continue
        keep = chrono[~drop]
        n_keep = keep.size
        tup_f[dst][:, :n_keep] = tup_f[dst][:, keep]
        tup_sid[dst][:, :n_keep] = tup_sid[dst][:, keep]
        tup_f[dst][:, n_keep:] = 0.0
        tup_sid[dst][:, n_keep:] = -1
        tup_count[dst] = n_keep
        tup_pos[dst] = n_keep % cap
        tup_over[dst] = min(int(tup_over[dst]) + n_drop, _COUNT_SAT)
        info["slots_reclaimed"] += n_drop

    index = IndexState(
        ent_f=jnp.asarray(ent_f), ent_i=jnp.asarray(ent_i),
        valid=jnp.asarray(valid), cursor=jnp.asarray(cursor),
        dropped=jnp.asarray(dropped), retired=jnp.asarray(retired),
        ent_step=jnp.asarray(ent_step_tab))
    new_state = StoreState(
        index=index, tup_f=jnp.asarray(tup_f), tup_sid=jnp.asarray(tup_sid),
        tup_count=jnp.asarray(tup_count), tup_pos=jnp.asarray(tup_pos),
        tup_overwritten=jnp.asarray(tup_over), tup_dropped=state.tup_dropped,
        steps=state.steps, latest_f=state.latest_f,
        latest_seen=state.latest_seen)
    return new_state, info
