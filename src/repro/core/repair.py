"""Anti-entropy repair: recovery re-replication after edge/device outages.

The durability story (paper §3.4.2 + §4.5.3) assumes every shard keeps
``replication`` live copies. An outage breaks that in two ways:

* shards placed **before** the outage lose live replicas while their edges
  are down (the data survives on the dead edge's frozen state, but the
  replication factor is degraded until it recovers);
* shards placed **during** the outage were placed *around* the dead edges —
  their replica sets and index entries never touch them — so a recovered
  edge comes back with an index that is silently missing every shard
  ingested while it was away. If a later query selects that edge as its only
  index-lookup edge (a narrow window whose slice grid maps to exactly that
  edge), the missing entries become silently-incomplete results.

``repair_state`` is the control-plane fix: a full anti-entropy sweep that
re-derives, for every shard tracked by the index, the canonical placement
under the *current* alive mask (the placement the shard would have received
had the outage never happened — ``place_replicas`` is deterministic given
the mask), then converges the store to it:

  1. **re-placement** — where the canonical replica set differs from the
     stored one AND a surviving replica still holds the shard's tuples,
     every index entry of the shard is rewritten to the new set (a shard
     with no live copy left is counted unrepairable and its entries keep
     naming the dead replicas, so the degraded-query accounting keeps
     reporting the loss instead of being laundered into an empty all-clear);
  2. **tuple backfill** — for shards whose placement changed, every member
     of the new replica set that does not hold the shard's tuples (edges
     *added* by re-placement, or retained replicas whose own ring already
     overwrote the copy) receives them from the first surviving replica
     that still does (appended through the normal ring-buffer cursor, with
     overwrite telemetry). Shards whose placement is unchanged are left
     alone by design: re-verifying every copy of every shard on every sweep
     would resurrect retention-aged copies wholesale, fighting the ring's
     sliding window — repair converges *outage-affected* shards, retention
     owns the rest. Edges dropped by re-placement keep their now-stale
     copies — harmless, because sub-query OR-lists only ever name shards
     assigned from index entries, and ring retention reclaims the slots;
  3. **index backfill** — every edge that should hold a shard's entry under
     the slicing contract (slice owners + replica edges, ``_index_edge_mask``)
     but does not, gets the entry appended — this is what plugs the
     recovered edge's lookup hole, including for shards whose replicas never
     changed.

The sweep is **host-side numpy** by design: repair is a rare, metadata-scale
control-plane event (like an operator-triggered rebalance), not a hot path.
It is deterministic, so the single-device and sharded runtimes — which hold
bitwise-identical states by the differential harness — stay bitwise
identical after repairing through ``AerialDB.recover_edges`` on both.
Callers on a mesh re-shard the returned state (``shard_store``).

Scope / caveats: repair needs the index (``use_index=False`` stores track no
shards — the sweep is a no-op); copies are best-effort under retention — the
source is the surviving replica holding the MOST of the shard's tuples, but
a replica that retains only a partial remnant is left as-is (appending the
full copy next to the remnant would double-count in scans, and per-tuple
dedup is not worth a control-plane path; this is the same replica retention
skew the query-exactness notes in ``datastore.py`` already scope); a shard
whose live replicas ALL died before repair is unrepairable until one of
them recovers (counted in the info dict).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.datastore import (StoreConfig, StoreState, _COUNT_SAT,
                                  _index_edge_mask)
from repro.core.index import IndexState
from repro.core.placement import ShardMeta, place_replicas

__all__ = ["repair_state"]


def _shard_table(ent_i, ent_f, valid):
    """Flatten valid index entries into a deduplicated shard table.

    Returns (ev, ec, entry_key, uniq_keys, first_idx): entry coordinates,
    each entry's 64-bit sid key, the ascending unique keys, and the index of
    each unique shard's first (representative) entry.
    """
    ev, ec = np.nonzero(valid)
    hi = ent_i[ev, ec, 0].astype(np.int64)
    lo = ent_i[ev, ec, 1].astype(np.int64) & 0xFFFFFFFF
    key = (hi << 32) | lo
    uniq, first = np.unique(key, return_index=True)
    return ev, ec, key, uniq, first


def repair_state(cfg: StoreConfig, state: StoreState,
                 alive) -> Tuple[StoreState, dict]:
    """Run the anti-entropy sweep (module docstring) against ``state``.

    Args:
      cfg:   deployment config (placement + slicing geometry).
      state: StoreState — may be sharded; leaves are pulled to host.
      alive: (E,) bool — the CURRENT availability mask (recovered edges
             already alive; still-dead edges never receive copies/entries).

    Returns (new_state, info): a host-materialized StoreState (callers on a
    mesh re-shard it) and a telemetry dict — ``shards_tracked``,
    ``shards_replaced`` (replica set rewritten), ``shards_unrepairable``
    (no surviving source), ``tuples_copied``, ``entries_rewritten``,
    ``entries_backfilled``, ``entries_dropped`` (backfill hit a full table).
    """
    e, cap_l = state.tup_f.shape[0], state.tup_f.shape[2]
    cap = cfg.tuple_capacity
    alive_np = np.asarray(alive, bool)

    ent_f = np.array(state.index.ent_f)
    ent_i = np.array(state.index.ent_i)
    valid = np.array(state.index.valid)
    cursor = np.array(state.index.cursor)
    dropped = np.array(state.index.dropped)
    tup_f = np.array(state.tup_f)
    tup_sid = np.array(state.tup_sid)
    tup_count = np.array(state.tup_count)
    tup_pos = np.array(state.tup_pos)
    tup_over = np.array(state.tup_overwritten)

    info = {"shards_tracked": 0, "shards_replaced": 0,
            "shards_unrepairable": 0, "tuples_copied": 0,
            "entries_rewritten": 0, "entries_backfilled": 0,
            "entries_dropped": 0}

    ev, ec, key, uniq, first = _shard_table(ent_i, ent_f, valid)
    n = uniq.shape[0]
    info["shards_tracked"] = int(n)
    if n == 0:
        return state, info

    # Representative meta + stored replicas per tracked shard.
    f0 = ent_f[ev[first], ec[first]]                       # (N, 6)
    old3 = ent_i[ev[first], ec[first], 2:5]                # (N, 3)
    meta = ShardMeta(
        sid_hi=jnp.asarray(ent_i[ev[first], ec[first], 0]),
        sid_lo=jnp.asarray(ent_i[ev[first], ec[first], 1]),
        lat0=jnp.asarray(f0[:, 0]), lat1=jnp.asarray(f0[:, 1]),
        lon0=jnp.asarray(f0[:, 2]), lon1=jnp.asarray(f0[:, 3]),
        t0=jnp.asarray(f0[:, 4]), t1=jnp.asarray(f0[:, 5]))

    # Canonical placement under the current mask (deterministic — equals the
    # never-failed placement once every edge is back).
    new = np.asarray(place_replicas(meta, cfg.sites_array(),
                                    jnp.asarray(alive_np), cfg.tau,
                                    n_domains=cfg.n_failure_domains))
    new3 = np.full((n, 3), -1, np.int32)
    new3[:, : cfg.replication] = new[:, : cfg.replication]

    # Where every edge should hold the entry: slice owners + new replicas.
    want = np.asarray(_index_edge_mask(cfg, meta, jnp.asarray(new3),
                                       cfg.sites_array(),
                                       jnp.asarray(alive_np)))   # (N, E)
    # Where entries currently exist, per shard x edge.
    present = np.zeros((n, e), bool)
    present[np.searchsorted(uniq, key), ev] = True

    # Entry groups per shard, precomputed once: entries of shard i are
    # order[starts[i]:ends[i]] (avoids an O(entries) rescan per shard).
    order = np.argsort(key, kind="stable")
    starts = np.searchsorted(key, uniq, side="left", sorter=order)
    ends = np.searchsorted(key, uniq, side="right", sorter=order)

    def live_window(edge):
        """Live ring slots on ``edge`` right now (backfills grow it)."""
        return min(int(tup_count[edge]), cap)

    def holds_tuples(edge, hi, lo):
        w = live_window(edge)
        return bool(np.any((tup_sid[edge, 0, :w] == hi)
                           & (tup_sid[edge, 1, :w] == lo)))

    for i in range(n):
        old_set = {int(r) for r in old3[i] if r >= 0}
        new_set = {int(r) for r in new3[i] if r >= 0}
        hi = int(ent_i[ev[first[i]], ec[first[i]], 0])
        lo = int(ent_i[ev[first[i]], ec[first[i]], 1])

        if new_set != old_set:
            # The copy source is the alive replica holding the MOST of the
            # shard's tuples: rings wrap at independent rates, so a
            # lower-id survivor may hold only a partial remnant while a
            # fuller copy lives elsewhere — propagating the remnant would
            # cement the loss.
            hit = np.empty(0, np.int64)
            src = -1
            for cand in sorted(old_set):
                if not alive_np[cand]:
                    continue
                w = live_window(cand)
                h = np.nonzero((tup_sid[cand, 0, :w] == hi)
                               & (tup_sid[cand, 1, :w] == lo))[0]
                if h.size > hit.size:
                    hit, src = h, cand
            if hit.size == 0:
                # Unrepairable: every live copy is gone. Do NOT rewrite the
                # entries — replacing the dead replica ids with fresh (empty)
                # alive ones would launder the loss and reset the degraded-
                # query accounting (replicas_lost / completeness_bound) to a
                # fabricated all-clear. Keep the stored set so queries keep
                # reporting the shard as unreachable until a copy returns
                # (step 3 below still backfills missing entries — naming the
                # dead replicas — so the loss stays VISIBLE on recovered
                # lookup edges too, instead of vanishing from their index).
                info["shards_unrepairable"] += 1
                new3[i] = old3[i]
            else:
                # 1. rewrite every entry of this shard to the canonical set.
                idx = order[starts[i]:ends[i]]
                ent_i[ev[idx], ec[idx], 2:5] = new3[i]
                info["entries_rewritten"] += int(idx.size)
                info["shards_replaced"] += 1

                # 2. backfill tuples from the surviving copy onto every
                # member of the new replica set that does not hold them —
                # replicas *added* by re-placement, and retained replicas
                # whose own ring already overwrote the copy (verified via
                # holds_tuples, so replicas with the data are never touched).
                cols_f = tup_f[src][:, hit]                # (3+V, n_hit)
                for dst in sorted(new_set):
                    if not alive_np[dst] or holds_tuples(dst, hi, lo):
                        continue
                    slots = (tup_pos[dst] + np.arange(hit.size)) % cap
                    tup_f[dst][:, slots] = cols_f
                    tup_sid[dst][0, slots] = hi
                    tup_sid[dst][1, slots] = lo
                    before = min(int(tup_count[dst]), cap)
                    tup_count[dst] = min(int(tup_count[dst]) + hit.size,
                                         _COUNT_SAT)
                    after = min(int(tup_count[dst]), cap)
                    tup_over[dst] = min(
                        int(tup_over[dst]) + before + hit.size - after,
                        _COUNT_SAT)
                    tup_pos[dst] = (int(tup_pos[dst]) + hit.size) % cap
                    info["tuples_copied"] += int(hit.size)

        # 3. backfill missing index entries (slice owners + replicas) — this
        # runs for unchanged shards too: the recovered edge missed every
        # entry written while it was down, replicas moved or not.
        for dst in np.nonzero(want[i] & ~present[i])[0]:
            c = int(cursor[dst])
            if c >= valid.shape[1]:
                dropped[dst] += 1
                info["entries_dropped"] += 1
                continue
            ent_f[dst, c] = f0[i]
            ent_i[dst, c, 0] = hi
            ent_i[dst, c, 1] = lo
            ent_i[dst, c, 2:5] = new3[i]
            valid[dst, c] = True
            cursor[dst] = c + 1
            info["entries_backfilled"] += 1

    index = IndexState(
        ent_f=jnp.asarray(ent_f), ent_i=jnp.asarray(ent_i),
        valid=jnp.asarray(valid), cursor=jnp.asarray(cursor),
        dropped=jnp.asarray(dropped), retired=state.index.retired)
    new_state = StoreState(
        index=index, tup_f=jnp.asarray(tup_f), tup_sid=jnp.asarray(tup_sid),
        tup_count=jnp.asarray(tup_count), tup_pos=jnp.asarray(tup_pos),
        tup_overwritten=jnp.asarray(tup_over), tup_dropped=state.tup_dropped,
        steps=state.steps)
    return new_state, info
