"""Query planning and load balancing (paper §3.5.2, Alg. 1).

Given the index-lookup result ``{sid -> (e_i, e_j, e_k)}`` the coordinator
selects exactly one *alive* replica edge per shard. Strategies:

  * ``random``     — uniform choice among alive replicas,
  * ``min_edges``  — greedy set cover: fewest distinct edges queried
                     (fewer sub-query invocations, more shards per edge),
  * ``min_shards`` — paper Alg. 1: iteratively give the edge with the fewest
                     remaining replicas its least-replicated shard (most
                     edges, fewest shards each, max parallelism).

All planners are pure jittable functions over fixed-shape arrays; the greedy
loops are ``lax.while_loop``s with data-independent bodies so they lower
cleanly under pjit (the coordinator runs replicated — planning is metadata-
scale work, O(S·E) per step, invariant to tuple volume).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.index import MatchedShards


def _alive_replica_mask(matched: MatchedShards, alive: jnp.ndarray) -> jnp.ndarray:
    """(Q, S, 3) bool — which replica slots are usable."""
    reps = matched.replicas
    ok = (reps >= 0) & jnp.take(alive, jnp.clip(reps, 0), axis=0)
    return ok & matched.valid[..., None]


def plan_random(matched: MatchedShards, alive: jnp.ndarray,
                key: jax.Array) -> jnp.ndarray:
    """(Q, S) int32 edge per shard, -1 where unassignable.

    ``key`` is either one key (folded with each query index internally) or a
    (Q,) batch of per-query keys. Both forms draw the same gumbels for the
    same global query index, so callers that tile the query batch (the
    compute-overlapped federated merge) stay bitwise identical to the untiled
    plan as long as they fold against GLOBAL indices and slice."""
    ok = _alive_replica_mask(matched, alive)
    q = ok.shape[0]
    if jnp.shape(key) == ():
        key = jax.vmap(jax.random.fold_in, (None, 0))(key, jnp.arange(q))

    def per_query(k, okq, repsq):
        g = jax.random.gumbel(k, okq.shape)                     # (S, 3)
        pick = jnp.argmax(jnp.where(okq, g, -jnp.inf), axis=-1)
        edge = jnp.take_along_axis(repsq, pick[..., None], axis=-1)[..., 0]
        return jnp.where(jnp.any(okq, axis=-1), edge, -1).astype(jnp.int32)

    return jax.vmap(per_query)(key, ok, matched.replicas)


def _coverage(ok: jnp.ndarray, reps: jnp.ndarray, unassigned: jnp.ndarray,
              n_edges: int) -> jnp.ndarray:
    """(E,) — #unassigned shards with an alive replica on each edge."""
    onehot = (reps[..., None] == jnp.arange(n_edges, dtype=jnp.int32))  # (S,3,E)
    m = onehot & ok[..., None] & unassigned[:, None, None]
    return jnp.sum(jnp.any(m, axis=1), axis=0)  # distinct shards per edge


def plan_min_edges(matched: MatchedShards, alive: jnp.ndarray) -> jnp.ndarray:
    """Greedy set cover: repeatedly take the edge covering the most
    unassigned shards and give it all of them."""
    n_edges = alive.shape[0]

    def per_query(reps, valid):
        ok = (reps >= 0) & jnp.take(alive, jnp.clip(reps, 0), axis=0) & valid[:, None]
        s = reps.shape[0]

        def cond(state):
            assignment, unassigned, it = state
            return jnp.any(unassigned) & (it < jnp.int32(min(n_edges, s) + 1))

        def body(state):
            assignment, unassigned, it = state
            cov = _coverage(ok, reps, unassigned, n_edges)
            best = jnp.argmax(cov).astype(jnp.int32)
            has_best = jnp.any((reps == best) & ok, axis=-1)
            take = unassigned & has_best & (cov[best] > 0)
            assignment = jnp.where(take, best, assignment)
            unassigned = unassigned & ~take & (cov[best] > 0)
            return assignment, unassigned, it + 1

        init = (jnp.full((s,), -1, jnp.int32), jnp.any(ok, axis=-1), jnp.int32(0))
        assignment, _, _ = jax.lax.while_loop(cond, body, init)
        return assignment

    return jax.vmap(per_query)(matched.replicas, matched.valid)


def plan_min_shards(matched: MatchedShards, alive: jnp.ndarray) -> jnp.ndarray:
    """Paper Alg. 1 (MinShards): one shard assigned per iteration — the
    least-loaded edge receives its least-replicated shard; that shard is then
    removed from every edge. Maximizes the number of edges participating."""
    n_edges = alive.shape[0]

    def per_query(reps, valid):
        ok0 = (reps >= 0) & jnp.take(alive, jnp.clip(reps, 0), axis=0) & valid[:, None]
        s = reps.shape[0]
        edge_ids = jnp.arange(n_edges, dtype=jnp.int32)

        def cond(state):
            assignment, ok, it = state
            return jnp.any(ok) & (it < jnp.int32(s + 1))

        def body(state):
            assignment, ok, it = state
            onehot = (reps[..., None] == edge_ids) & ok[..., None]   # (S,3,E)
            per_edge = jnp.sum(jnp.any(onehot, axis=1), axis=0)      # (E,)
            # Edge with fewest (but >0) remaining replicas.
            cnt = jnp.where(per_edge > 0, per_edge, jnp.iinfo(jnp.int32).max)
            e_star = jnp.argmin(cnt).astype(jnp.int32)
            on_e = jnp.any((reps == e_star) & ok, axis=-1)           # (S,)
            # Its shard with the fewest alive replicas overall.
            n_rep = jnp.sum(ok, axis=-1)                             # (S,)
            shard_key = jnp.where(on_e, n_rep, jnp.iinfo(jnp.int32).max)
            s_star = jnp.argmin(shard_key)
            assignment = assignment.at[s_star].set(e_star)
            ok = ok & (jnp.arange(s) != s_star)[:, None]             # remove shard
            return assignment, ok, it + 1

        init = (jnp.full((s,), -1, jnp.int32), ok0, jnp.int32(0))
        assignment, _, _ = jax.lax.while_loop(cond, body, init)
        return assignment

    return jax.vmap(per_query)(matched.replicas, matched.valid)


PLANNERS = {
    "random": plan_random,
    "min_edges": plan_min_edges,
    "min_shards": plan_min_shards,
}


def plan(strategy: str, matched: MatchedShards, alive: jnp.ndarray,
         key: jax.Array | None = None) -> jnp.ndarray:
    if strategy == "random":
        if key is None:
            raise ValueError("random planner needs a PRNG key")
        return plan_random(matched, alive, key)
    if strategy == "min_edges":
        return plan_min_edges(matched, alive)
    if strategy == "min_shards":
        return plan_min_shards(matched, alive)
    raise ValueError(f"unknown planner {strategy!r}")
