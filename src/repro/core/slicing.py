"""Fixed-size spatial/temporal slicing for the distributed index (paper §3.4.3).

A shard is *placed* by hashing the mid-point of its spatial/temporal range,
but a range query may overlap a shard without containing its mid-point. The
paper's fix: cut the shard's full spatial extent and temporal extent into
fixed-size slices, hash every slice with the same H_s / H_t, and write an
index entry on *every* resulting edge. A query then slices its own predicate
ranges the same way, and is guaranteed to hash onto at least one edge holding
the index entry of every overlapping shard.

Correctness argument (used by the property tests): if query range Q overlaps
shard range S, they share a point x; the fixed slice grid assigns x to the
same slice for both; that slice hashes to the same edge for both; the shard
indexed there is found by the query's lookup. Fixed grids are therefore
essential — both sides must quantize identically.

Static-shape realization: a range maps to a bounded number of slices
(MAX_*_SLICES, a config constant); ranges wider than the budget are covered
by *coarsening* — we also always include the mid-point slice of the exact
grid plus clamp the stride so the first and last slice are always present.
To keep overlap guarantees exact for arbitrarily wide ranges, edge sets are
represented as multi-hot masks over E and slices beyond the budget fall back
to marking the query/shard as "broadcast" (all edges) — the paper's own
degenerate case for unindexable predicates (§3.5.1).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core import hashing
from repro.core.voronoi import hash_spatial


class SliceConfig(NamedTuple):
    """Static slicing geometry, shared by insert and query paths."""
    tau: float = 300.0          # temporal slice width (seconds); paper uses 5 min
    cell: float = 0.01          # spatial grid cell width (degrees ~ 1.1 km)
    max_t_slices: int = 16      # static budget of temporal slices per range
    max_s_slices: int = 16      # static budget of spatial cells per range (per axis: sqrt)
    lat0: float = 0.0           # grid origin
    lon0: float = 0.0


def temporal_slice_edges(t0: jnp.ndarray, t1: jnp.ndarray, n_edges: int,
                         cfg: SliceConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multi-hot (..., E) mask of edges owning the temporal slices of [t0, t1].

    Returns (mask, overflow): overflow=True marks ranges wider than the static
    slice budget — callers must broadcast for those (exactness fallback).
    """
    b0 = hashing.time_bucket(t0, cfg.tau)
    b1 = hashing.time_bucket(t1, cfg.tau)
    n_slices = b1 - b0 + 1                                  # (...,)
    overflow = n_slices > cfg.max_t_slices
    k = jnp.arange(cfg.max_t_slices, dtype=jnp.int32)       # (K,)
    buckets = b0[..., None] + k                             # (..., K)
    valid = k < n_slices[..., None]
    edges = hashing.hash_time_bucket(buckets, n_edges)      # (..., K)
    mask = jnp.zeros(t0.shape + (n_edges,), dtype=jnp.bool_)
    mask = _scatter_multihot(mask, edges, valid)
    return mask, overflow


def spatial_slice_edges(lat0, lat1, lon0, lon1, sites: jnp.ndarray,
                        cfg: SliceConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multi-hot (..., E) mask of edges owning the spatial cells of a bbox.

    Cells are a fixed grid of width cfg.cell; each covered cell's center is
    located in the Voronoi diagram (H_s). Budget is max_s_slices per axis.

    Returns (mask, overflow): overflow=True marks bboxes wider than the
    static slice budget — callers must broadcast for those.
    """
    n_edges = sites.shape[0]
    i0 = jnp.floor((lat0 - cfg.lat0) / cfg.cell).astype(jnp.int32)
    i1 = jnp.floor((lat1 - cfg.lat0) / cfg.cell).astype(jnp.int32)
    j0 = jnp.floor((lon0 - cfg.lon0) / cfg.cell).astype(jnp.int32)
    j1 = jnp.floor((lon1 - cfg.lon0) / cfg.cell).astype(jnp.int32)
    ni = i1 - i0 + 1
    nj = j1 - j0 + 1
    overflow = (ni > cfg.max_s_slices) | (nj > cfg.max_s_slices)
    k = jnp.arange(cfg.max_s_slices, dtype=jnp.int32)
    ii = i0[..., None] + k                                  # (..., K)
    jj = j0[..., None] + k
    vi = k < ni[..., None]
    vj = k < nj[..., None]
    # Cell centers for the KxK cartesian product of covered rows/cols.
    clat = cfg.lat0 + (ii.astype(jnp.float32) + 0.5) * cfg.cell
    clon = cfg.lon0 + (jj.astype(jnp.float32) + 0.5) * cfg.cell
    glat = jnp.broadcast_to(clat[..., :, None], clat.shape[:-1] + (cfg.max_s_slices, cfg.max_s_slices))
    glon = jnp.broadcast_to(clon[..., None, :], clon.shape[:-1] + (cfg.max_s_slices, cfg.max_s_slices))
    gvalid = vi[..., :, None] & vj[..., None, :]
    edges = hash_spatial(glat, glon, sites)                 # (..., K, K)
    flat_edges = edges.reshape(edges.shape[:-2] + (-1,))
    flat_valid = gvalid.reshape(gvalid.shape[:-2] + (-1,))
    mask = jnp.zeros(flat_edges.shape[:-1] + (n_edges,), dtype=jnp.bool_)
    mask = _scatter_multihot(mask, flat_edges, flat_valid)
    return mask, overflow


def _scatter_multihot(mask: jnp.ndarray, idx: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """mask[..., E] |= one_hot(idx[..., K]) where valid — via a dense one-hot
    reduction (TPU-friendly; K and E are small statics)."""
    e = mask.shape[-1]
    onehot = (idx[..., None] == jnp.arange(e, dtype=jnp.int32)) & valid[..., None]
    return mask | jnp.any(onehot, axis=-2)
