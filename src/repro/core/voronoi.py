"""Spatial hashing H_s via Voronoi point-location (paper §3.4.1).

The paper partitions the city with a Voronoi tessellation over the edge-server
sites (built with Fortune's sweepline) and defines H_s(lat, lon) as the edge
whose cell contains the point.

TPU adaptation: point-location in a Voronoi diagram is *exactly* nearest-site
search, so instead of constructing the polygon arrangement (a CPU-geometry
algorithm with irregular control flow) we evaluate all E sites at once on the
MXU using the matmul expansion

    ||p - s||^2 = ||p||^2 - 2 p.s + ||s||^2,

and take the argmin over sites. This yields the identical partition to the
paper's Fortune construction, with dense hardware-aligned compute. The
perf-critical version is the Pallas kernel in ``repro.kernels.voronoi_assign``;
this module is the jnp implementation used by the rest of the system.
"""

from __future__ import annotations

import jax.numpy as jnp


def voronoi_assign(points: jnp.ndarray, sites: jnp.ndarray) -> jnp.ndarray:
    """Assign each point to the Voronoi cell (edge) of its nearest site.

    Args:
      points: (..., 2) float array of (lat, lon).
      sites:  (E, 2) float array of edge locations.

    Returns:
      (...,) int32 edge indices. Ties break toward the lower edge index,
      which makes the partition deterministic (matters for boundary points).
    """
    # Center on the site centroid first: raw geographic coordinates (~77.6
    # deg lon) make ||s||^2 ~ 6e3 while inter-site gaps are ~1e-4, so the
    # uncentered matmul form cancels catastrophically in fp32. Centering is
    # argmin-invariant and restores ~1e-9 resolution.
    c = jnp.mean(sites.astype(jnp.float32), axis=0)
    p = points.astype(jnp.float32) - c
    s = sites.astype(jnp.float32) - c
    # ||p||^2 is constant over the argmin and dropped.
    cross = p @ s.T                                   # (..., E) on the MXU
    s_norm = jnp.sum(s * s, axis=-1)                  # (E,)
    dist = s_norm[None, :] - 2.0 * cross if p.ndim == 2 else s_norm - 2.0 * cross
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)


def hash_spatial(lat: jnp.ndarray, lon: jnp.ndarray, sites: jnp.ndarray) -> jnp.ndarray:
    """H_s: (lat, lon) -> edge index via Voronoi point-location."""
    pts = jnp.stack([lat, lon], axis=-1)
    flat = pts.reshape(-1, 2)
    return voronoi_assign(flat, sites).reshape(lat.shape)
