"""Replica placement with successor fallback (paper §3.4.1–3.4.2).

Three replicas per shard, one per content dimension:

    r_s = H_s(spatial mid-point)     r_t = H_t(temporal mid-point)
    r_i = H_i(shardID)

If a produced edge collides with an earlier replica of the same shard, or is
dead (failure mask), the replica moves to the *immediate successor* edge id in
the deterministic ascending order — resolved here with a vectorized
first-alive-offset search instead of a sequential probe loop.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import hashing
from repro.core.voronoi import hash_spatial


class ShardMeta(NamedTuple):
    """Metadata accompanying a shard insertion (paper Fig 2)."""
    sid_hi: jnp.ndarray   # (B,) int32 — shardID high word
    sid_lo: jnp.ndarray   # (B,) int32 — shardID low word
    lat0: jnp.ndarray     # (B,) float32 — bbox
    lat1: jnp.ndarray
    lon0: jnp.ndarray
    lon1: jnp.ndarray
    t0: jnp.ndarray       # (B,) float32 — temporal range
    t1: jnp.ndarray


def successor_resolve(start: jnp.ndarray, forbidden: jnp.ndarray) -> jnp.ndarray:
    """First edge >= start (cyclically) that is not forbidden.

    Args:
      start:     (B,) int32 candidate edge ids.
      forbidden: (B, E) bool — dead or already-used edges.

    Returns (B,) int32 resolved edges; if all edges are forbidden, returns
    ``start`` unchanged (caller handles the degenerate total-failure case).
    """
    e = forbidden.shape[-1]
    offs = jnp.arange(e, dtype=jnp.int32)
    idx = (start[..., None] + offs) % e                      # (B, E) probe order
    ok = ~jnp.take_along_axis(forbidden, idx, axis=-1)       # (B, E)
    first = jnp.argmax(ok, axis=-1)                          # first True offset
    any_ok = jnp.any(ok, axis=-1)
    resolved = jnp.take_along_axis(idx, first[..., None], axis=-1)[..., 0]
    return jnp.where(any_ok, resolved, start).astype(jnp.int32)


def place_replicas(meta: ShardMeta, sites: jnp.ndarray, alive: jnp.ndarray,
                   tau: float) -> jnp.ndarray:
    """Compute the 3 replica edges for each shard (paper §3.4.2).

    Args:
      meta:  ShardMeta of B shards.
      sites: (E, 2) edge locations.
      alive: (E,) bool availability mask.
      tau:   temporal bucket width for H_t.

    Returns:
      (B, 3) int32 distinct, alive edge ids (ordering: spatial, temporal, id).
    """
    e = sites.shape[0]
    mid_lat = 0.5 * (meta.lat0 + meta.lat1)
    mid_lon = 0.5 * (meta.lon0 + meta.lon1)
    mid_t = 0.5 * (meta.t0 + meta.t1)

    cand_s = hash_spatial(mid_lat, mid_lon, sites)
    cand_t = hashing.hash_time(mid_t, tau, e)
    cand_i = hashing.hash_shard_id(meta.sid_hi, meta.sid_lo, e)

    dead = ~jnp.broadcast_to(alive, cand_s.shape + (e,))
    eye = jnp.arange(e, dtype=jnp.int32)

    r0 = successor_resolve(cand_s, dead)
    used = dead | (eye == r0[..., None])
    r1 = successor_resolve(cand_t, used)
    used = used | (eye == r1[..., None])
    r2 = successor_resolve(cand_i, used)
    return jnp.stack([r0, r1, r2], axis=-1)


def parent_edge(lat: jnp.ndarray, lon: jnp.ndarray, sites: jnp.ndarray,
                alive: jnp.ndarray) -> jnp.ndarray:
    """Parent edge of a drone: Voronoi cell over its current location
    (paper §3.3), falling back to the successor if that edge is down."""
    cand = hash_spatial(lat, lon, sites)
    dead = ~jnp.broadcast_to(alive, cand.shape + (alive.shape[0],))
    return successor_resolve(cand, dead)
