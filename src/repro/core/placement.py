"""Replica placement with successor fallback (paper §3.4.1–3.4.2).

Three replicas per shard, one per content dimension:

    r_s = H_s(spatial mid-point)     r_t = H_t(temporal mid-point)
    r_i = H_i(shardID)

If a produced edge collides with an earlier replica of the same shard, or is
dead (failure mask), the replica moves to the *immediate successor* edge id in
the deterministic ascending order — resolved here with a vectorized
first-alive-offset search instead of a sequential probe loop.

Mass-failure contract: when fewer edges are alive than replica slots, the
unsatisfiable slots are **explicitly degraded to the ``-1`` sentinel** (never
a duplicate or dead edge id) — the same sentinel the index already uses for
unfilled replica slots, so ``insert_local``'s dispatch, ``insert_entries``,
``retire_entries``, and every planner skip them without special-casing. With
0 alive edges all three slots are -1 and the batch is (explicitly) dropped.

Failure-domain spreading (``n_domains > 1``): the edge axis is divided into
``n_domains`` contiguous blocks (device blocks of the sharded runtime — see
the layout contract in ``core.datastore``). The temporal replica ``r_t``
additionally avoids the failure domain hosting ``r_s`` *whenever an alive,
unused edge exists outside it*, so every shard's replica set spans >= 2
distinct domains (whenever >= 2 domains have alive edges) and a whole-device
loss can never take out all copies. The constraint is advisory — when only
``r_s``'s domain has alive edges left it falls back to the plain successor
probe, never to a dead or duplicate edge. ``n_domains == 1`` is bit-identical
to the unconstrained placement.

Only ``r_t`` carries the constraint, deliberately: spatial and temporal
index *lookups* are served by slice-owner entries written independently of
replica locations, so moving ``r_s``/``r_t`` is invisible to them — but sid
point-lookups consult exactly ``H_i(shardID)``, whose entry exists only
because ``r_i`` is that edge (or its collision successor, itself a replica).
Constraining ``r_i`` would strand sid lookups on an alive edge holding no
entry; constraining ``r_s`` would similarly skew the spatial-locality story
(paper §3.4.1) for no extra durability.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import hashing
from repro.core.voronoi import hash_spatial


class ShardMeta(NamedTuple):
    """Metadata accompanying a shard insertion (paper Fig 2)."""
    sid_hi: jnp.ndarray   # (B,) int32 — shardID high word
    sid_lo: jnp.ndarray   # (B,) int32 — shardID low word
    lat0: jnp.ndarray     # (B,) float32 — bbox
    lat1: jnp.ndarray
    lon0: jnp.ndarray
    lon1: jnp.ndarray
    t0: jnp.ndarray       # (B,) float32 — temporal range
    t1: jnp.ndarray


def successor_resolve(start: jnp.ndarray, forbidden: jnp.ndarray) -> jnp.ndarray:
    """First edge >= start (cyclically) that is not forbidden.

    Args:
      start:     (B,) int32 candidate edge ids.
      forbidden: (B, E) bool — dead or already-used edges.

    Returns (B,) int32 resolved edges; if all edges are forbidden, returns
    the ``-1`` sentinel (an explicitly-degraded slot — the historical
    behaviour of returning ``start`` handed callers a dead or duplicate edge
    that no caller actually handled).
    """
    e = forbidden.shape[-1]
    offs = jnp.arange(e, dtype=jnp.int32)
    idx = (start[..., None] + offs) % e                      # (B, E) probe order
    ok = ~jnp.take_along_axis(forbidden, idx, axis=-1)       # (B, E)
    first = jnp.argmax(ok, axis=-1)                          # first True offset
    any_ok = jnp.any(ok, axis=-1)
    resolved = jnp.take_along_axis(idx, first[..., None], axis=-1)[..., 0]
    return jnp.where(any_ok, resolved, -1).astype(jnp.int32)


def edge_domains(n_edges: int, n_domains: int) -> jnp.ndarray:
    """(E,) int32 — failure domain of each edge: ``n_domains`` contiguous
    blocks of ``E / n_domains`` edges, matching the sharded runtime's
    device-block layout (device d hosts exactly domain d when the mesh size
    equals ``n_domains``)."""
    if n_domains < 1 or n_edges % n_domains:
        raise ValueError(
            f"n_domains={n_domains} must be >= 1 and divide n_edges="
            f"{n_edges} (contiguous device blocks).")
    return jnp.arange(n_edges, dtype=jnp.int32) // (n_edges // n_domains)


def _spread_resolve(cand: jnp.ndarray, used: jnp.ndarray,
                    dom_used: jnp.ndarray) -> jnp.ndarray:
    """Successor-resolve ``cand`` preferring edges outside the failure
    domains already hosting a replica (``dom_used``: (B, E) bool). The
    domain constraint applies only where some non-``used`` edge exists
    outside those domains; otherwise it degrades to the plain probe."""
    constrained = used | dom_used
    can_spread = jnp.any(~constrained, axis=-1)              # (B,)
    forbidden = jnp.where(can_spread[..., None], constrained, used)
    return successor_resolve(cand, forbidden)


def place_replicas(meta: ShardMeta, sites: jnp.ndarray, alive: jnp.ndarray,
                   tau: float, n_domains: int = 1) -> jnp.ndarray:
    """Compute the 3 replica edges for each shard (paper §3.4.2).

    Args:
      meta:      ShardMeta of B shards.
      sites:     (E, 2) edge locations.
      alive:     (E,) bool availability mask.
      tau:       temporal bucket width for H_t.
      n_domains: failure domains (contiguous device blocks) to spread the
                 replica set across; 1 = unconstrained hash placement.

    Returns:
      (B, 3) int32 replica edge ids (ordering: spatial, temporal, id).
      Slots are distinct and alive; with fewer than 3 alive edges the
      unsatisfiable trailing slots degrade to ``-1`` (see module docstring),
      and with ``n_domains > 1`` the temporal replica avoids the spatial
      replica's failure domain when the alive mask allows (>= 2 domains
      spanned — the whole-device durability invariant).
    """
    e = sites.shape[0]
    mid_lat = 0.5 * (meta.lat0 + meta.lat1)
    mid_lon = 0.5 * (meta.lon0 + meta.lon1)
    mid_t = 0.5 * (meta.t0 + meta.t1)

    cand_s = hash_spatial(mid_lat, mid_lon, sites)
    cand_t = hashing.hash_time(mid_t, tau, e)
    cand_i = hashing.hash_shard_id(meta.sid_hi, meta.sid_lo, e)

    dead = ~jnp.broadcast_to(alive, cand_s.shape + (e,))
    eye = jnp.arange(e, dtype=jnp.int32)

    r0 = successor_resolve(cand_s, dead)
    used = dead | (eye == r0[..., None])
    if n_domains == 1:
        r1 = successor_resolve(cand_t, used)
    else:
        dom = edge_domains(e, n_domains)                     # (E,)
        r0_dom = jnp.where(r0 >= 0, dom[jnp.clip(r0, 0)], -1)
        dom_used = dom[None, :] == r0_dom[..., None]         # (B, E)
        r1 = _spread_resolve(cand_t, used, dom_used)
    used = used | (eye == r1[..., None])
    # r_i stays the plain successor of H_i(shardID): sid point-lookups
    # consult exactly that edge (module docstring).
    r2 = successor_resolve(cand_i, used)
    return jnp.stack([r0, r1, r2], axis=-1)


def parent_edge(lat: jnp.ndarray, lon: jnp.ndarray, sites: jnp.ndarray,
                alive: jnp.ndarray) -> jnp.ndarray:
    """Parent edge of a drone: Voronoi cell over its current location
    (paper §3.3), falling back to the successor if that edge is down
    (``-1`` when no edge is alive at all)."""
    cand = hash_spatial(lat, lon, sites)
    dead = ~jnp.broadcast_to(alive, cand.shape + (alive.shape[0],))
    return successor_resolve(cand, dead)
