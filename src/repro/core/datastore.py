"""AerialDB datastore: federated insert and decentralized query (paper §3).

State layout — every array carries the *logical edge axis* E in front, which
the launcher shards over the device mesh (edges ≈ experts in an MoE: the
insertion path literally reuses the dispatch-by-one-hot pattern). All
operations are pure jittable functions: ``insert_step(state, shards) ->
(state, info)`` and ``query_step(state, queries) -> (results, info)``.

Sharded-state layout contract (the federation story, paper §3.3): the leading
E dimension of every ``StoreState`` array (including the nested ``IndexState``)
is the mesh axis ``"edge"`` — each device of an ``("edge",)`` mesh hosts a
contiguous block of ``E / n_devices`` ground edge servers, exactly like one
edge site owning its local InfluxDB. The bodies here are therefore factored as
*shard-local* functions (``insert_local`` / ``query_local``) parameterized by
``edge_ids`` — the global ids of the edges this state slice holds — plus a
collective hook for the two metadata-scale cross-device exchanges (the
retention-watermark all-gather and the candidate-shard merge).
``insert_step``/``query_step`` are the 1-device special case
(``edge_ids = arange(E)``, identity hooks); ``repro.distributed.federation``
wraps the same bodies in ``shard_map`` so the per-edge tuple scan runs
device-local and only the final (Q, E) combine crosses devices.

  tup_f:   (E, 3+V, CAP_L) float32   COLUMN-MAJOR tuple log: row r of edge e
                                     is field r (t, lat, lon, v0..) over all
                                     log slots — the tuple axis is LAST
  tup_sid: (E, 2, CAP_L)   int32     owning shard id rows (hi, lo)
  tup_count: (E,)          int32     total tuples EVER written (monotonic)
  tup_pos: (E,)            int32     ring write cursor in [0, capacity)
  tup_overwritten, tup_dropped: (E,) retention / loss telemetry
  index:   IndexState                sliced distributed index (index.py)

Column-major log layout (the scan-engine contract): the tuple axis is the
*minor* (lane) dimension, sized ``CAP_L = StoreConfig.padded_capacity`` — the
logical ``tuple_capacity`` rounded up to a 128-lane multiple at
``init_store``. Queries therefore stream each field as unit-stride
128-aligned vector loads with **no relayout and no padding at query time**;
the cost moved to the insert path, whose scatter writes one *column* (all
3+V+2 field rows of a slot) per tuple instead of one contiguous row — a
strided write of a few words per tuple, amortized far below the one-hot
dispatch that surrounds it. Lane-padding slots in
``[tuple_capacity, padded_capacity)`` are never written and never admitted:
ring positions are taken modulo the LOGICAL capacity, and both scan engines
clamp validity to ``slot < min(tup_count, tuple_capacity)``.

Retention semantics (sustained ingest, paper §3.4: drones offload 60-sample
shards every 5 minutes *indefinitely*): the tuple log is a **ring buffer** —
``tup_count`` counts every tuple ever written and the physical slot is
``position % tuple_capacity``, so once an edge's log is full new tuples
overwrite the oldest ones instead of being dropped. The retained window on an
edge is always the most recent ``min(tup_count, tuple_capacity)`` tuples
(scan validity rule ``slot < min(count, cap)``). ``tup_overwritten`` counts
tuples aged out by retention; ``tup_dropped`` counts tuples actually *lost*
(stays 0 under ring-buffer semantics). Every ``retention_every``-th insert
step derives a per-edge watermark (oldest retained timestamp, once the ring
has wrapped) and runs ``index.retire_entries`` + ``index.compact_index`` so
the shard index tracks the same sliding window instead of saturating.

Query exactness under retention: replicas' rings wrap at independent rates,
and the planner picks one replica per shard without retention awareness, so
exact results are guaranteed for windows that lie inside *every* replica's
retained window (what the sustained-ingest tests and fig15 assert). Windows
straddling the retention boundary are answered best-effort — a
faster-wrapping replica may already have overwritten tuples a slower one
still holds; loss is bounded by the replicas' retention skew.

The per-edge query engine (the paper's InfluxDB role) is a predicate scan —
``repro.kernels.st_scan`` provides the Pallas TPU kernel; ``scan_engine`` here
dispatches to it or to the jnp reference.
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import lru_cache, partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, planner as planner_lib
from repro.core.index import (IndexState, QueryPred, compact_index,
                              init_index, insert_entries, lookup,
                              retire_entries)
from repro.core.placement import ShardMeta, place_replicas
from repro.core.slicing import SliceConfig, spatial_slice_edges, temporal_slice_edges


class EdgeCollectives(NamedTuple):
    """Axis-parameterized collective hook bundle for the shard-local bodies.

    The shard-local bodies (``insert_local`` / ``query_local``) are mesh-
    agnostic: the two metadata-scale cross-device exchanges they need are
    injected through this bundle, so the same bodies serve the single-device
    runtime (identity hooks — ``LOCAL_COLLECTIVES``), the 1-D ``("edge",)``
    mesh, and the 2-D ``("fleet", "edge")`` cross-host mesh
    (``distributed.federation.make_collectives`` builds the bundle from the
    mesh's edge-bearing axes; on the fleet mesh the candidate merge is
    hierarchical — intra-fleet first, inter-fleet over the reduced set).

      gather_watermark: (E_local,) local retention watermark -> (E,) global
          (identity on one device; all-gather over the edge-bearing axes
          under shard_map).
      combine_matched:  (MatchedShards over local edges, max_shards) ->
          globally-merged MatchedShards every device plans against
          (identity on one device; hierarchical all-gather + top-S
          re-dedup under shard_map — bit-identical to the single-device
          lookup, see ``index.dedup_matched``).
    """
    gather_watermark: Callable
    combine_matched: Callable


#: Identity hooks — the 1-device special case (``edge_ids == arange(E)``).
LOCAL_COLLECTIVES = EdgeCollectives(
    gather_watermark=lambda wm: wm,
    combine_matched=lambda matched, max_shards: matched)


def _default_site_grid(n_edges: int) -> Tuple[Tuple[float, float], ...]:
    """Deterministic lat/lon grid over the synthetic-city bbox, slightly
    inset — used when ``sites`` is left empty so a default-constructed
    StoreConfig is immediately usable. Bounds come from CityConfig itself
    (lazy import; the data layer already depends on core) so the default
    deployment region can never drift from the default data region."""
    from repro.data.synthetic import CityConfig
    city = CityConfig()
    pad_lat = 0.08 * (city.lat_max - city.lat_min)
    pad_lon = 0.08 * (city.lon_max - city.lon_min)
    rows = int(np.ceil(np.sqrt(n_edges)))
    cols = int(np.ceil(n_edges / rows))
    lat = np.linspace(city.lat_min + pad_lat, city.lat_max - pad_lat, rows)
    lon = np.linspace(city.lon_min + pad_lon, city.lon_max - pad_lon, cols)
    grid = [(float(la), float(lo)) for la in lat for lo in lon]
    return tuple(grid[:n_edges])


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    """Static configuration of an AerialDB deployment."""
    n_edges: int = 20
    sites: Tuple[Tuple[float, float], ...] = ()   # (E, 2) edge locations
    tau: float = 300.0
    slice_cfg: SliceConfig = SliceConfig()
    tuple_capacity: int = 1 << 14                 # ring-buffer slots per edge
    index_capacity: int = 1 << 12                 # index entries per edge
    max_shards_per_query: int = 128               # S
    records_per_shard: int = 60                   # R (paper: 60 samples / 5 min)
    n_values: int = 4                             # sensor channels per tuple
    replication: int = 3                          # 1 => Feather-like baseline
    use_index: bool = True                        # False => broadcast baseline
    planner: str = "min_shards"
    or_group: int = 150                           # paper: sub-queries split at 150 sids
    retention_every: int = 4                      # insert steps between index sweeps
    n_failure_domains: int = 1                    # contiguous device blocks to spread
                                                  # each shard's replicas across
    max_drones: int = 0                           # latest-per-drone hot-cache rows
                                                  # (0 disables the cache)

    def __post_init__(self):
        if not (1 <= self.replication <= 3):
            raise ValueError(
                f"replication={self.replication} is unsupported: index entries "
                "carry exactly 3 replica slots (paper §3.4.2); pass "
                "1 <= replication <= 3.")
        if not self.use_index and self.replication != 1:
            raise ValueError(
                f"use_index=False with replication={self.replication} would "
                f"overcount results ~{self.replication}x: the broadcast "
                "baseline has no shard scoping, so every replica edge scans "
                "every tuple. Use replication=1 for the Feather-like "
                "baseline, or keep the index enabled.")
        if self.retention_every < 1:
            raise ValueError(
                f"retention_every={self.retention_every} must be >= 1 (index "
                "retention sweeps run every retention_every insert steps).")
        if self.max_drones < 0:
            raise ValueError(
                f"max_drones={self.max_drones} must be >= 0: it sizes the "
                "latest-per-drone hot cache (0 disables it; drone ids >= "
                "max_drones are not cached).")
        if self.n_failure_domains < 1 or self.n_edges % self.n_failure_domains:
            raise ValueError(
                f"n_failure_domains={self.n_failure_domains} must be >= 1 and "
                f"divide n_edges={self.n_edges}: failure domains are the "
                "contiguous device blocks of the sharded layout contract "
                "(one block of E / n_failure_domains edges each).")
        if not self.sites:
            object.__setattr__(self, "sites", _default_site_grid(self.n_edges))
        elif len(self.sites) != self.n_edges:
            raise ValueError(
                f"sites has {len(self.sites)} entries but n_edges="
                f"{self.n_edges}; pass one (lat, lon) per edge or leave "
                "sites=() for a deterministic default grid.")

    @property
    def tuple_width(self) -> int:
        return 3 + self.n_values

    @property
    def padded_capacity(self) -> int:
        """Stored (lane-aligned) size of the tuple axis: ``tuple_capacity``
        rounded up to a 128 multiple, so the column-major log's minor dim is
        always vector-lane aligned. Slots >= ``tuple_capacity`` are dead —
        never written, never scanned."""
        return -(-self.tuple_capacity // 128) * 128

    def sites_array(self) -> jnp.ndarray:
        return jnp.asarray(np.asarray(self.sites, np.float32).reshape(self.n_edges, 2))


class StoreState(NamedTuple):
    index: IndexState
    tup_f: jnp.ndarray
    tup_sid: jnp.ndarray
    tup_count: jnp.ndarray        # (E,) total tuples ever written (monotonic;
                                  #      saturates near 2^31 — see _COUNT_SAT)
    tup_pos: jnp.ndarray          # (E,) ring write cursor, always in [0, cap)
    tup_overwritten: jnp.ndarray  # (E,) tuples aged out by ring retention
    tup_dropped: jnp.ndarray      # (E,) tuples actually lost (0 by design)
    steps: jnp.ndarray            # () insert steps executed (retention cadence)
    latest_f: jnp.ndarray         # (D, 3+V) latest-per-drone hot cache —
                                  #      max-t record per drone id, REPLICATED
                                  #      across the mesh (D = cfg.max_drones)
    latest_seen: jnp.ndarray      # (D,) insert step that last updated each
                                  #      drone's cache row; -1 = never seen


class LatestResult(NamedTuple):
    """``AerialDB.latest()`` / ``Query().latest()`` answer: the O(drones)
    hot-cache read (paper §4.4 near-real-time shape — Wingxtra's "latest
    position matters more than history" rule), bypassing the log scan and
    the index entirely.

      record:    (D, 3+V) last (max-t) record per drone id; rows of drones
                 never seen are zeros. Channels a partial payload never
                 filled are NaN (the validity mask is ``isfinite``).
      last_seen: (D,) insert step that wrote each row (-1 = never seen).
      valid:     (D,) ``last_seen >= 0``.

    Staleness bound: the cache never forgets — each row is the max-t record
    ever *inserted* for that drone, even after ring retention has aged the
    tuple itself out of the log, and is exact the moment the insert that
    carried it completes (no scan, no index lookup, no planner).
    """
    record: jnp.ndarray
    last_seen: jnp.ndarray
    valid: jnp.ndarray


# The monotonic counter saturates here instead of wrapping int32 negative
# (which would silently blank every scan). The ring write position uses
# tup_pos, which never overflows, so ingest continues correctly past this
# point — only the total-written telemetry stops being exact.
_COUNT_SAT = (1 << 31) - (1 << 26)


AGG_OPS = ("count", "sum", "min", "max", "mean")


@dataclasses.dataclass(frozen=True, init=False)
class AggSpec:
    """Static aggregation spec: which sensor channel(s) to aggregate and
    which aggregates the caller asked for (paper §4.5's range-*aggregation*
    workloads over arbitrary channels).

    The spec is static (hashable — a jit static argument / shard_map cache
    key): ``channels`` selects the value rows ``3 + channel`` of the
    column-major log all the way down into both scan engines, which evaluate
    the predicate mask ONCE and accumulate every requested channel's fused
    (count, sum, min, max) set in the same single pass over the log — a
    K-channel spec costs one scan, not K (the marginal accumulators are nil
    next to the predicate evaluation). ``mean`` is derived after the final
    (Q, E) combine (``finalize_query``), which keeps sum/count the only
    cross-device reductions. ``ops`` records the caller's projection; apply
    it with ``QueryResult.view``. Only ``channels`` is a compile-time cache
    key — specs differing in ``ops`` alone share one compiled scan.

    Construct with either ``channel=`` (one channel, the common case) or
    ``channels=`` (a static tuple batched into one scan); a single-channel
    spec produces (Q,)-shaped aggregates, a multi-channel spec (Q, K).
    """
    channels: Tuple[int, ...] = (0,)
    ops: Tuple[str, ...] = AGG_OPS

    def __init__(self, channel: Optional[int] = None,
                 ops: Tuple[str, ...] = AGG_OPS,
                 channels: Optional[Tuple[int, ...]] = None):
        if channel is not None and channels is not None:
            raise ValueError(
                "pass channel= (single) OR channels= (batched), not both.")
        if channels is None:
            channels = (0 if channel is None else channel,)
        if isinstance(channels, int):
            channels = (channels,)
        channels = tuple(int(c) for c in channels)
        ops = (ops,) if isinstance(ops, str) else tuple(ops)
        object.__setattr__(self, "channels", channels)
        object.__setattr__(self, "ops", ops)
        unknown = [op for op in self.ops if op not in AGG_OPS]
        if unknown:
            raise ValueError(
                f"unknown aggregate op(s) {unknown}: pick from {AGG_OPS}.")
        if not self.ops:
            raise ValueError("AggSpec.ops is empty: request at least one of "
                             f"{AGG_OPS}.")
        if not self.channels:
            raise ValueError("AggSpec.channels is empty: select at least one "
                             "sensor channel.")
        if len(set(self.channels)) != len(self.channels):
            raise ValueError(
                f"channels={self.channels} contains duplicates: each channel "
                "is aggregated once per scan; deduplicate the request.")
        for c in self.channels:
            if c < 0:
                raise ValueError(f"channel={c} must be >= 0.")

    @property
    def channel(self) -> int:
        """First (for single-channel specs: the only) selected channel."""
        return self.channels[0]

    @property
    def n_channels(self) -> int:
        return len(self.channels)

    def validate_for(self, cfg: "StoreConfig") -> "AggSpec":
        for c in self.channels:
            if c >= cfg.n_values:
                raise ValueError(
                    f"channel={c} out of range: this deployment stores "
                    f"n_values={cfg.n_values} sensor channels per tuple "
                    f"(valid channels 0..{cfg.n_values - 1}).")
        return self


class QueryResult(NamedTuple):
    """Fixed-shape query answer: aggregates over matching tuples of the
    ``AggSpec``-selected sensor channel(s).

    Value aggregates are (Q,) float32 for a single-channel spec and (Q, K)
    for a K-channel spec (one column per requested channel, in spec order);
    ``count`` is channel-independent and always (Q,). All value aggregates
    (min/max/mean) are NaN for queries that matched nothing."""
    count: jnp.ndarray    # (Q,) int32
    vsum: jnp.ndarray     # (Q[, K]) float32 — sum of the selected channel(s)
    vmin: jnp.ndarray     # (Q[, K]) float32 (NaN when count==0)
    vmax: jnp.ndarray     # (Q[, K]) float32 (NaN when count==0)
    overflow: jnp.ndarray # (Q,) bool — matched shards exceeded the static budget
    vmean: jnp.ndarray = None  # (Q[, K]) float32 — vsum/count (NaN when count==0)
    completeness_bound: jnp.ndarray = None  # (Q,) float32 — see QueryInfo
    replicas_lost: jnp.ndarray = None       # (Q,) int32 — see QueryInfo

    def view(self, agg: AggSpec) -> dict:
        """Project the aggregates the spec asked for plus the degradation
        telemetry every caller should see: op name -> array — ``count`` is
        (Q,); value ops are (Q,) for a single-channel spec and (Q, K) for a
        K-channel spec (one column per channel, spec order).

        The view always carries ``completeness_bound`` (planner-assigned
        fraction of the index-visible shard set; 1.0 when fully served, NaN
        when unknown — overflow or broadcast) and ``replicas_lost`` (dead
        replica slots over the matched shards) so applications observe
        degraded answers without digging through ``QueryInfo``. See the
        ``QueryInfo`` docstring for the bound's exact (shard-weighted,
        index-visible) semantics and caveat."""
        full = {"count": self.count, "sum": self.vsum, "min": self.vmin,
                "max": self.vmax, "mean": self.vmean}
        out = {op: full[op] for op in agg.ops}
        out["completeness_bound"] = self.completeness_bound
        out["replicas_lost"] = self.replicas_lost
        return out


class QueryInfo(NamedTuple):
    """Telemetry used by the paper-figure benchmarks (Fig 9–14).

    Degraded-query accounting (paper §4.5.3 resilience): ``replicas_lost``
    counts dead replica slots over the matched shard set, and
    ``completeness_bound`` is ``assigned_shards / matched_shards`` — the
    planner-assigned fraction of the *index-visible* shard set (1.0 when
    every matched shard has a live replica; shards whose entire replica set
    is dead are unassignable and pull it below 1). It is NOT a tuple-level
    floor in general: the fraction is shard-weighted, and a shard whose
    every index entry died with its edges never appears in ``matched`` at
    all — so without failure-domain spreading it can sit ABOVE the true
    tuple completeness (fig14's spread=0 row demonstrates exactly that).
    Under failure-domain spreading with <= replication-1 edge failures (or
    one whole device), entry over-replication keeps every shard visible and
    assignable, and the value is exactly 1.0 — which is what the fig14 CI
    gate asserts. When ``overflow`` clipped the match, or on the index-free
    broadcast baseline (``shards_matched == -1``), it is NaN (unknown)
    rather than a fabricated 1.0."""
    lookup_edges: jnp.ndarray      # (Q,) #edges consulted for the index lookup
    subquery_edges: jnp.ndarray    # (Q,) #edges executing sub-queries
    shards_matched: jnp.ndarray    # (Q,) #distinct shards
    max_shards_per_edge: jnp.ndarray  # (Q,) worst per-edge OR-list length
    broadcast: jnp.ndarray         # (Q,) bool — index lookup degenerated
    replicas_lost: jnp.ndarray     # (Q,) dead replica slots over matched shards
    completeness_bound: jnp.ndarray  # (Q,) float32 assigned/matched (NaN unknown)


def _concrete(x, q):
    if isinstance(x, jax.core.Tracer):
        return None
    try:
        return np.broadcast_to(np.asarray(x), (q,))
    except Exception:
        return None


def _check_ranges(q, pairs, enabled, is_and):
    """Reject inverted ranges on concrete (non-traced) inputs: under an AND
    predicate an inverted bound makes the whole query match nothing, which
    historically returned silently-empty results. OR predicates are exempt —
    there an inverted clause merely contributes nothing while the other
    clauses still match. Tracers skip the check."""
    en, am = _concrete(enabled, q), _concrete(is_and, q)
    if en is None or am is None:
        return
    en = en & am
    if not en.any():
        return
    for name, lo, hi in pairs:
        lo, hi = _concrete(lo, q), _concrete(hi, q)
        if lo is None or hi is None:
            continue
        bad = en & (np.asarray(lo) > np.asarray(hi))
        if bad.any():
            i = int(np.argmax(bad))
            raise ValueError(
                f"inverted {name} range for query {i}: "
                f"{name}0={float(lo[i])} > {name}1={float(hi[i])}. Inverted "
                "ranges match nothing under an AND predicate; swap the "
                "bounds (ranges are inclusive [lo, hi]).")


def make_pred(q: int = 1, lat0=0.0, lat1=0.0, lon0=0.0, lon1=0.0, t0=0.0,
              t1=0.0, sid_hi=-1, sid_lo=-1, has_spatial=False,
              has_temporal=False, has_sid=False, is_and=True) -> QueryPred:
    """Build a batched QueryPred, broadcasting scalars to (q,).

    Inverted ranges (``lat1 < lat0``, ``lon1 < lon0``, ``t1 < t0``) on
    concrete inputs under an AND predicate raise — they would silently match
    nothing. The ``repro.api.Query`` builder performs the same validation
    eagerly (for every clause, since the builder composes clause-wise).
    """
    _check_ranges(q, [("lat", lat0, lat1), ("lon", lon0, lon1)],
                  has_spatial, is_and)
    _check_ranges(q, [("t", t0, t1)], has_temporal, is_and)

    def arr(x, dt):
        a = jnp.asarray(x, dt)
        return jnp.broadcast_to(a, (q,) if a.ndim == 0 else a.shape)
    return QueryPred(
        lat0=arr(lat0, jnp.float32), lat1=arr(lat1, jnp.float32),
        lon0=arr(lon0, jnp.float32), lon1=arr(lon1, jnp.float32),
        t0=arr(t0, jnp.float32), t1=arr(t1, jnp.float32),
        sid_hi=arr(sid_hi, jnp.int32), sid_lo=arr(sid_lo, jnp.int32),
        has_spatial=arr(has_spatial, jnp.bool_),
        has_temporal=arr(has_temporal, jnp.bool_),
        has_sid=arr(has_sid, jnp.bool_), is_and=arr(is_and, jnp.bool_))


def init_store(cfg: StoreConfig) -> StoreState:
    e = cfg.n_edges
    return StoreState(
        index=init_index(e, cfg.index_capacity),
        tup_f=jnp.zeros((e, cfg.tuple_width, cfg.padded_capacity), jnp.float32),
        tup_sid=jnp.full((e, 2, cfg.padded_capacity), -1, jnp.int32),
        tup_count=jnp.zeros((e,), jnp.int32),
        tup_pos=jnp.zeros((e,), jnp.int32),
        tup_overwritten=jnp.zeros((e,), jnp.int32),
        tup_dropped=jnp.zeros((e,), jnp.int32),
        steps=jnp.zeros((), jnp.int32),
        latest_f=jnp.zeros((cfg.max_drones, cfg.tuple_width), jnp.float32),
        latest_seen=jnp.full((cfg.max_drones,), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Insertion (paper §3.4, Fig 2)
# ---------------------------------------------------------------------------

def _index_edge_mask(cfg: StoreConfig, meta: ShardMeta, replicas: jnp.ndarray,
                     sites: jnp.ndarray, alive: jnp.ndarray) -> jnp.ndarray:
    """(B, E) — edges that must hold this shard's index entry: every spatial
    and temporal slice owner, plus the replica edges themselves (§3.4.3).
    Ranges wider than the static slice budget broadcast their entry (the
    entry is tiny; the paper notes wide shards index 'on many more edges')."""
    e = cfg.n_edges
    sm, s_ovf = spatial_slice_edges(meta.lat0, meta.lat1, meta.lon0, meta.lon1,
                                    sites, cfg.slice_cfg)
    tm, t_ovf = temporal_slice_edges(meta.t0, meta.t1, e, cfg.slice_cfg)
    rep_mask = jnp.any(replicas[..., None] == jnp.arange(e, dtype=jnp.int32), axis=1)
    mask = sm | tm | rep_mask
    mask = jnp.where((s_ovf | t_ovf)[:, None], jnp.ones_like(mask), mask)
    return mask & alive[None, :]


def _update_latest(latest_f: jnp.ndarray, latest_seen: jnp.ndarray,
                   payload: jnp.ndarray, sid_hi: jnp.ndarray,
                   steps: jnp.ndarray):
    """Latest-per-drone hot-cache update (the §4.4 near-real-time fast path).

    Deterministic under duplicate drone ids: ``.at[].set`` with duplicate
    scatter indices has unspecified winner order in XLA, so the per-drone
    argmax is built from two COMMUTATIVE ``.at[].max`` scatters instead —
    (1) max-t per drone, (2) max flat index among the records achieving that
    t (so t ties resolve to the last record in the batch, matching the host
    oracle's "latest arrival wins" rule). Records with non-finite t are
    excluded; drone ids outside [0, D) fall off via mode="drop".

    Inputs are replicated under shard_map (payload/meta/steps plus the
    previous replicated cache), so every device computes the identical new
    cache and the P() out-spec is sound without a collective.
    """
    d = latest_f.shape[0]
    b, r, w = payload.shape
    flat = payload.reshape(b * r, w)                              # (N, W)
    did = jnp.broadcast_to(sid_hi[:, None], (b, r)).reshape(-1)   # (N,)
    t = flat[:, 0]
    # Negative ids would WRAP under .at[] scatter semantics (mode="drop" only
    # guards the high side) — neutralise them alongside non-finite t.
    vmask = jnp.isfinite(t) & (did >= 0)
    t_clean = jnp.where(vmask, t, -jnp.inf)
    cand_t = jnp.full((d,), -jnp.inf, jnp.float32).at[did].max(
        t_clean, mode="drop")                                     # (D,)
    hit = vmask & (t_clean == jnp.take(cand_t, did, mode="fill",
                                       fill_value=jnp.inf))
    idx = jnp.where(hit, jnp.arange(b * r, dtype=jnp.int32), -1)
    best = jnp.full((d,), -1, jnp.int32).at[did].max(idx, mode="drop")
    cur_t = jnp.where(latest_seen >= 0, latest_f[:, 0], -jnp.inf)
    newer = (best >= 0) & (cand_t >= cur_t)
    latest_f = jnp.where(newer[:, None],
                         jnp.take(flat, jnp.maximum(best, 0), axis=0),
                         latest_f)
    latest_seen = jnp.where(newer, steps, latest_seen)
    return latest_f, latest_seen


def insert_local(cfg: StoreConfig, state: StoreState, payload: jnp.ndarray,
                 meta: ShardMeta, alive: jnp.ndarray, edge_ids: jnp.ndarray,
                 collectives: EdgeCollectives = LOCAL_COLLECTIVES):
    """Shard-local insert body — placement, replication, indexing.

    ``state`` arrays carry a slice of the logical edge axis whose global ids
    are ``edge_ids`` (the full ``arange(E)`` on one device); ``payload``,
    ``meta``, ``alive`` are global and replicated. Placement and slice masks
    are metadata-scale, recomputed replicated on every shard; the tuple
    scatter and index writes touch only the local edges.

    ``collectives.gather_watermark`` maps this shard's (E_local,) retention
    watermark to the global (E,) watermark that ``retire_entries`` needs
    (entries name replica edges anywhere in the deployment): identity on one
    device, an all-gather over the mesh's edge-bearing axes under shard_map.

    Returns (new_state, info dict) with per-edge info sliced like ``state``.
    """
    cap = cfg.tuple_capacity
    e_loc = edge_ids.shape[0]
    b, r, w = payload.shape
    sites = cfg.sites_array()

    replicas = place_replicas(meta, sites, alive, cfg.tau,
                              n_domains=cfg.n_failure_domains)  # (B, 3)
    replicas = replicas[:, : cfg.replication]
    alive_loc = jnp.take(alive, edge_ids)

    # --- tuple dispatch: one-hot shard->edge routing (MoE-style) ---
    dm = jnp.any(replicas[..., None] == edge_ids, axis=1)        # (B, E_loc)
    dm = dm & alive_loc[None, :]
    rank = jnp.cumsum(dm, axis=0) - 1                            # (B, E_loc)
    start = state.tup_pos[None, :] + rank * r                    # (B, E_loc)
    pos = start[..., None] + jnp.arange(r, dtype=jnp.int32)      # (B, E_loc, R)
    ok = dm[..., None]
    # Ring slot modulo the LOGICAL capacity (lane-padding slots stay dead);
    # the drop sentinel must be out of range of the PADDED tuple axis.
    pp = jnp.where(ok, pos % cap, cfg.padded_capacity)
    ee = jnp.broadcast_to(
        jnp.arange(e_loc, dtype=jnp.int32)[None, :, None], (b, e_loc, r))

    pay = jnp.broadcast_to(payload[:, None], (b, e_loc, r, w))
    sid = jnp.broadcast_to(
        jnp.stack([meta.sid_hi, meta.sid_lo], axis=-1)[:, None, None, :],
        (b, e_loc, r, 2))

    # Column-major write pattern: one scatter per tuple writes its whole
    # field COLUMN tup_f[e, :, slot] (the slice between the advanced indices
    # spans the field rows), so the lane-aligned log never needs a
    # query-time relayout.
    tup_f = state.tup_f.at[ee, :, pp].set(pay, mode="drop")
    tup_sid = state.tup_sid.at[ee, :, pp].set(sid, mode="drop")
    n_in = jnp.sum(dm, axis=0) * r                               # (E_loc,)
    tup_pos = ((state.tup_pos + n_in) % cap).astype(jnp.int32)
    tup_count = jnp.minimum(state.tup_count + n_in,
                            _COUNT_SAT).astype(jnp.int32)        # monotonic
    # Retention telemetry: slots reclaimed from the previous window.
    valid_before = jnp.minimum(state.tup_count, cap)
    valid_after = jnp.minimum(tup_count, cap)
    overwritten_now = (valid_before + n_in - valid_after).astype(jnp.int32)
    tup_overwritten = jnp.minimum(state.tup_overwritten + overwritten_now,
                                  _COUNT_SAT).astype(jnp.int32)

    # --- index retention (cadenced): retire entries whose data has aged out
    # of every replica edge's ring, then compact so the cursor is reusable.
    # Runs BEFORE this batch's index writes so freed slots host the fresh
    # entries. Watermarks (oldest retained timestamp; -inf until the edge
    # has ever aged out a tuple — wrap OR repair-time ring reclamation, i.e.
    # tup_overwritten > 0, so retention resumes after a reclaimed ring is
    # rewound below cap) are only computed on sweep steps — the (E, CAP)
    # reduction stays off the ingest hot path. The watermark gather sits
    # OUTSIDE the cond so
    # every device executes the same collective schedule regardless of how
    # rep-checking handles conditional branches. ---
    steps = state.steps + 1
    do_sweep = steps % cfg.retention_every == 0

    def _local_wm(_):
        retained = (jnp.arange(cfg.padded_capacity, dtype=jnp.int32)[None, :]
                    < valid_after[:, None])                      # (E_loc, CAP_L)
        t_oldest = jnp.min(jnp.where(retained, tup_f[:, 0, :], jnp.inf),
                           axis=1)                               # t row
        # Epoch-aware: after repair's ring reclamation rewinds tup_count
        # below cap, tup_overwritten > 0 still marks the edge as having
        # lost tuples — without it the watermark would read -inf and
        # retention would silently pause until the ring re-wrapped.
        lossy = (tup_count > cap) | (tup_overwritten > 0)
        return jnp.where(lossy, t_oldest,
                         -jnp.inf).astype(jnp.float32)           # (E_loc,)

    wm_local = jax.lax.cond(
        do_sweep, _local_wm,
        lambda _: jnp.full((e_loc,), -jnp.inf, jnp.float32), None)
    watermark = collectives.gather_watermark(wm_local)           # (E,) global
    index = jax.lax.cond(
        do_sweep, lambda ix: compact_index(retire_entries(ix, watermark)),
        lambda ix: ix, state.index)

    # --- sliced index entries (§3.4.3) ---
    idx_mask = _index_edge_mask(cfg, meta, replicas, sites, alive)  # (B, E)
    idx_mask = jnp.take(idx_mask, edge_ids, axis=1)                 # (B, E_loc)
    index = insert_entries(index, meta,
                           jnp.pad(replicas, ((0, 0), (0, 3 - cfg.replication)),
                                   constant_values=-1),
                           idx_mask, step=steps)

    # --- latest-per-drone hot cache: replicated O(D) state, updated on the
    # ingest path from the same replicated payload (statically compiled out
    # when the cache is disabled so existing graphs are untouched). ---
    latest_f, latest_seen = state.latest_f, state.latest_seen
    if cfg.max_drones:
        latest_f, latest_seen = _update_latest(
            latest_f, latest_seen, payload, meta.sid_hi, steps)

    new_state = StoreState(index, tup_f, tup_sid, tup_count, tup_pos,
                           tup_overwritten, state.tup_dropped, steps,
                           latest_f, latest_seen)
    info = {
        "replicas": replicas,
        "intake_per_edge": n_in,
        "index_writes_per_edge": jnp.sum(idx_mask, axis=0),
        "tuples_overwritten": overwritten_now,
        "tuples_dropped": jnp.zeros_like(n_in),
        # Ingest-time index-capacity drops (per-edge delta this step): the
        # session ledger folds the batch's sids into the incremental-repair
        # pending set whenever this is nonzero, closing the repair() vs
        # repair(full=True) gap for drops outside swept shards.
        "index_entries_dropped": index.dropped - state.index.dropped,
        "index_entries_retired": index.retired - state.index.retired,
        "retention_watermark": watermark,
    }
    return new_state, info


def check_batch_fits(cfg: StoreConfig, payload_shape) -> None:
    """Reject batches that could wrap one edge's ring within a single insert
    (scatter order would be undefined). Static — call before tracing."""
    b, r = payload_shape[0], payload_shape[1]
    if b * r > cfg.tuple_capacity:
        raise ValueError(
            f"batch writes {b}x{r}={b * r} tuples, exceeding tuple_capacity="
            f"{cfg.tuple_capacity}: one edge could wrap its own ring within a "
            "single insert_step (scatter order would be undefined). Split the "
            "batch or raise tuple_capacity.")


@partial(jax.jit, static_argnums=(0,))
def _insert_step_jit(cfg: StoreConfig, state: StoreState, payload: jnp.ndarray,
                     meta: ShardMeta, alive: jnp.ndarray):
    edge_ids = jnp.arange(cfg.n_edges, dtype=jnp.int32)
    return insert_local(cfg, state, payload, meta, alive, edge_ids)


def _insert(cfg: StoreConfig, state: StoreState, payload: jnp.ndarray,
            meta: ShardMeta, alive: jnp.ndarray):
    """1-device insert body shared by the ``AerialDB`` facade and the
    deprecated ``insert_step`` shim: batch-fit check + jitted insert_local."""
    check_batch_fits(cfg, payload.shape)
    return _insert_step_jit(cfg, state, payload, meta, alive)


@lru_cache(maxsize=None)
def _warn_deprecated(old: str, new: str):
    """One DeprecationWarning per (old, new) pair per process — the step
    shims sit on hot loops in older callers."""
    warnings.warn(
        f"{old} is deprecated: drive the store through {new} (the unified "
        "repro.api facade owns state/alive/key plumbing and dispatches to "
        "the single-device or federated runtime from one entry point). The "
        "shim remains supported and bit-identical.",
        DeprecationWarning, stacklevel=3)


def insert_step(cfg: StoreConfig, state: StoreState, payload: jnp.ndarray,
                meta: ShardMeta, alive: jnp.ndarray):
    """Insert B shards (R tuples each) — the 1-device special case of
    ``insert_local`` (see the sharded-state layout contract in the module
    docstring; ``repro.distributed.federation`` runs the same body over a
    device mesh).

    .. deprecated:: kept as a thin shim over the same body the
       ``repro.api.AerialDB`` facade drives; prefer ``AerialDB.insert``.

    The tuple log is a ring buffer: writes land at ``position % capacity``
    (oldest-first overwrite), so inserts never saturate; every
    ``cfg.retention_every``-th call additionally retires + compacts index
    entries that aged out of the retained window.

    Args:
      payload: (B, R, 3+V) tuple records (t, lat, lon, values...).
      meta:    ShardMeta of the B shards.
      alive:   (E,) availability mask.

    Returns (new_state, info dict).
    """
    _warn_deprecated("insert_step", "repro.api.AerialDB.insert")
    return _insert(cfg, state, payload, meta, alive)


# ---------------------------------------------------------------------------
# Query (paper §3.5, Fig 4)
# ---------------------------------------------------------------------------

def _lookup_sets(cfg: StoreConfig, pred: QueryPred, sites: jnp.ndarray,
                 alive: jnp.ndarray):
    """Candidate edge sets E_s, E_t, E_i for the index lookup (§3.5.1) and
    the chosen lookup mask. AND => smallest failure-free set; OR => union.
    Any unusable situation falls back to broadcasting to alive edges."""
    e = cfg.n_edges
    q = pred.lat0.shape[0]

    es, s_ovf = spatial_slice_edges(pred.lat0, pred.lat1, pred.lon0, pred.lon1,
                                    sites, cfg.slice_cfg)
    et, t_ovf = temporal_slice_edges(pred.t0, pred.t1, e, cfg.slice_cfg)
    ei = (hashing.hash_shard_id(pred.sid_hi, pred.sid_lo, e)[..., None]
          == jnp.arange(e, dtype=jnp.int32))

    sets = jnp.stack([es, et, ei], axis=1)                       # (Q, 3, E)
    usable = jnp.stack([pred.has_spatial & ~s_ovf,
                        pred.has_temporal & ~t_ovf,
                        pred.has_sid], axis=1)                   # (Q, 3)
    has_failed = jnp.any(sets & ~alive, axis=-1)                 # (Q, 3)
    sizes = jnp.sum(sets, axis=-1)                               # (Q, 3)

    # §3.5.3: prefer failure-free sets; among them the smallest.
    big = jnp.int32(1 << 30)
    score = jnp.where(usable & ~has_failed, sizes, big)
    best = jnp.argmin(score, axis=-1)                            # (Q,)
    best_ok = jnp.take_along_axis(score, best[:, None], axis=1)[:, 0] < big

    chosen = jnp.take_along_axis(sets, best[:, None, None], axis=1)[:, 0]  # (Q, E)
    union = jnp.any(jnp.where(usable[..., None], sets, False), axis=1)
    union_ok = jnp.any(usable, axis=-1) & ~jnp.any(union & ~alive, axis=-1)

    is_and = pred.is_and
    mask = jnp.where(is_and[:, None], chosen, union)
    ok = jnp.where(is_and, best_ok, union_ok)
    if not cfg.use_index:
        ok = jnp.zeros_like(ok)                                  # Feather-like: no index
    broadcast = ~ok
    mask = jnp.where(broadcast[:, None], jnp.broadcast_to(alive, (q, e)), mask & alive)
    return mask, broadcast


def scan_engine(tup_f, tup_sid, tup_count, pred: QueryPred, sublists,
                sublist_len, use_kernel: bool = False,
                interpret: Optional[bool] = None,
                channels: Tuple[int, ...] = (0,),
                valid_c: Optional[int] = None):
    """Per-edge predicate scan (the InfluxDB role). Evaluates each query's
    predicate + shard OR-list against the edge-local retained window
    (``slot < min(tup_count, valid_c)`` — ring-buffer validity over the
    logical capacity; the stored tuple axis may be lane-padded above it).

    Single pass: the whole query batch and every requested channel are
    answered in ONE sweep over the column-major log — the Pallas kernel
    tiles queries so each resident tuple tile serves a ``block_q``-query
    tile, and both engines fuse all K channels' aggregates behind one
    predicate mask.

    Args:
      tup_f/tup_sid: column-major (E, 3+V, C) / (E, 2, C) — the native
                   StoreState layout, streamed as-is (no relayout).
      sublists:    (Q, E, L, 2) int32 shard ids assigned to each (query, edge).
      sublist_len: (Q, E) int32 — #valid entries in each OR-list.
      use_kernel:  dispatch to the Pallas TPU kernel instead of the jnp ref.
      interpret:   force Pallas interpret mode; None = auto (compiled on TPU,
                   interpreted elsewhere).
      channels:    static tuple of sensor channels to aggregate
                   (``AggSpec.channels``); value rows ``3 + channel``.
      valid_c:     logical ring capacity (``StoreConfig.tuple_capacity``);
                   None = the stored C (unpadded input).

    Returns (count, vsum, vmin, vmax): count (Q, E) int32; vsum/vmin/vmax
    (Q, K, E) float32 per-channel partials.
    """
    if use_kernel:
        from repro.kernels.st_scan import ops as st_ops
        return st_ops.st_scan(tup_f, tup_sid, tup_count, pred, sublists,
                              sublist_len, interpret=interpret,
                              channels=channels, valid_c=valid_c)
    from repro.kernels.st_scan import ref as st_ref
    return st_ref.st_scan_ref(tup_f, tup_sid, tup_count, pred, sublists,
                              sublist_len, channels=channels, valid_c=valid_c)


def _tile_slices(q: int, n_tiles: int):
    """Split the static query-batch dim into ``min(n_tiles, q)`` contiguous
    slices, as evenly as possible (sizes differ by at most 1)."""
    n = max(1, min(n_tiles, q))
    base, rem = divmod(q, n)
    out, start = [], 0
    for i in range(n):
        size = base + (1 if i < rem else 0)
        out.append(slice(start, start + size))
        start += size
    return out


def query_local(cfg: StoreConfig, state: StoreState, pred: QueryPred,
                alive: jnp.ndarray, key: jax.Array, edge_ids: jnp.ndarray,
                collectives: EdgeCollectives = LOCAL_COLLECTIVES,
                use_kernel: bool = False, interpret: Optional[bool] = None,
                agg: AggSpec = AggSpec(), overlap_tiles: int = 1):
    """Shard-local query body: index lookup -> candidate merge -> planning ->
    per-edge sub-query scan, over the slice of the edge axis named by
    ``edge_ids``.

    Lookup-set selection and planning are metadata-scale and computed
    replicated from the global ``pred``/``alive``; the index match and the
    tuple scan touch only local state. ``collectives.combine_matched`` merges
    per-shard candidate lists into the global ``MatchedShards`` every device
    plans against: identity on one device; under shard_map, a (hierarchical)
    all-gather of each device's local top-S candidates re-deduplicated with
    ``index.dedup_matched`` (exactly the single-device result — see there).

    Collective/compute overlap: with ``overlap_tiles > 1`` the query batch is
    split into that many tiles and every tile's index match + candidate merge
    is issued BEFORE any tile's log scan — the merge collectives of tile t+1
    (on the fleet mesh: the cross-host inter-fleet exchange) carry no data
    dependency on tile t's scan, so the latency-hiding scheduler can overlap
    them (double-buffered at the default ``overlap_tiles=2`` the federated
    runtime uses on multi-fleet meshes). Every per-query computation here —
    lookup, dedup, planning (per-query folded PRNG keys), OR-list build, scan
    — is query-independent, so results are bitwise invariant to the tiling;
    the differential harness pins that.

    Returns (partials, sublist_len, (lookup_mask, broadcast, overflow,
    shards_matched, replicas_lost, completeness_bound)): ``partials`` are the
    per-edge aggregates — (Q, E_local)
    count plus (Q, K, E_local) per-channel value aggregates for the
    ``agg.channels`` tuple, all produced by ONE scan of the local log;
    ``sublist_len`` is (Q, E_local); the rest is replicated metadata. Feed
    the pieces (with per-edge arrays concatenated back to full E) to
    ``finalize_query`` for the final combine.
    """
    q = pred.lat0.shape[0]
    s = cfg.max_shards_per_query
    e_loc = edge_ids.shape[0]
    sites = cfg.sites_array()

    lookup_mask, broadcast = _lookup_sets(cfg, pred, sites, alive)   # (Q, E)
    lookup_loc = jnp.take(lookup_mask, edge_ids, axis=1)             # (Q, E_loc)

    if not cfg.use_index:
        # Broadcast baseline (Feather-like): no shard scoping; every alive
        # edge scans everything. StoreConfig rejects use_index=False with
        # replication > 1, which would overcount ~R-fold here. No candidate
        # merge means nothing to overlap — the batch stays untiled.
        alive_loc = jnp.take(alive, edge_ids)
        sublists = jnp.zeros((q, e_loc, 1, 2), jnp.int32)
        sublist_len = jnp.where(jnp.broadcast_to(alive_loc, (q, e_loc)),
                                -1, 0).astype(jnp.int32)
        ovf = jnp.zeros((q,), jnp.bool_)
        shards_matched = jnp.full((q,), -1, jnp.int32)
        # No index: no shard tracking, so completeness is unknowable here.
        replicas_lost = jnp.zeros((q,), jnp.int32)
        bound = jnp.full((q,), jnp.nan, jnp.float32)
        partials = scan_engine(state.tup_f, state.tup_sid, state.tup_count,
                               pred, sublists, sublist_len, use_kernel,
                               interpret, channels=agg.channels,
                               valid_c=cfg.tuple_capacity)
        return partials, sublist_len, (lookup_mask, broadcast, ovf,
                                       shards_matched, replicas_lost, bound)

    # Per-query planner keys (key folded with the GLOBAL query index), so
    # planner randomness is invariant to the tiling below.
    qkeys = jax.vmap(jax.random.fold_in, (None, 0))(key,
                                                    jnp.arange(q))

    # Phase 1 — index match + candidate merge for EVERY tile up front: all
    # cross-device exchanges are issued before any log scan.
    tiles = _tile_slices(q, overlap_tiles)
    pred_tiles = [jax.tree.map(lambda a: a[sl], pred) for sl in tiles]
    matched_tiles = [
        collectives.combine_matched(
            lookup(state.index, p, lookup_loc[sl], s), s)
        for sl, p in zip(tiles, pred_tiles)]

    # Phase 2 — plan + per-edge OR-lists + single-pass scan, per tile (tile
    # t's scan is dependency-free of tile t+1's in-flight merge).
    outs = []
    for sl, p, matched in zip(tiles, pred_tiles, matched_tiles):
        qt = p.lat0.shape[0]
        assignment = planner_lib.plan(cfg.planner, matched, alive,
                                      qkeys[sl])                  # (Qt, S)
        # Per-edge OR-lists: rank of shard within its assigned edge.
        am = (assignment[..., None] == edge_ids)                  # (Qt, S, E_loc)
        rank = jnp.cumsum(am, axis=1) - 1
        pos = jnp.where(am, rank, s)
        sublists = jnp.full((qt, e_loc, s, 2), -1, jnp.int32)
        qq = jnp.broadcast_to(jnp.arange(qt, dtype=jnp.int32)[:, None, None],
                              (qt, s, e_loc))
        ee = jnp.broadcast_to(jnp.arange(e_loc, dtype=jnp.int32)[None, None, :],
                              (qt, s, e_loc))
        sidv = jnp.stack([matched.sid_hi, matched.sid_lo], axis=-1)  # (Qt, S, 2)
        sidv = jnp.broadcast_to(sidv[:, :, None, :], (qt, s, e_loc, 2))
        sublists = sublists.at[qq, ee, pos].set(sidv, mode="drop")
        sublist_len = jnp.sum(am, axis=1).astype(jnp.int32)       # (Qt, E_loc)
        ovf = matched.overflow
        shards_matched = jnp.sum(matched.valid, axis=-1)
        # Degraded-query accounting (replicated metadata, like planning):
        # dead replica slots over the matched set, and the planner-derived
        # completeness bound — matched shards whose replicas all died are
        # unassignable (assignment == -1) and provably missing from the
        # result. Overflow clips the tracked set, so the bound is unknown.
        reps = matched.replicas
        dead_slot = (matched.valid[..., None] & (reps >= 0)
                     & ~jnp.take(alive, jnp.clip(reps, 0), axis=0))
        replicas_lost = jnp.sum(dead_slot, axis=(1, 2)).astype(jnp.int32)
        assigned_n = jnp.sum(matched.valid & (assignment >= 0), axis=-1)
        bound = jnp.where(shards_matched > 0,
                          assigned_n / jnp.maximum(shards_matched, 1), 1.0)
        bound = jnp.where(ovf, jnp.nan, bound).astype(jnp.float32)
        partials = scan_engine(state.tup_f, state.tup_sid, state.tup_count,
                               p, sublists, sublist_len, use_kernel,
                               interpret, channels=agg.channels,
                               valid_c=cfg.tuple_capacity)
        outs.append((partials, sublist_len, ovf, shards_matched,
                     replicas_lost, bound))

    if len(outs) == 1:
        partials, sublist_len, ovf, shards_matched, replicas_lost, bound = \
            outs[0]
    else:
        cat = lambda xs: jnp.concatenate(xs, axis=0)
        partials = tuple(cat([o[0][i] for o in outs]) for i in range(4))
        sublist_len, ovf, shards_matched, replicas_lost, bound = (
            cat([o[j] for o in outs]) for j in range(1, 6))
    return partials, sublist_len, (lookup_mask, broadcast, ovf, shards_matched,
                                   replicas_lost, bound)


def finalize_query(partials, sublist_len, lookup_mask, broadcast, overflow,
                   shards_matched, replicas_lost, completeness_bound):
    """Final (Q, K, E) -> (Q[, K]) combine shared by the 1-device and sharded
    paths (under the federated runtime, this is the only
    tuple-volume-independent reduction crossing devices). ``partials`` are
    full-E per-edge aggregates: channel-independent (Q, E) count plus
    per-channel (Q, K, E) value aggregates; single-channel specs (K == 1)
    squeeze to the classic (Q,) result shapes. ``mean`` is derived here from
    the combined sum/count, so it adds no cross-device reduction of its own.

    Zero-match queries: the scan's +inf/-inf min/max accumulator sentinels
    (and the meaningless mean) are masked to NaN — they must never leak into
    ``QueryResult`` as if they were data.
    """
    count, vsum, vmin, vmax = partials
    total = jnp.sum(count, axis=-1).astype(jnp.int32)            # (Q,)
    vsum_total = jnp.sum(vsum, axis=-1)                          # (Q, K)
    vmin_total = jnp.min(vmin, axis=-1)
    vmax_total = jnp.max(vmax, axis=-1)
    some = (total > 0)[:, None]                                  # (Q, 1)
    vmin_total = jnp.where(some, vmin_total, jnp.nan)
    vmax_total = jnp.where(some, vmax_total, jnp.nan)
    vmean = jnp.where(some, vsum_total / jnp.maximum(total, 1)[:, None],
                      jnp.nan)
    if vsum_total.shape[-1] == 1:    # single-channel spec: classic (Q,) shape
        vsum_total, vmin_total, vmax_total, vmean = (
            a[:, 0] for a in (vsum_total, vmin_total, vmax_total, vmean))
    result = QueryResult(
        count=total,
        vsum=vsum_total,
        vmin=vmin_total,
        vmax=vmax_total,
        overflow=overflow,
        vmean=vmean,
        completeness_bound=completeness_bound,
        replicas_lost=replicas_lost,
    )
    info = QueryInfo(
        lookup_edges=jnp.sum(lookup_mask, axis=-1),
        subquery_edges=jnp.sum(sublist_len != 0, axis=-1),
        shards_matched=shards_matched,
        max_shards_per_edge=jnp.max(jnp.abs(sublist_len), axis=-1),
        broadcast=broadcast,
        replicas_lost=replicas_lost,
        completeness_bound=completeness_bound,
    )
    return result, info


@partial(jax.jit, static_argnums=(0, 5, 6, 7))
def _query_step_jit(cfg: StoreConfig, state: StoreState, pred: QueryPred,
                    alive: jnp.ndarray, key: jax.Array,
                    use_kernel: bool = False,
                    interpret: Optional[bool] = None,
                    channels: Tuple[int, ...] = (0,)):
    edge_ids = jnp.arange(cfg.n_edges, dtype=jnp.int32)
    partials, sublist_len, meta_info = \
        query_local(cfg, state, pred, alive, key, edge_ids,
                    use_kernel=use_kernel, interpret=interpret,
                    agg=AggSpec(channels=channels))
    return finalize_query(partials, sublist_len, *meta_info)


def _query(cfg: StoreConfig, state: StoreState, pred: QueryPred,
           alive: jnp.ndarray, key: jax.Array, use_kernel: bool = False,
           interpret: Optional[bool] = None, agg: AggSpec = AggSpec()):
    """1-device query body shared by the ``AerialDB`` facade and the
    deprecated ``query_step`` shim. Only ``agg.channels`` reaches the jit
    cache key — varying the requested ops never recompiles."""
    agg.validate_for(cfg)
    return _query_step_jit(cfg, state, pred, alive, key, use_kernel,
                           interpret, agg.channels)


def query_step(cfg: StoreConfig, state: StoreState, pred: QueryPred,
               alive: jnp.ndarray, key: jax.Array, use_kernel: bool = False,
               interpret: Optional[bool] = None, agg: AggSpec = AggSpec()):
    """Decentralized query execution (paper Fig 4): index lookup -> planning
    -> per-edge sub-queries -> combine. The 1-device special case of
    ``query_local``. Returns (QueryResult, QueryInfo).

    .. deprecated:: kept as a thin shim over the same body the
       ``repro.api.AerialDB`` facade drives; prefer ``AerialDB.query``.
    """
    _warn_deprecated("query_step", "repro.api.AerialDB.query")
    return _query(cfg, state, pred, alive, key, use_kernel, interpret, agg)
