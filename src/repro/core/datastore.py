"""AerialDB datastore: federated insert and decentralized query (paper §3).

State layout — every array carries the *logical edge axis* E in front, which
the launcher shards over the device mesh (edges ≈ experts in an MoE: the
insertion path literally reuses the dispatch-by-one-hot pattern). All
operations are pure jittable functions: ``insert_step(state, shards) ->
(state, info)`` and ``query_step(state, queries) -> (results, info)``.

  tup_f:   (E, CAP_T, 3+V) float32   t, lat, lon, v0..  — the per-edge tuple log
  tup_sid: (E, CAP_T, 2)   int32     owning shard id (hi, lo)
  tup_count, tup_dropped: (E,)       append cursor / overflow telemetry
  index:   IndexState                sliced distributed index (index.py)

The per-edge query engine (the paper's InfluxDB role) is a predicate scan —
``repro.kernels.st_scan`` provides the Pallas TPU kernel; ``scan_engine`` here
dispatches to it or to the jnp reference.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, planner as planner_lib
from repro.core.index import IndexState, MatchedShards, QueryPred, init_index, insert_entries, lookup
from repro.core.placement import ShardMeta, place_replicas
from repro.core.slicing import SliceConfig, spatial_slice_edges, temporal_slice_edges


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    """Static configuration of an AerialDB deployment."""
    n_edges: int = 20
    sites: Tuple[Tuple[float, float], ...] = ()   # (E, 2) edge locations
    tau: float = 300.0
    slice_cfg: SliceConfig = SliceConfig()
    tuple_capacity: int = 1 << 14                 # tuples per edge
    index_capacity: int = 1 << 12                 # index entries per edge
    max_shards_per_query: int = 128               # S
    records_per_shard: int = 60                   # R (paper: 60 samples / 5 min)
    n_values: int = 4                             # sensor channels per tuple
    replication: int = 3                          # 1 => Feather-like baseline
    use_index: bool = True                        # False => broadcast baseline
    planner: str = "min_shards"
    or_group: int = 150                           # paper: sub-queries split at 150 sids

    @property
    def tuple_width(self) -> int:
        return 3 + self.n_values

    def sites_array(self) -> jnp.ndarray:
        return jnp.asarray(np.asarray(self.sites, np.float32).reshape(self.n_edges, 2))


class StoreState(NamedTuple):
    index: IndexState
    tup_f: jnp.ndarray
    tup_sid: jnp.ndarray
    tup_count: jnp.ndarray
    tup_dropped: jnp.ndarray


class QueryResult(NamedTuple):
    """Fixed-shape query answer: aggregates over matching tuples."""
    count: jnp.ndarray    # (Q,) int32
    vsum: jnp.ndarray     # (Q,) float32 — sum of v0
    vmin: jnp.ndarray     # (Q,) float32 (+inf when count==0)
    vmax: jnp.ndarray     # (Q,) float32 (-inf when count==0)
    overflow: jnp.ndarray # (Q,) bool — matched shards exceeded the static budget


class QueryInfo(NamedTuple):
    """Telemetry used by the paper-figure benchmarks (Fig 9–13)."""
    lookup_edges: jnp.ndarray      # (Q,) #edges consulted for the index lookup
    subquery_edges: jnp.ndarray    # (Q,) #edges executing sub-queries
    shards_matched: jnp.ndarray    # (Q,) #distinct shards
    max_shards_per_edge: jnp.ndarray  # (Q,) worst per-edge OR-list length
    broadcast: jnp.ndarray         # (Q,) bool — index lookup degenerated


def make_pred(q: int = 1, lat0=0.0, lat1=0.0, lon0=0.0, lon1=0.0, t0=0.0,
              t1=0.0, sid_hi=-1, sid_lo=-1, has_spatial=False,
              has_temporal=False, has_sid=False, is_and=True) -> QueryPred:
    """Build a batched QueryPred, broadcasting scalars to (q,)."""
    def arr(x, dt):
        a = jnp.asarray(x, dt)
        return jnp.broadcast_to(a, (q,) if a.ndim == 0 else a.shape)
    return QueryPred(
        lat0=arr(lat0, jnp.float32), lat1=arr(lat1, jnp.float32),
        lon0=arr(lon0, jnp.float32), lon1=arr(lon1, jnp.float32),
        t0=arr(t0, jnp.float32), t1=arr(t1, jnp.float32),
        sid_hi=arr(sid_hi, jnp.int32), sid_lo=arr(sid_lo, jnp.int32),
        has_spatial=arr(has_spatial, jnp.bool_),
        has_temporal=arr(has_temporal, jnp.bool_),
        has_sid=arr(has_sid, jnp.bool_), is_and=arr(is_and, jnp.bool_))


def init_store(cfg: StoreConfig) -> StoreState:
    e = cfg.n_edges
    return StoreState(
        index=init_index(e, cfg.index_capacity),
        tup_f=jnp.zeros((e, cfg.tuple_capacity, cfg.tuple_width), jnp.float32),
        tup_sid=jnp.full((e, cfg.tuple_capacity, 2), -1, jnp.int32),
        tup_count=jnp.zeros((e,), jnp.int32),
        tup_dropped=jnp.zeros((e,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Insertion (paper §3.4, Fig 2)
# ---------------------------------------------------------------------------

def _index_edge_mask(cfg: StoreConfig, meta: ShardMeta, replicas: jnp.ndarray,
                     sites: jnp.ndarray, alive: jnp.ndarray) -> jnp.ndarray:
    """(B, E) — edges that must hold this shard's index entry: every spatial
    and temporal slice owner, plus the replica edges themselves (§3.4.3).
    Ranges wider than the static slice budget broadcast their entry (the
    entry is tiny; the paper notes wide shards index 'on many more edges')."""
    e = cfg.n_edges
    sm, s_ovf = spatial_slice_edges(meta.lat0, meta.lat1, meta.lon0, meta.lon1,
                                    sites, cfg.slice_cfg)
    tm, t_ovf = temporal_slice_edges(meta.t0, meta.t1, e, cfg.slice_cfg)
    rep_mask = jnp.any(replicas[..., None] == jnp.arange(e, dtype=jnp.int32), axis=1)
    mask = sm | tm | rep_mask
    mask = jnp.where((s_ovf | t_ovf)[:, None], jnp.ones_like(mask), mask)
    return mask & alive[None, :]


@partial(jax.jit, static_argnums=(0,))
def insert_step(cfg: StoreConfig, state: StoreState, payload: jnp.ndarray,
                meta: ShardMeta, alive: jnp.ndarray):
    """Insert B shards (R tuples each) — placement, replication, indexing.

    Args:
      payload: (B, R, 3+V) tuple records (t, lat, lon, values...).
      meta:    ShardMeta of the B shards.
      alive:   (E,) availability mask.

    Returns (new_state, info dict).
    """
    e, cap = cfg.n_edges, cfg.tuple_capacity
    b, r, w = payload.shape
    sites = cfg.sites_array()

    replicas = place_replicas(meta, sites, alive, cfg.tau)      # (B, 3)
    replicas = replicas[:, : cfg.replication]

    # --- tuple dispatch: one-hot shard->edge routing (MoE-style) ---
    dm = jnp.any(replicas[..., None] == jnp.arange(e, dtype=jnp.int32), axis=1)  # (B, E)
    dm = dm & alive[None, :]
    rank = jnp.cumsum(dm, axis=0) - 1                            # (B, E)
    start = state.tup_count[None, :] + rank * r                  # (B, E)
    pos = start[..., None] + jnp.arange(r, dtype=jnp.int32)      # (B, E, R)
    ok = dm[..., None] & (pos < cap)
    pp = jnp.where(ok, pos, cap)                                 # drop OOB
    ee = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32)[None, :, None], (b, e, r))

    pay = jnp.broadcast_to(payload[:, None], (b, e, r, w))
    sid = jnp.broadcast_to(
        jnp.stack([meta.sid_hi, meta.sid_lo], axis=-1)[:, None, None, :], (b, e, r, 2))

    tup_f = state.tup_f.at[ee, pp].set(pay, mode="drop")
    tup_sid = state.tup_sid.at[ee, pp].set(sid, mode="drop")
    n_in = jnp.sum(dm, axis=0) * r                               # (E,)
    tup_count = jnp.minimum(state.tup_count + n_in, cap).astype(jnp.int32)
    n_dropped = state.tup_dropped + jnp.sum(jnp.sum(dm[..., None] & (pos >= cap),
                                                    axis=-1), axis=0)

    # --- sliced index entries (§3.4.3) ---
    idx_mask = _index_edge_mask(cfg, meta, replicas, sites, alive)
    index = insert_entries(state.index, meta,
                           jnp.pad(replicas, ((0, 0), (0, 3 - cfg.replication)),
                                   constant_values=-1),
                           idx_mask)

    new_state = StoreState(index, tup_f, tup_sid, tup_count, n_dropped)
    info = {
        "replicas": replicas,
        "intake_per_edge": n_in,
        "index_writes_per_edge": jnp.sum(idx_mask, axis=0),
        "tuples_dropped": n_dropped - state.tup_dropped,
    }
    return new_state, info


# ---------------------------------------------------------------------------
# Query (paper §3.5, Fig 4)
# ---------------------------------------------------------------------------

def _lookup_sets(cfg: StoreConfig, pred: QueryPred, sites: jnp.ndarray,
                 alive: jnp.ndarray):
    """Candidate edge sets E_s, E_t, E_i for the index lookup (§3.5.1) and
    the chosen lookup mask. AND => smallest failure-free set; OR => union.
    Any unusable situation falls back to broadcasting to alive edges."""
    e = cfg.n_edges
    q = pred.lat0.shape[0]

    es, s_ovf = spatial_slice_edges(pred.lat0, pred.lat1, pred.lon0, pred.lon1,
                                    sites, cfg.slice_cfg)
    et, t_ovf = temporal_slice_edges(pred.t0, pred.t1, e, cfg.slice_cfg)
    ei = (hashing.hash_shard_id(pred.sid_hi, pred.sid_lo, e)[..., None]
          == jnp.arange(e, dtype=jnp.int32))

    sets = jnp.stack([es, et, ei], axis=1)                       # (Q, 3, E)
    usable = jnp.stack([pred.has_spatial & ~s_ovf,
                        pred.has_temporal & ~t_ovf,
                        pred.has_sid], axis=1)                   # (Q, 3)
    has_failed = jnp.any(sets & ~alive, axis=-1)                 # (Q, 3)
    sizes = jnp.sum(sets, axis=-1)                               # (Q, 3)

    # §3.5.3: prefer failure-free sets; among them the smallest.
    big = jnp.int32(1 << 30)
    score = jnp.where(usable & ~has_failed, sizes, big)
    best = jnp.argmin(score, axis=-1)                            # (Q,)
    best_ok = jnp.take_along_axis(score, best[:, None], axis=1)[:, 0] < big

    chosen = jnp.take_along_axis(sets, best[:, None, None], axis=1)[:, 0]  # (Q, E)
    union = jnp.any(jnp.where(usable[..., None], sets, False), axis=1)
    union_ok = jnp.any(usable, axis=-1) & ~jnp.any(union & ~alive, axis=-1)

    is_and = pred.is_and
    mask = jnp.where(is_and[:, None], chosen, union)
    ok = jnp.where(is_and, best_ok, union_ok)
    if not cfg.use_index:
        ok = jnp.zeros_like(ok)                                  # Feather-like: no index
    broadcast = ~ok
    mask = jnp.where(broadcast[:, None], jnp.broadcast_to(alive, (q, e)), mask & alive)
    return mask, broadcast


def scan_engine(tup_f, tup_sid, tup_count, pred: QueryPred, sublists,
                sublist_len, use_kernel: bool = False):
    """Per-edge predicate scan (the InfluxDB role). Evaluates each query's
    predicate + shard OR-list against every edge-local tuple.

    Args:
      sublists:    (Q, E, L, 2) int32 shard ids assigned to each (query, edge).
      sublist_len: (Q, E) int32 — #valid entries in each OR-list.

    Returns (count, vsum, vmin, vmax): each (Q, E).
    """
    if use_kernel:
        from repro.kernels.st_scan import ops as st_ops
        return st_ops.st_scan(tup_f, tup_sid, tup_count, pred, sublists, sublist_len)
    from repro.kernels.st_scan import ref as st_ref
    return st_ref.st_scan_ref(tup_f, tup_sid, tup_count, pred, sublists, sublist_len)


@partial(jax.jit, static_argnums=(0, 5))
def query_step(cfg: StoreConfig, state: StoreState, pred: QueryPred,
               alive: jnp.ndarray, key: jax.Array, use_kernel: bool = False):
    """Decentralized query execution (paper Fig 4): index lookup -> planning
    -> per-edge sub-queries -> combine. Returns (QueryResult, QueryInfo)."""
    e = cfg.n_edges
    q = pred.lat0.shape[0]
    s = cfg.max_shards_per_query
    sites = cfg.sites_array()

    lookup_mask, broadcast = _lookup_sets(cfg, pred, sites, alive)

    if cfg.use_index:
        matched = lookup(state.index, pred, lookup_mask, s)
        assignment = planner_lib.plan(cfg.planner, matched, alive, key)  # (Q, S)
        # Per-edge OR-lists: rank of shard within its assigned edge.
        am = (assignment[..., None] == jnp.arange(e, dtype=jnp.int32))   # (Q, S, E)
        rank = jnp.cumsum(am, axis=1) - 1
        pos = jnp.where(am, rank, s)
        sublists = jnp.full((q, e, s, 2), -1, jnp.int32)
        qq = jnp.broadcast_to(jnp.arange(q, dtype=jnp.int32)[:, None, None], (q, s, e))
        ee = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32)[None, None, :], (q, s, e))
        sidv = jnp.stack([matched.sid_hi, matched.sid_lo], axis=-1)       # (Q, S, 2)
        sidv = jnp.broadcast_to(sidv[:, :, None, :], (q, s, e, 2))
        sublists = sublists.at[qq, ee, pos].set(sidv, mode="drop")
        sublist_len = jnp.sum(am, axis=1).astype(jnp.int32)               # (Q, E)
        ovf = matched.overflow
        shards_matched = jnp.sum(matched.valid, axis=-1)
    else:
        # Broadcast baseline (Feather-like): no shard scoping; every alive
        # edge scans everything. Correct only under replication=1.
        sublists = jnp.zeros((q, e, 1, 2), jnp.int32)
        sublist_len = jnp.where(jnp.broadcast_to(alive, (q, e)), -1, 0).astype(jnp.int32)
        ovf = jnp.zeros((q,), jnp.bool_)
        shards_matched = jnp.full((q,), -1, jnp.int32)

    count, vsum, vmin, vmax = scan_engine(state.tup_f, state.tup_sid,
                                          state.tup_count, pred,
                                          sublists, sublist_len, use_kernel)

    result = QueryResult(
        count=jnp.sum(count, axis=-1).astype(jnp.int32),
        vsum=jnp.sum(vsum, axis=-1),
        vmin=jnp.min(vmin, axis=-1),
        vmax=jnp.max(vmax, axis=-1),
        overflow=ovf,
    )
    info = QueryInfo(
        lookup_edges=jnp.sum(lookup_mask, axis=-1),
        subquery_edges=jnp.sum(sublist_len != 0, axis=-1),
        shards_matched=shards_matched,
        max_shards_per_edge=jnp.max(jnp.abs(sublist_len), axis=-1),
        broadcast=broadcast,
    )
    return result, info
