"""Content-based hashing for AerialDB (paper §3.4.1).

The paper uses xxHash64 over three content dimensions:

    H_i(shardID)   -> edge     (modulo over the hash)
    H_t(timepoint) -> edge     (fixed tau-width bucket id, hashed, then modulo)
    H_s(lat, lon)  -> edge     (Voronoi point-location; see voronoi.py)

TPU adaptation: the TPU VPU has no 64-bit integer lanes, so a 64-bit value is
represented as a pair of uint32 limbs ``(hi, lo)`` and all xxHash64 arithmetic
(mod-2^64 add/mul, rotations, shifts) is performed in 32-bit limb math. The
32x32 -> 64 partial products are computed via 16-bit digit splits, which map
onto native uint32 multiplies. The same limb formulation is used by the Pallas
kernel in ``repro.kernels.hash64``; this module is the jnp implementation and
the oracle for that kernel lives in ``repro/kernels/hash64/ref.py`` (pure
python ints).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

U32 = np.uint32          # numpy scalars inline as jaxpr literals (Pallas-safe)
MASK16 = np.uint32(0xFFFF)

# xxHash64 primes, as (hi, lo) uint32 limb pairs.
PRIME64_1 = (0x9E3779B1, 0x85EBCA87)
PRIME64_2 = (0xC2B2AE3D, 0x27D4EB4F)
PRIME64_3 = (0x165667B1, 0x9E3779F9)
PRIME64_4 = (0x85EBCA77, 0xC2B2AE63)
PRIME64_5 = (0x27D4EB2F, 0x165667C5)

U64 = Tuple[jnp.ndarray, jnp.ndarray]  # (hi, lo) uint32 limbs


def u64(hi, lo) -> U64:
    return jnp.asarray(hi, jnp.uint32), jnp.asarray(lo, jnp.uint32)


def const64(pair):
    return np.uint32(pair[0]), np.uint32(pair[1])


def xor64(a: U64, b: U64) -> U64:
    return a[0] ^ b[0], a[1] ^ b[1]


def add64(a: U64, b: U64) -> U64:
    lo = a[1] + b[1]
    carry = (lo < a[1]).astype(U32)
    return a[0] + b[0] + carry, lo


def shr64(a: U64, n: int) -> U64:
    """Logical right shift by a static amount 0 < n < 64."""
    if n == 0:
        return a
    if n >= 32:
        return jnp.zeros_like(a[0]), a[0] >> U32(n - 32)
    return a[0] >> U32(n), (a[1] >> U32(n)) | (a[0] << U32(32 - n))


def shl64(a: U64, n: int) -> U64:
    if n == 0:
        return a
    if n >= 32:
        return a[1] << U32(n - 32), jnp.zeros_like(a[1])
    return (a[0] << U32(n)) | (a[1] >> U32(32 - n)), a[1] << U32(n)


def rotl64(a: U64, n: int) -> U64:
    n = n % 64
    if n == 0:
        return a
    return or64(shl64(a, n), shr64(a, 64 - n))


def or64(a: U64, b: U64) -> U64:
    return a[0] | b[0], a[1] | b[1]


def _mul32x32(a: jnp.ndarray, b: jnp.ndarray) -> U64:
    """Exact 32x32 -> 64 product via 16-bit digit split (TPU-friendly)."""
    a_lo, a_hi = a & MASK16, a >> U32(16)
    b_lo, b_hi = b & MASK16, b >> U32(16)
    ll = a_lo * b_lo                      # <= 2^32 - 2^17 + 1: fits u32
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi
    # result = hh << 32 + (lh + hl) << 16 + ll, with carry tracking
    mid = lh + (ll >> U32(16))            # <= 2^32-1: no overflow
    carry_mid = (mid < lh).astype(U32)    # lh + x can wrap? lh<=(2^16-1)^2, ll>>16<2^16 -> no wrap
    mid2 = mid + hl
    carry_mid = carry_mid + (mid2 < mid).astype(U32)
    lo = (mid2 << U32(16)) | (ll & MASK16)
    hi = hh + (mid2 >> U32(16)) + (carry_mid << U32(16))
    return hi, lo


def mul64(a: U64, b: U64) -> U64:
    """(a * b) mod 2^64 in uint32 limbs."""
    hi, lo = _mul32x32(a[1], b[1])
    hi = hi + a[1] * b[0] + a[0] * b[1]   # cross terms only affect hi limb
    return hi, lo


def xxh64_avalanche(h: U64) -> U64:
    h = xor64(h, shr64(h, 33))
    h = mul64(h, const64(PRIME64_2))
    h = xor64(h, shr64(h, 29))
    h = mul64(h, const64(PRIME64_3))
    h = xor64(h, shr64(h, 32))
    return h


def xxh64_u64(key: U64, seed: U64 = None) -> U64:
    """xxHash64 of a single 64-bit word (8-byte input path of XXH64)."""
    if seed is None:
        seed = u64(jnp.zeros_like(key[0]), jnp.zeros_like(key[1]))
    h = add64(add64(seed, const64(PRIME64_5)), u64(jnp.zeros_like(key[0]), jnp.full_like(key[1], 8)))
    k1 = mul64(key, const64(PRIME64_2))
    k1 = rotl64(k1, 31)
    k1 = mul64(k1, const64(PRIME64_1))
    h = xor64(h, k1)
    h = add64(mul64(rotl64(h, 27), const64(PRIME64_1)), const64(PRIME64_4))
    return xxh64_avalanche(h)


def mod_u64(h: U64, n: int) -> jnp.ndarray:
    """(h mod n) for small static n (< 2^16), returned as int32.

    h mod n = ((hi mod n) * (2^32 mod n) + (lo mod n)) mod n. With n < 2^16
    both factors of the product are < 2^16, so all arithmetic stays in native
    uint32 lanes. Edge counts (tens to low thousands) satisfy this easily.
    """
    if not (0 < n < (1 << 16)):
        raise ValueError(f"mod_u64 requires 0 < n < 65536, got {n}")
    n32 = np.uint32(n)
    two32_mod = np.uint32((1 << 32) % n)
    hi_m = h[0] % n32
    lo_m = h[1] % n32
    return (((hi_m * two32_mod) % n32 + lo_m) % n32).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Paper hash functions H_i and H_t (H_s lives in voronoi.py).
# ---------------------------------------------------------------------------

def hash_shard_id(sid_hi: jnp.ndarray, sid_lo: jnp.ndarray, n_edges: int) -> jnp.ndarray:
    """H_i: mod(xxh64(shardID), edgeCount) (paper §3.4.1)."""
    h = xxh64_u64(u64(sid_hi.astype(U32), sid_lo.astype(U32)))
    return mod_u64(h, n_edges)


def time_bucket(t: jnp.ndarray, tau: float) -> jnp.ndarray:
    """Bucket id of a timepoint for tau-width temporal slicing (int32)."""
    return jnp.floor(t / tau).astype(jnp.int32)


def hash_time_bucket(bucket: jnp.ndarray, n_edges: int) -> jnp.ndarray:
    """H_t applied to a precomputed bucket id: mod(xxh64(bucket), edgeCount).

    Hashing the bucket id (not the raw time) ensures shard-collection
    periodicity does not hit adjacent edges (paper §3.4.1).
    """
    b = bucket.astype(U32)
    h = xxh64_u64(u64(jnp.zeros_like(b), b))
    return mod_u64(h, n_edges)


def hash_time(t: jnp.ndarray, tau: float, n_edges: int) -> jnp.ndarray:
    """H_t: timepoint -> tau bucket -> edge index."""
    return hash_time_bucket(time_bucket(t, tau), n_edges)
