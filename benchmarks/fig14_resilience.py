"""Fig 14: query latency + result completeness under edge/device failures.

The paper's resilience claim (§4.5.3): graceful degradation upon edge
failures with relatively low latency. The reproduction gates it numerically:

* ``fig14/failures=k`` — k random edge failures; the ``derived`` column
  carries machine-readable ``completeness=...`` (matched tuples / full-store
  tuples for a catch-all audit query — the ground truth the gate reads) plus
  ``bound=...`` (``QueryInfo.completeness_bound``: the planner-assigned
  fraction of index-visible shards — shard-weighted and blind to shards
  whose every entry died, so it can exceed the true completeness under
  unspread placement; see the QueryInfo docstring) and ``replicas_lost=...``.
  CI asserts completeness == 1.0 for every k <= replication - 1 = 2 (the
  paper's 2-failure durability guarantee).
* ``fig14/device_failure/*`` — a whole failure domain (device block) dies at
  once. With failure-domain placement (``n_failure_domains=4``) completeness
  stays 1.0 and is gated; the ``spread=0`` row shows the ungated baseline
  where all three content hashes can land in one block.
* ``fig14/post_recovery`` — the device comes back and the anti-entropy
  repair pass runs (``AerialDB.recover_device``); completeness must be 1.0
  again (gated) and the repair telemetry rides in ``derived``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_store, emit, open_session, timeit
from repro.core.datastore import make_pred

PRED = make_pred(q=8, t0=0.0, t1=1e9, has_temporal=True, is_and=True)


def _completeness(db, total, key):
    us, (res, info) = timeit(lambda: db.query(PRED, key=key))
    got = int(np.asarray(res.count)[0])
    bound = float(np.asarray(info.completeness_bound)[0])
    lost = int(np.asarray(info.replicas_lost)[0])
    return us / 8, got / total, (
        f"completeness={got / total:.4f};bound={bound:.4f};"
        f"replicas_lost={lost};broadcast_frac="
        f"{np.asarray(info.broadcast).mean():.2f}")


def run():
    cfg, state, alive_full, _, t_max, _ = build_store(n_drones=40, rounds=6)
    cfg = dataclasses.replace(cfg, planner="random")  # catch-all audit query
    db_full = open_session(cfg, state, alive_full)
    _, (res_full, _) = timeit(
        lambda: db_full.query(PRED, key=jax.random.key(4)))
    total = int(np.asarray(res_full.count)[0])

    # --- random edge failures: the paper's fig14 sweep ---
    rng = np.random.default_rng(9)
    for k in (0, 1, 2, 3, 4):
        alive = np.ones(cfg.n_edges, bool)
        alive[rng.choice(cfg.n_edges, k, replace=False)] = False
        db = open_session(cfg, state, jnp.asarray(alive))
        us, _, derived = _completeness(db, total, jax.random.key(4))
        emit(f"fig14/failures={k}", us, derived)

    # --- whole-device failures: one contiguous domain block dies at once ---
    # (16 edges / 4 domains so the block divides evenly; spread=1 places
    # every shard's replicas across >= 2 domains and is the gated row.)
    for spread in (1, 0):
        cfg_d, state_d, alive_d, fleet_d, _, _ = build_store(
            n_edges=16, n_drones=40, rounds=6,
            n_failure_domains=4 if spread else 1)
        cfg_d = dataclasses.replace(cfg_d, planner="random",
                                    n_failure_domains=4)
        db = open_session(cfg_d, state_d, alive_d)
        _, (res, _) = timeit(lambda: db.query(PRED, key=jax.random.key(4)))
        total_d = int(np.asarray(res.count)[0])
        db.fail_device(1)
        us, _, derived = _completeness(db, total_d, jax.random.key(4))
        emit(f"fig14/device_failure/spread={spread}", us, derived)
        if spread:
            # --- ingest DURING the outage (placed around the dead block),
            # then recover + anti-entropy repair: the recovered device is
            # re-integrated (replicas re-placed onto it, index backfilled)
            # and the full window stays complete. ---
            payloads, metas = fleet_d.next_rounds(2)
            db.ingest_rounds(payloads, metas)
            total_d += int(np.prod(payloads.shape[:3]))
            db.recover_device(1)
            rep = db.last_repair
            us, _, derived = _completeness(db, total_d, jax.random.key(5))
            emit("fig14/post_recovery", us,
                 derived + f";repaired={rep['shards_replaced']};"
                 f"tuples_copied={rep['tuples_copied']};"
                 f"entries_backfilled={rep['entries_backfilled']}")
