"""Fig 14: query latency + result completeness under 0-4 edge failures."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_store, emit, open_session, timeit
from repro.core.datastore import make_pred


def run():
    cfg, state, alive_full, _, t_max, _ = build_store(n_drones=40, rounds=6)
    cfg = dataclasses.replace(cfg, planner="random")  # catch-all audit query
    pred = make_pred(q=8, t0=0.0, t1=1e9, has_temporal=True, is_and=True)
    db_full = open_session(cfg, state, alive_full)
    _, (res_full, _) = timeit(
        lambda: db_full.query(pred, key=jax.random.key(4)))
    total = int(np.asarray(res_full.count)[0])
    rng = np.random.default_rng(9)
    for k in (0, 1, 2, 3, 4):
        alive = np.ones(cfg.n_edges, bool)
        alive[rng.choice(cfg.n_edges, k, replace=False)] = False
        db = open_session(cfg, state, jnp.asarray(alive))
        us, (res, info) = timeit(
            lambda d=db: d.query(pred, key=jax.random.key(4)))
        got = int(np.asarray(res.count)[0])
        emit(f"fig14/failures={k}", us / 8,
             f"completeness={got/total:.4f};broadcast_frac="
             f"{np.asarray(info.broadcast).mean():.2f}")
