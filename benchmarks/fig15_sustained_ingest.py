"""Fig 15 (beyond the paper): sustained ingest far past tuple capacity.

The seed implementation saturated at ``tuple_capacity`` — ``tup_count``
clamped at the cap and every later insert was silently dropped, so the store
went permanently read-only after ~16k tuples per edge. With the ring-buffer
tuple log + index retention this benchmark drives >= 4x capacity through
every edge and reports:

  * insert latency cold (ring not yet wrapped) vs steady state (every write
    overwrites) — flat latency is the headline claim;
  * query correctness over the retained window: result vs a replication-free
    oracle, and Pallas kernel vs jnp reference engine;
  * index `valid` occupancy and cursor high-water mark vs capacity across
    the retention/compaction cycles.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_store, emit, open_session, timeit
from repro.api import AerialDB
from repro.core.datastore import make_pred

CAP = 2048
TARGET_FILL = 4          # stop once min(tup_count) >= TARGET_FILL * CAP
MAX_ROUNDS = 400


def run():
    cfg, state, alive, fleet, t_max, _ = build_store(
        n_edges=8, n_drones=16, rounds=1, records=30, tuple_capacity=CAP,
        index_capacity=1024, retention_every=4)
    db = open_session(cfg, state, alive)     # sustained-ingest session

    def one_round():
        payload, meta = fleet.next_shards()
        info = db.insert(payload, meta)
        return payload, np.asarray(info["intake_per_edge"])

    payloads, intakes, occ_hwm, cur_hwm = [], [], 0, 0
    cold_us, steady_us = [], []
    rounds = 0
    while rounds < MAX_ROUNDS:
        count_min = int(np.asarray(db.state.tup_count).min())
        if count_min >= TARGET_FILL * CAP:
            break
        t0 = time.perf_counter()
        payload, intake = one_round()
        jax.block_until_ready(db.state.tup_count)
        dt_us = (time.perf_counter() - t0) * 1e6
        (steady_us if count_min >= CAP else cold_us).append(dt_us)
        payloads.append(payload)
        intakes.append(intake)
        occ_hwm = max(occ_hwm,
                      int(np.asarray(db.state.index.valid.sum(axis=1)).max()))
        cur_hwm = max(cur_hwm, int(np.asarray(db.state.index.cursor).max()))
        rounds += 1

    state = db.state
    count = np.asarray(state.tup_count)
    # Skip the first timed call of each regime (compile / cache effects).
    emit("fig15/insert_cold", float(np.mean(cold_us[1:])),
         f"rounds={len(cold_us)}")
    emit("fig15/insert_steady", float(np.mean(steady_us[1:])),
         f"rounds={len(steady_us)};fill={count.min() / CAP:.1f}x")
    emit("fig15/ingest_totals", 0.0,
         f"written={int(count.sum())};overwritten="
         f"{int(np.asarray(state.tup_overwritten).sum())};lost="
         f"{int(np.asarray(state.tup_dropped).sum())}")
    emit("fig15/index_retention", 0.0,
         f"occ_hwm={occ_hwm}/{cfg.index_capacity};cursor_hwm={cur_hwm};"
         f"retired={int(np.asarray(state.index.retired).sum())};"
         f"idx_dropped={int(np.asarray(state.index.dropped).sum())}")

    # Fused ingest driver: the same steady-state ingest as ONE lax.scan
    # dispatch over stacked rounds with donated state (the facade's
    # ingest_rounds) — amortizes per-round dispatch + host sync vs the
    # per-step loop above.
    n_fused = 16
    payloads_f, metas_f = fleet.next_rounds(n_fused)
    db_f = open_session(cfg, jax.tree.map(jnp.copy, state), alive)
    db_f.ingest_rounds(payloads_f, metas_f)     # compile; donates the copy
    jax.block_until_ready(db_f.state.tup_count)
    t0 = time.perf_counter()
    db_f.ingest_rounds(payloads_f, metas_f)
    jax.block_until_ready(db_f.state.tup_count)
    us_fused = (time.perf_counter() - t0) * 1e6 / n_fused
    emit("fig15/insert_steady_fused", us_fused,
         f"rounds_per_dispatch={n_fused};"
         f"speedup_vs_loop={np.mean(steady_us[1:]) / us_fused:.2f}x")

    # Retained-window query: widest recent window that provably fits every ring.
    intakes_arr = np.asarray(intakes)
    k = 1
    while k < len(payloads) and intakes_arr[-(k + 1):].sum(axis=0).max() <= CAP:
        k += 1
    t_lo = float(min(p[..., 0].min() for p in payloads[-k:]))
    t_hi = float(payloads[-1][..., 0].max()) + 1.0
    flat = np.concatenate([p.reshape(-1, p.shape[-1]) for p in payloads])
    m = (flat[:, 0] >= t_lo) & (flat[:, 0] <= t_hi)
    exp_count = int(m.sum())

    pred = make_pred(q=1, t0=t_lo, t1=t_hi, has_temporal=True, is_and=True)
    key = jax.random.key(0)
    db_ker = AerialDB(cfg, state, alive, key, use_kernel=True)
    us_ref, (res_ref, _) = timeit(lambda: db.query(pred, key=key))
    us_ker, (res_ker, _) = timeit(lambda: db_ker.query(pred, key=key))
    exact = int(res_ref.count[0]) == exp_count
    match = (int(res_ker.count[0]) == int(res_ref.count[0])
             and np.allclose(np.asarray(res_ker.vsum), np.asarray(res_ref.vsum),
                             rtol=1e-5))
    emit("fig15/query_ref", us_ref,
         f"window_rounds={k};count={int(res_ref.count[0])};"
         f"oracle={exp_count};exact={exact}")
    emit("fig15/query_kernel", us_ker, f"match_ref={match}")


if __name__ == "__main__":
    run()
