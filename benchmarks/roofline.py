"""Roofline aggregation: dry-run JSONs -> per-cell three-term table.

    compute term    = HLO_FLOPs_per_device / 197e12   (bf16 peak / chip)
    memory term     = HLO_bytes_per_device / 819e9    (HBM bw / chip)
    collective term = wire_bytes_per_device / 50e9    (per-link ICI bw)

HLO_* come from the structural analyzer (launch/hlo_analysis.py) over the
compiled per-device module, with while-loop trip multiplication. MODEL_FLOPS
is the analytic useful work (6*N_active*D for train, 2*N_active*D for
prefill/decode forward, + exact attention terms); the ratio
MODEL/HLO exposes remat + dispatch overhead.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
Emits a markdown table (stdout) consumed by EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(arch: str, shape: dict) -> float:
    """Analytic useful FLOPs for the cell (global, all chips)."""
    from repro.configs.base import get_config
    cfg = get_config(arch)
    s, b = shape["seq_len"], shape["global_batch"]
    kind = shape["kind"]
    tokens = b * s if kind != "decode" else b   # decode: 1 new token/seq

    # --- parameter-matmul flops: 2 * N_active per token (fwd) ---
    d = cfg.d_model
    n_active = 0.0
    l = cfg.n_layers
    if cfg.family in ("dense", "moe", "encdec", "hybrid"):
        if cfg.mla:
            nope, rph, vdim = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
            attn_p = (d * cfg.n_heads * (nope + rph) + d * (cfg.kv_lora + rph)
                      + cfg.kv_lora * cfg.n_heads * (nope + vdim)
                      + cfg.n_heads * vdim * d)
        elif cfg.n_heads:
            attn_p = d * cfg.n_heads * cfg.d_head * 2 \
                + d * cfg.n_kv * cfg.d_head * 2
        else:
            attn_p = 0.0
        if cfg.n_experts:
            expert = 3 * d * cfg.d_ff_expert
            ffn_p = (cfg.top_k * expert + cfg.n_shared * expert
                     + d * cfg.n_experts / 1e6)  # gate negligible
            dense_ffn = 3 * d * cfg.d_ff
            n_active = (l - cfg.first_dense) * (attn_p + ffn_p) \
                + cfg.first_dense * (attn_p + dense_ffn)
        elif cfg.family == "hybrid":
            from repro.models.transformer import hybrid_attn_sites
            di = cfg.d_inner
            g, n = cfg.n_groups, cfg.ssm_state
            nh = di // cfg.ssm_headdim
            mamba_p = d * (2 * di + 2 * g * n + nh) + di * d
            shared_apps = len(hybrid_attn_sites(cfg))
            attn_shared = attn_p + 3 * d * cfg.d_ff
            n_active = l * mamba_p + shared_apps * attn_shared
        else:
            n_active = l * (attn_p + 3 * d * cfg.d_ff)
        if cfg.family == "encdec":
            # encoder runs over s/ratio tokens; fold into effective N*T
            enc_p = cfg.encoder_layers * (attn_p + 3 * d * cfg.d_ff)
            xattn_p = cfg.n_layers * (attn_p + d * d)
            n_active += xattn_p
            n_active += enc_p / cfg.enc_seq_ratio  # enc tokens are s/ratio
    elif cfg.family == "ssm":
        di = cfg.d_inner
        n, dtr = cfg.ssm_state, max(d // 16, 1)
        n_active = l * (d * 2 * di + di * (dtr + 2 * n) + dtr * di + di * d)

    unembed = d * cfg.vocab_padded
    fwd = 2.0 * (n_active + unembed) * tokens

    # --- attention score/context flops (full attention) ---
    if cfg.n_heads and cfg.family != "ssm":
        h, dh = cfg.n_heads, (cfg.d_head or 0)
        if cfg.mla:
            dh = cfg.mla_nope_dim + cfg.mla_rope_dim
        if kind == "decode":
            kv_len = s
            attn = 4.0 * b * h * kv_len * dh * l
        else:
            attn = 4.0 * b * h * (s * s / 2) * dh * l / 1.0
        if cfg.family == "hybrid":
            from repro.models.transformer import hybrid_attn_sites
            attn = attn / l * len(hybrid_attn_sites(cfg))
        if cfg.family == "encdec":
            attn += 4.0 * b * h * (s // cfg.enc_seq_ratio) * dh * l * \
                (1 if kind == "decode" else s)
        fwd += attn
    return 3.0 * fwd if kind == "train" else fwd


def load_cells(d):
    cells = []
    for f in sorted(glob.glob(f"{d}/*.json")):
        cells.append(json.load(open(f)))
    return cells


def row_for(r):
    chips = CHIPS.get(r["mesh"], 256)
    ha = r["hlo_analysis_per_device"]
    flops_dev = ha["flops"]
    t_c = flops_dev / PEAK_FLOPS
    t_m = ha["bytes_accessed"] / HBM_BW
    t_x = ha["collectives"]["wire_bytes"] / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    mf = model_flops(r["arch"], r)
    ratio = mf / (flops_dev * chips) if flops_dev else 0.0
    mem = r.get("memory_analysis", {})
    hbm = (mem.get("argument_size_in_bytes", 0) +
           mem.get("temp_size_in_bytes", 0)) / 1e9
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom, "model_flops": mf,
        "hlo_flops_global": flops_dev * chips, "useful_ratio": ratio,
        "hbm_gb_per_dev": hbm,
        "roofline_frac": (t_c / max(t_c, t_m, t_x)) if max(t_c, t_m, t_x) else 0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = []
    print("| arch | shape | t_compute | t_memory | t_collective | dominant "
          "| MODEL/HLO flops | HBM GB/dev | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in load_cells(args.dir):
        if r["status"] == "skipped":
            if r["mesh"].endswith(args.mesh) or args.mesh in r["mesh"]:
                print(f"| {r['arch']} | {r['shape']} | — | — | — | skipped: "
                      f"{r['skip_reason'][:40]}… | — | — | — |")
            continue
        if r["status"] != "ok" or r["mesh"] != args.mesh:
            continue
        row = row_for(r)
        rows.append(row)
        print(f"| {row['arch']} | {row['shape']} | {row['t_compute_s']:.3f}s "
              f"| {row['t_memory_s']:.3f}s | {row['t_collective_s']:.3f}s "
              f"| **{row['dominant']}** | {row['useful_ratio']:.2f} "
              f"| {row['hbm_gb_per_dev']:.1f} | {row['roofline_frac']:.2f} |")
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
