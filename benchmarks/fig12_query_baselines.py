"""Fig 12: query latency vs baselines — the paper's headline ~100x claim.

AerialDB (indexed, shard-scoped) vs Feather-like (broadcast scan) vs
centralized cloud (single store). The dense SPMD emulation on one CPU core
serializes per-edge work, so the derived column reports the parallel-latency
proxy the paper's gap comes from: max tuples scanned on any single node
(per-node work). AerialDB scopes each edge to the OR-list shards; broadcast
and centralized scan their full logs."""
import jax
import numpy as np

from benchmarks.common import (build_store, emit, open_session,
                               paper_workloads, timeit)


def run():
    variants = [
        ("aerialdb", dict(replication=3, use_index=True, n_edges=20)),
        ("feather_bcast", dict(replication=1, use_index=False, n_edges=20)),
        ("cloud_central", dict(replication=1, use_index=True, n_edges=1)),
    ]
    stores = {name: build_store(n_drones=40, rounds=6,
                                tuple_capacity=1 << 17, **kw)
              for name, kw in variants}
    proxy_base = {}
    for name in ("aerialdb", "feather_bcast", "cloud_central"):
        cfg, state, alive, _, t_max, anchors = stores[name]
        db = open_session(cfg, state, alive)
        wl = paper_workloads(t_max, n_queries=8, anchors=anchors)
        for wname in ("5min/200m", "30min/1km", "2h/5km"):
            pred = wl[wname]
            us, (res, info) = timeit(
                lambda d=db, p=pred: d.query(p, key=jax.random.key(2)))
            if name == "aerialdb":
                per_node = (np.asarray(info.max_shards_per_edge).mean()
                            * cfg.records_per_shard)
                proxy_base[wname] = max(per_node, 1.0)
                emit(f"fig12/{name}/{wname}", us / 8,
                     f"max_node_tuples_scanned={per_node:.0f};"
                     f"rows={np.asarray(res.count).mean():.0f}")
            else:
                per_node = np.asarray(state.tup_count).max()
                emit(f"fig12/{name}/{wname}", us / 8,
                     f"max_node_tuples_scanned={per_node:.0f};"
                     f"per_node_work_vs_aerialdb="
                     f"{per_node/proxy_base[wname]:.0f}x")
