"""Shared benchmark harness: timing, store construction, CSV emission.

Each fig*.py module mirrors one paper table/figure (DESIGN.md §7) and prints
``name,us_per_call,derived`` rows. Absolute times are CPU-host numbers; the
paper-relevant content is the RELATIVE orderings (AerialDB vs broadcast vs
centralized, planner comparisons, failure degradation), which are
algorithmic and transfer across hosts.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import AerialDB
from repro.core.datastore import StoreConfig, init_store, make_pred
from repro.data.synthetic import CityConfig, DroneFleet, make_sites, make_query_workload
from repro.distributed.federation import ingest_rounds, shard_store

ROWS = []   # structured rows, cleared per figure by run.py's --json machinery


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append({"name": name, "us_per_call": round(float(us_per_call), 1),
                 "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def build_store(n_edges=20, n_drones=20, rounds=4, records=30, planner="min_shards",
                replication=3, use_index=True, tuple_capacity=1 << 15, seed=0,
                stagger_s=0.0, index_capacity=4096, retention_every=4,
                mesh=None, max_shards=512, n_failure_domains=1):
    """Stand up a loaded store. Ingest goes through the fused lax.scan driver
    (one dispatch for all rounds, donated state); pass ``mesh`` (an edge mesh)
    to load through the sharded federated runtime instead of 1-device jit.
    ``n_failure_domains`` > 1 turns on failure-domain replica spreading
    (fig14's device-failure rows)."""
    sites = make_sites(n_edges, CityConfig(), seed=3)
    cfg = StoreConfig(
        n_edges=n_edges, sites=tuple(map(tuple, sites.tolist())),
        tuple_capacity=tuple_capacity, index_capacity=index_capacity,
        max_shards_per_query=max_shards, records_per_shard=records,
        planner=planner, replication=replication, use_index=use_index,
        retention_every=retention_every, n_failure_domains=n_failure_domains)
    fleet = DroneFleet(n_drones, records_per_shard=records, seed=seed + 1,
                       stagger_s=stagger_s)
    state = init_store(cfg)
    if mesh is not None:
        state = shard_store(state, mesh)
    alive = jnp.ones(n_edges, bool)
    payloads, metas = fleet.next_rounds(rounds)
    state, _ = ingest_rounds(cfg, state, payloads, metas, alive, mesh=mesh)
    flat = payloads.reshape(-1, payloads.shape[-1])
    t_max = float(flat[:, 0].max())
    anchors = flat[:, :3]          # (t, lat, lon) of every inserted tuple
    return cfg, state, alive, fleet, t_max, anchors


def open_session(cfg, state, alive, seed=0, **kw) -> AerialDB:
    """Adopt a ``build_store`` state into an ``AerialDB`` session (the
    benchmarks' query/insert surface — no deprecated step shims)."""
    return AerialDB(cfg, state, alive, jax.random.key(seed), **kw)


def timed_insert(cfg, state, alive, payload, meta):
    """One facade insert from a FIXED pre-state (pure per call, so timeit
    re-runs measure the same work): returns the post-insert StoreState."""
    db = open_session(cfg, state, alive)
    db.insert(payload, meta)
    return db.state


def paper_workloads(t_max, n_queries=8, seed=11, anchors=None):
    """The paper's 9 workloads: {5min, 30min, 2h} x {200m, 1km, 5km}.

    ``anchors``: (N, 3) array of (t, lat, lon) of really-inserted tuples;
    windows are centered on sampled anchors (analysts query where drones
    flew), so small windows are non-empty as in the paper's trace-driven
    workload."""
    rng = np.random.default_rng(seed)
    out = {}
    for tname, tsec in [("5min", 300.0), ("30min", 1800.0), ("2h", 7200.0)]:
        for sname, skm in [("200m", 0.2), ("1km", 1.0), ("5km", 5.0)]:
            if anchors is None:
                w = make_query_workload(rng, n_queries, CityConfig(), t_max,
                                        skm, tsec)
            else:
                pick = anchors[rng.integers(0, len(anchors), n_queries)]
                deg = skm / 111.0
                w = dict(
                    lat0=(pick[:, 1] - deg / 2).astype(np.float32),
                    lat1=(pick[:, 1] + deg / 2).astype(np.float32),
                    lon0=(pick[:, 2] - deg / 2).astype(np.float32),
                    lon1=(pick[:, 2] + deg / 2).astype(np.float32),
                    t0=(pick[:, 0] - tsec / 2).astype(np.float32),
                    t1=(pick[:, 0] + tsec / 2).astype(np.float32))
            out[f"{tname}/{sname}"] = make_pred(
                q=n_queries, has_spatial=True, has_temporal=True, is_and=True,
                **w)
    return out
