"""Fig 10/11: query scaling with concurrent clients (batched query sets:
concurrency on TPU is batch width, not threads)."""
import jax

from benchmarks.common import (build_store, emit, open_session,
                               paper_workloads, timeit)


def run():
    cfg, state, alive, _, t_max, anchors = build_store(n_drones=40, rounds=6)
    db = open_session(cfg, state, alive)
    for q in (1, 4, 8, 16):
        wl = paper_workloads(t_max, n_queries=q, anchors=anchors, seed=5)
        pred = wl["30min/1km"]
        us, _ = timeit(lambda p=pred: db.query(p, key=jax.random.key(1)))
        emit(f"fig10/clients={q}", us, f"us_per_query={us/q:.1f}")
