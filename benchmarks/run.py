"""Benchmark driver: one module per paper table/figure (DESIGN.md §7).
Prints ``name,us_per_call,derived`` CSV rows; also usable per-figure:
``python -m benchmarks.run --only fig12``."""

import argparse
import importlib
import sys
import time

FIGS = ["fig5_membership", "fig7_insertion_scaling", "fig8_insertion_baselines",
        "fig9_planners", "fig10_concurrency", "fig11_mixed_queries",
        "fig12_query_baselines", "fig13_locality", "fig14_resilience",
        "fig15_sustained_ingest"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter, e.g. fig12")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = time.time()
    for mod_name in FIGS:
        if args.only and args.only not in mod_name:
            continue
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        print(f"# --- {mod_name} ---", flush=True)
        mod.run()
    print(f"# total_wall_s={time.time() - t0:.0f}")


if __name__ == "__main__":
    main()
