"""Benchmark driver: one module per paper table/figure (DESIGN.md §7).
Prints ``name,us_per_call,derived`` CSV rows; also usable per-figure:
``python -m benchmarks.run --only fig12``.

``--json`` additionally writes one machine-readable ``BENCH_<fig>.json`` per
figure run (rows + wall-clock + host/config fingerprint), so the perf
trajectory is tracked across PRs — CI runs the scan-batch family with
``--only fig5_scan_batch --json`` and archives the file as an artifact.
"""

import argparse
import importlib
import json
import platform
import sys
import time

FIGS = ["fig5_membership", "fig5_scan_batch", "fig7_insertion_scaling",
        "fig8_insertion_baselines", "fig9_planners", "fig10_concurrency",
        "fig11_mixed_queries", "fig12_query_baselines", "fig13_locality",
        "fig14_resilience", "fig15_sustained_ingest", "fig17_churn_soak",
        "fig18_streaming_ingest", "fig19_chaos_soak"]


def _config_fingerprint() -> dict:
    """Host/config context stored with every JSON result so cross-PR
    comparisons know what they are comparing."""
    import jax
    return {
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter, e.g. fig12")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<fig>.json per figure (rows + "
                         "wall-clock + config)")
    args = ap.parse_args()
    from benchmarks import common

    print("name,us_per_call,derived")
    t0 = time.time()
    config = _config_fingerprint() if args.json else None
    ran = 0
    for mod_name in FIGS:
        if args.only and args.only not in mod_name:
            continue
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        print(f"# --- {mod_name} ---", flush=True)
        common.ROWS.clear()
        fig_t0 = time.time()
        mod.run()
        if args.json:
            out = {
                "fig": mod_name,
                "wall_s": round(time.time() - fig_t0, 2),
                "config": config,
                "rows": list(common.ROWS),
            }
            path = f"BENCH_{mod_name}.json"
            with open(path, "w") as f:
                json.dump(out, f, indent=2)
            print(f"# wrote {path} ({len(out['rows'])} rows)", flush=True)
        ran += 1
    if not ran:
        print(f"# no figure matches --only {args.only!r}", file=sys.stderr)
        sys.exit(2)
    print(f"# total_wall_s={time.time() - t0:.0f}")


if __name__ == "__main__":
    main()
