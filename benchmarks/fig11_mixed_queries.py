"""Fig 11-style mixed query workload, driven through the unified API.

The paper's analyst traffic (§4.5, Fig 9-13) is a MIX of spatial, temporal,
and id range-aggregation queries, not a single shape. This row family runs a
representative mix — spatial-only, temporal-only, spatio-temporal AND, the OR
combinator, and shard-id point lookups, batched into one compiled scan via
``Query.batch`` — and sweeps the ``AggSpec`` axis (channels, requested ops)
so any regression in the generalized aggregation pipeline (channel selection
/ mean derivation / per-spec recompiles) shows up in the perf trajectory.

All rows go through ``repro.api`` (the facade + builder), which is the
surface future workloads will use.
"""

import jax
import numpy as np

from benchmarks.common import emit, timeit
from repro.api import AerialDB, AggSpec, Query
from repro.data.synthetic import CityConfig, DroneFleet, make_sites


def _mixed_batch(anchors, t_max, rng):
    """One mixed workload batch: 8 queries over really-visited anchors."""
    deg = 1.0 / 111.0
    qs = []
    for _ in range(2):                      # spatial-only (1 km boxes)
        t, la, lo = anchors[rng.integers(0, len(anchors))]
        qs.append(Query().bbox(la - deg / 2, la + deg / 2,
                               lo - deg / 2, lo + deg / 2))
    for _ in range(2):                      # temporal-only (5 min windows)
        t, la, lo = anchors[rng.integers(0, len(anchors))]
        qs.append(Query().time(max(t - 150.0, 0.0), t + 150.0))
    for _ in range(2):                      # spatio-temporal AND (30 min/5 km)
        t, la, lo = anchors[rng.integers(0, len(anchors))]
        qs.append(Query().bbox(la - 2.5 * deg, la + 2.5 * deg,
                               lo - 2.5 * deg, lo + 2.5 * deg)
                  & Query().time(max(t - 900.0, 0.0), t + 900.0))
    t, la, lo = anchors[rng.integers(0, len(anchors))]     # OR combinator
    qs.append(Query().bbox(la - deg, la + deg, lo - deg, lo + deg)
              | Query().time(max(t_max - 300.0, 0.0), t_max))
    qs.append(Query().shard(3, 2).time(0.0, t_max))        # id range
    return qs


def run():
    n_edges, n_drones, rounds, records = 20, 40, 6, 30
    sites = make_sites(n_edges, CityConfig(), seed=3)
    db = AerialDB.open(n_edges=n_edges,
                       sites=tuple(map(tuple, sites.tolist())),
                       tuple_capacity=1 << 14, index_capacity=4096,
                       max_shards_per_query=512, records_per_shard=records)
    fleet = DroneFleet(n_drones, records_per_shard=records, seed=1)
    payloads, metas = fleet.next_rounds(rounds)
    db.ingest_rounds(payloads, metas)
    flat = payloads.reshape(-1, payloads.shape[-1])
    anchors, t_max = flat[:, :3], float(flat[:, 0].max())

    rng = np.random.default_rng(17)
    qs = _mixed_batch(anchors, t_max, rng)
    key = jax.random.key(2)

    specs = [
        ("count_sum_ch0", AggSpec(channel=0, ops=("count", "sum"))),
        ("mean_ch2", AggSpec(channel=2, ops=("mean",))),
        ("minmax_ch3", AggSpec(channel=3, ops=("min", "max"))),
        ("all_ops_ch1", AggSpec(channel=1)),
        # fused multi-channel: every channel's aggregates from ONE scan of
        # the log (vs 4 single-channel queries) — the tentpole's third leg
        ("fused_all_channels", AggSpec(channels=(0, 1, 2, 3),
                                       ops=("count", "mean"))),
    ]
    for name, spec in specs:
        pred, _ = Query.batch(*[q.agg(*spec.ops, channels=spec.channels)
                                for q in qs])
        us, (res, info) = timeit(
            lambda p=pred, s=spec: db.query((p, s), key=key))
        emit(f"fig11/mixed/{name}", us / len(qs),
             f"rows={np.asarray(res.count).mean():.0f};"
             f"channels={len(spec.channels)};"
             f"edges={np.asarray(info.subquery_edges).mean():.1f};"
             f"broadcast={int(np.asarray(info.broadcast).sum())}")

    # Multi-channel win: one fused 4-channel scan vs 4 single-channel scans.
    fused_spec = AggSpec(channels=(0, 1, 2, 3), ops=("count", "mean"))
    pred, _ = Query.batch(*[q.agg("count", "mean", channels=(0, 1, 2, 3))
                            for q in qs])
    us_fused, _ = timeit(lambda: db.query((pred, fused_spec), key=key))

    def four_single():
        outs = [db.query((pred, AggSpec(channel=ch, ops=("count", "mean"))),
                         key=key) for ch in range(4)]
        return outs[-1]
    us_four, _ = timeit(four_single)
    emit("fig11/fused_4ch_vs_4x1ch", us_fused / len(qs),
         f"speedup_vs_4_queries={us_four / us_fused:.2f}x")
