"""Fig 9: the 9 query workloads x load-balancing strategy (Random /
MinShards / MinEdges). Derived mirrors the paper's right axis: max shards
queried on any participating edge (the per-edge latency driver MinShards
minimizes) + #edges engaged (which MinEdges minimizes)."""
import dataclasses
import jax
import numpy as np

from benchmarks.common import (build_store, emit, open_session,
                               paper_workloads, timeit)


def run():
    cfg, state, alive, _, t_max, anchors = build_store(n_drones=40, rounds=6)
    wl = paper_workloads(t_max, n_queries=8, anchors=anchors)
    for planner in ("random", "min_shards", "min_edges"):
        db = open_session(dataclasses.replace(cfg, planner=planner), state,
                          alive)
        for wname, pred in wl.items():
            key = jax.random.key(0)
            us, (res, info) = timeit(
                lambda d=db, pr=pred: d.query(pr, key=key))
            spe = np.asarray(info.max_shards_per_edge).mean()
            edges = np.asarray(info.subquery_edges).mean()
            emit(f"fig9/{planner}/{wname}", us / 8,
                 f"max_shards_per_edge={spe:.1f};edges={edges:.1f};"
                 f"rows={np.asarray(res.count).mean():.0f}")
