"""Fig 18: streaming ingest — pipeline latency + latest hot path at scale.

The paper's D400 deployment (§4.4) is the smallest interesting fleet; this
sweep drives the PR 8 streaming subsystem (``repro.ingest``) end to end at
400 / 4k / 40k drones under an adversarial telemetry stream (shuffled
arrival order, ~3% duplicate re-sends, ~2% seq drops, ~5% partial payloads)
and measures the mixed serving surface:

* ``fig18/D<n>/ingest`` — per-record **ingest-to-queryable latency**
  (submit wall-time -> flush ``block_until_ready``), p50/p99 over every
  record flushed after the warm-up round. This is the double-buffered
  path: host coalescing of chunk k+1 overlaps chunk k's device scan.
* ``fig18/D<n>/latest`` — the O(drones) hot-cache read
  (``AerialDB.latest()``), p50/p99 per call.
* ``fig18/D<n>/insert_single`` — one B=1 facade insert from a fixed
  pre-state: the single-record baseline the latest path is gated against.
* ``fig18/D<n>/range`` — an 8-query anchored spatio-temporal scan batch
  (1 km x 30 min windows over really-ingested telemetry).
* ``fig18/D<n>/reconcile`` — the exact counter audit
  (``IngestPipeline.reconcile``): ``accepted == flushed + pending`` and
  ``sum(tup_count) == flushed * replication``.

In-benchmark gates (CI re-asserts both from ``BENCH_*.json``): every
reconcile row is ``ok=1``, and latest-query p99 <= 10x the single-insert
path. ``FIG18_SWEEP`` overrides the drone counts (comma-separated).
"""
import os
import time

import jax
import numpy as np

from benchmarks.common import emit, open_session
from repro.api import AerialDB
from repro.core.datastore import StoreConfig, make_pred
from repro.data.synthetic import CityConfig, DroneFleet, make_sites
from repro.ingest import IngestPipeline
from repro.launch.mesh import make_edge_mesh

E = 16            # edge servers (4 per device on the 4-device mesh)
RPD = 4           # records per drone per round == records_per_shard
ROUNDS = 3        # round 0 warms compile caches; latency measured after it
DUP_FRAC, DROP_FRAC, PARTIAL_FRAC = 0.03, 0.02, 0.05


def _mult128(n: int) -> int:
    return (int(n) + 127) // 128 * 128


def _make_cfg(d: int) -> StoreConfig:
    # Size the ring so the sweep never wraps (reconcile's exact-count regime)
    # with ~1.5x headroom over the even-spread per-edge load; the index gets
    # the same headroom so entries are not capacity-dropped mid-benchmark.
    per_edge = d * RPD * (ROUNDS + 1) * 3 // E
    sites = make_sites(E, CityConfig(), seed=3)
    return StoreConfig(
        n_edges=E, sites=tuple(map(tuple, sites.tolist())),
        tuple_capacity=max(2048, _mult128(per_edge * 3 // 2)),
        index_capacity=max(512, _mult128(per_edge * 3 // (2 * RPD))),
        records_per_shard=RPD, replication=3, max_drones=d,
        n_failure_domains=4)


def _round_records(rng, city, d: int, rnd: int):
    """One telemetry round: every drone emits RPD sequenced records, then the
    stream is roughed up — drops (seq gaps), duplicate re-sends, partial
    value payloads, and a full arrival-order shuffle."""
    drone = np.repeat(np.arange(d, dtype=np.int64), RPD)
    seq = np.tile(np.arange(rnd * RPD, (rnd + 1) * RPD, dtype=np.int64), d)
    n = drone.size
    t = (seq + rng.uniform(0.0, 0.5, n)).astype(np.float32)
    lat = rng.uniform(city.lat_min, city.lat_max, n).astype(np.float32)
    lon = rng.uniform(city.lon_min, city.lon_max, n).astype(np.float32)
    vals = rng.normal(size=(n, 4)).astype(np.float32)
    vals[rng.random(n) < PARTIAL_FRAC, 2:] = np.nan
    idx = np.nonzero(rng.random(n) >= DROP_FRAC)[0]
    dup = idx[rng.random(idx.size) < DUP_FRAC]
    idx = np.concatenate([idx, dup])
    rng.shuffle(idx)
    return drone[idx], seq[idx], t[idx], lat[idx], lon[idx], vals[idx]


def _ptimes(fn, iters: int, warmup: int = 2):
    """Per-call p50/p99 (us): individual wall-times, not a mean — the gate
    is on the tail."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    us = np.empty(iters)
    for i in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        us[i] = (time.perf_counter() - t0) * 1e6
    return float(np.percentile(us, 50)), float(np.percentile(us, 99))


def run():
    sweep = [int(s) for s in
             os.environ.get("FIG18_SWEEP", "400,4000,40000").split(",")]
    mesh = (make_edge_mesh(4, n_edges=E) if jax.device_count() >= 4
            else None)
    city = CityConfig()
    for d in sweep:
        rng = np.random.default_rng(7)
        cfg = _make_cfg(d)
        db = AerialDB.open(cfg, mesh, seed=0)
        pipe = IngestPipeline(db)

        lat_us, anchors = [], []
        for rnd in range(ROUNDS):
            dr, sq, t, la, lo, vals = _round_records(rng, city, d, rnd)
            pipe.submit_arrays(dr, sq, t, la, lo, vals)
            fl = pipe.flush()
            if rnd:                       # round 0 pays one-time compiles
                lat_us.append(np.asarray(fl["latency_s"]) * 1e6)
            anchors.append(np.stack([t, la, lo], axis=1))
        fl = pipe.flush(drain=True)       # ship sub-shard tails (drop holes)
        lat_us.append(np.asarray(fl["latency_s"]) * 1e6)
        lat_us = np.concatenate([a for a in lat_us if a.size])
        c = pipe.counters
        p50i, p99i = (float(np.percentile(lat_us, p)) for p in (50, 99))
        emit(f"fig18/D{d}/ingest", p50i,
             f"p50_us={p50i:.1f};p99_us={p99i:.1f};"
             f"records={c['flushed_records']};flushes={c['flushes']};"
             f"duplicate={c['duplicate']};partial={c['partial']}")

        # Exact counter audit BEFORE the timing probes below touch the
        # session state from throwaway sessions.
        rec = pipe.reconcile()
        assert rec["ok"], f"D{d}: counter reconciliation failed: {rec}"

        p50l, p99l = _ptimes(lambda: db.latest(), iters=50)
        emit(f"fig18/D{d}/latest", p50l,
             f"p50_us={p50l:.1f};p99_us={p99l:.1f};drones={d}")

        one_pay, one_meta = DroneFleet(
            1, records_per_shard=RPD, seed=99).next_shards()
        state, alive = db.state, db.alive

        def ins():
            s = open_session(cfg, state, alive)
            s.insert(one_pay, one_meta)
            return s.state.tup_count

        p50s, p99s = _ptimes(ins, iters=20)
        emit(f"fig18/D{d}/insert_single", p50s,
             f"p50_us={p50s:.1f};p99_us={p99s:.1f}")

        anc = np.concatenate(anchors)
        pick = anc[np.random.default_rng(5).integers(0, len(anc), 8)]
        deg = 1.0 / 111.0                 # 1 km x 30 min anchored windows
        pred = make_pred(
            q=8, lat0=pick[:, 1] - deg / 2, lat1=pick[:, 1] + deg / 2,
            lon0=pick[:, 2] - deg / 2, lon1=pick[:, 2] + deg / 2,
            t0=pick[:, 0] - 900.0, t1=pick[:, 0] + 900.0,
            has_spatial=True, has_temporal=True, is_and=True)
        p50q, p99q = _ptimes(
            lambda: db.query(pred, key=jax.random.key(2))[0].count,
            iters=8, warmup=1)
        emit(f"fig18/D{d}/range", p50q,
             f"p50_us={p50q:.1f};p99_us={p99q:.1f};q=8")

        assert p99l <= 10.0 * max(p99s, 1.0), (
            f"D{d}: latest p99 {p99l:.1f}us exceeds 10x single-insert "
            f"p99 {p99s:.1f}us — the O(drones) hot path regressed")
        emit(f"fig18/D{d}/reconcile", 0.0,
             f"ok=1;accepted={rec['accepted']};"
             f"flushed={rec['flushed_records']};pending={rec['pending']};"
             f"stored={rec['stored_tuples']};duplicate={rec['duplicate']};"
             f"partial={rec['partial']};dropped={rec['dropped']};"
             f"latest_p99_us={p99l:.1f};insert_p99_us={p99s:.1f}")
