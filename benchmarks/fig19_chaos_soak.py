"""Fig 19: chaos soak — seeded fault schedules against a live deployment.

The PR 9 chaos engine end to end: for each seed, ``FaultPlan.random``
generates a replayable schedule mixing edge crashes, whole-domain loss,
fleet partitions, and transient flush-dispatch failures (every plan is
required to contain a partition and a flush burst), and ``ChaosRunner``
drives it against a streaming deployment (``AerialDB`` + ``IngestPipeline``
with the bounded retry loop) while the SAME telemetry stream feeds a
never-faulted reference. Rows per seed:

* ``fig19/seed<s>/soak`` — wall time per soak step, plus the fault mix
  (events applied, retries absorbed, give-ups) and flush totals.
* ``fig19/seed<s>/recovery`` — the degradation/recovery trajectory:
  catch-all ``completeness_bound`` after every repair-running event
  (heal / recover_edges / recover_device); ``completeness`` in the derived
  string is the MINIMUM over events where the fleet was back to full
  health — the paper's recovery claim is that it is exactly 1.0.
* ``fig19/seed<s>/reconcile`` — ``accepted == flushed + pending`` +
  stored-tuple audit, ``gave_up == 0`` (bursts stay within the retry
  budget), ring wrap-free-ness, and ``content_equal=1``: the faulted
  store's canonical content (sorted ring windows + per-shard replica/
  holder sets) is bit-identical to the never-faulted reference's.
* ``fig19/crash_replay`` — the crash-durability leg: a mid-flush
  ``PipelineCrash`` tears the pipeline after records were acked into the
  write-ahead journal; a fresh session + pipeline + ``replay_journal``
  recovers with ``lost=0`` and reference-equal content.

In-benchmark gates (CI re-asserts all from ``BENCH_*.json``): completeness
exactly 1.0 at every full-health event and at the end, ``gave_up == 0``,
counter reconcile ok, content equal, crash replay ``lost == 0``.
``FIG19_SEEDS`` overrides the seed sweep (comma-separated).
"""
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.api import AerialDB
from repro.chaos import (ChaosRunner, FaultEvent, FaultPlan,
                         canonical_content)
from repro.core.datastore import StoreConfig, make_pred
from repro.data.synthetic import CityConfig, make_sites
from repro.ingest import IngestPipeline, PipelineCrash
from repro.launch.mesh import make_edge_mesh

E = 16            # edge servers (4 per device on the 4-device mesh)
D = 24            # drones; each emits one full shard per soak step
RPD = 4           # records per drone per step == records_per_shard
N_STEPS = 8
MIN_ALIVE = 6     # alive AND reachable floor (>= replication = 3)
_REPAIR_EVENTS = ("heal", "recover_edges", "recover_device")
CATCH_ALL = make_pred(q=1, t0=-1e9, t1=1e9, has_temporal=True, is_and=True)


def _cfg() -> StoreConfig:
    # Wrap-free sizing (the content-equality precondition, see
    # repro.chaos.audit): worst-case per-edge load is the whole volume
    # concentrated on MIN_ALIVE edges; 2048 covers it ~3x over.
    sites = make_sites(E, CityConfig(), seed=3)
    return StoreConfig(
        n_edges=E, sites=tuple(map(tuple, sites.tolist())),
        tuple_capacity=2048, index_capacity=512,
        max_shards_per_query=256, records_per_shard=RPD,
        replication=3, max_drones=D, n_failure_domains=4)


def _step_records(seed: int, step: int):
    """Deterministic per-(seed, step) telemetry: every drone contributes
    exactly one full shard, so faulted and reference runs coalesce
    identically."""
    rng = np.random.default_rng((seed, step))
    n = D * RPD
    drone = np.repeat(np.arange(D, dtype=np.int64), RPD)
    seq = np.tile(np.arange(RPD, dtype=np.int64), D) + step * RPD
    t = seq.astype(np.float64) + step * 0.25
    lat = rng.uniform(12.90, 13.00, n)
    lon = rng.uniform(77.50, 77.62, n)
    vals = rng.normal(size=(n, 4))
    return drone, seq, t, lat, lon, vals


def _feed(pipe, seed, step):
    pipe.submit_arrays(*_step_records(seed, step))
    return pipe.flush()


def _bound(db) -> float:
    _res, qi = db.query(CATCH_ALL, key=jax.random.key(1))
    return float(np.asarray(qi.completeness_bound)[0])


def _content_equal(a, b) -> bool:
    if any(ra.shape != rb.shape or not np.array_equal(ra, rb)
           for ra, rb in zip(a["edges"], b["edges"])):
        return False
    return a["index"] == b["index"]


def _soak(seed: int, mesh) -> None:
    plan = FaultPlan.random(
        seed, n_edges=E, n_steps=N_STEPS, n_domains=4, min_alive=MIN_ALIVE,
        max_transient=2, require=("partition", "flush_fail"))
    cfg = _cfg()
    db = AerialDB.open(cfg, mesh, seed=0)
    pipe = IngestPipeline(db, max_retries=4, sleep=lambda s: None)
    runner = ChaosRunner(plan, db, pipe)
    db_ref = AerialDB.open(cfg, mesh, seed=0)
    pipe_ref = IngestPipeline(db_ref)

    full_bounds, degraded_bounds = [], []

    def probe(applied):
        for entry in applied:
            if entry["kind"] not in _REPAIR_EVENTS:
                continue
            b = _bound(db)
            if bool(np.asarray(db.effective_alive).all()):
                # Full health restored: repair must leave NOTHING degraded.
                assert b == 1.0, (
                    f"seed {seed}: completeness {b} after full-health "
                    f"{entry['kind']} at step {entry['step']}")
                full_bounds.append(b)
            else:
                degraded_bounds.append(b)   # telemetry, legitimately < 1.0

    t0 = time.perf_counter()
    for step in range(plan.n_steps):
        probe(runner.advance(step))
        _feed(pipe, seed, step)
        _feed(pipe_ref, seed, step)
        rec = pipe.reconcile()
        assert rec["counters_ok"], f"seed {seed} step {step}: {rec}"
    probe(runner.advance(plan.n_steps))     # closing heal/recover events
    wall = time.perf_counter() - t0

    c = pipe.counters
    emit(f"fig19/seed{seed}/soak", wall / N_STEPS * 1e6,
         f"steps={N_STEPS};events={len(runner.log)};"
         f"kinds={'+'.join(sorted(set(plan.kinds())))};"
         f"retries={c['retries']};gave_up={c['gave_up']};"
         f"flushed={c['flushed_records']};duplicate={c['duplicate']}")

    final = _bound(db)
    assert final == 1.0, f"seed {seed}: final completeness {final}"
    comp = min(full_bounds + [final])
    emit(f"fig19/seed{seed}/recovery", 0.0,
         f"completeness={comp:.3f};full_health_probes={len(full_bounds)};"
         f"degraded_probes={len(degraded_bounds)};"
         f"degraded_min={min(degraded_bounds, default=1.0):.3f}")

    rec = pipe.reconcile()
    assert rec["ok"], f"seed {seed}: reconcile failed: {rec}"
    assert c["gave_up"] == 0, f"seed {seed}: {c['gave_up']} give-ups"
    wrapped = int(np.asarray(db.state.tup_count).max()) > cfg.tuple_capacity
    assert not wrapped, f"seed {seed}: ring wrapped, content gate unsound"
    equal = _content_equal(canonical_content(db), canonical_content(db_ref))
    assert equal, f"seed {seed}: content diverged from reference"
    emit(f"fig19/seed{seed}/reconcile", 0.0,
         f"ok=1;accepted={rec['accepted']};flushed={rec['flushed_records']};"
         f"pending={rec['pending']};stored={rec['stored_tuples']};"
         f"gave_up={c['gave_up']};wrapped={int(wrapped)};"
         f"content_equal={int(equal)}")


def _crash_replay(mesh) -> None:
    """Mid-flush crash against a journaled pipeline, then recovery from a
    cold start: fresh session + fresh pipeline + journal replay."""
    cfg = _cfg()
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "wal.bin")
        db = AerialDB.open(cfg, mesh, seed=0)
        pipe = IngestPipeline(db, journal=path, sleep=lambda s: None)
        _feed(pipe, 0, 0)
        plan = FaultPlan(events=(FaultEvent(1, "pipeline_crash"),),
                         n_steps=2)
        runner = ChaosRunner(plan, db, pipe)
        runner.advance(1)                   # arm the one-shot crash
        crashed = False
        try:
            _feed(pipe, 0, 1)
        except PipelineCrash:
            crashed = True
        assert crashed, "injected crash did not fire"
        acked = pipe.counters["accepted"]
        pipe.close()

        db2 = AerialDB.open(cfg, mesh, seed=0)
        pipe2 = IngestPipeline(db2, journal=path)
        rep = pipe2.replay_journal()
        pipe2.flush(drain=True)
        rec = pipe2.reconcile()
        lost = acked - rec["flushed_records"]
        db_ref = AerialDB.open(cfg, mesh, seed=0)
        pipe_ref = IngestPipeline(db_ref)
        _feed(pipe_ref, 0, 0)
        _feed(pipe_ref, 0, 1)
        equal = _content_equal(canonical_content(db2),
                               canonical_content(db_ref))
        assert rec["ok"] and lost == 0 and equal, (rep, rec, lost, equal)
        emit("fig19/crash_replay", 0.0,
             f"ok=1;journal_records={rep['journal_records']};"
             f"replayed={rep['accepted']};already_seen={rep['already_seen']};"
             f"acked={acked};lost={lost};content_equal={int(equal)}")


def run():
    seeds = [int(s) for s in
             os.environ.get("FIG19_SEEDS", "3,11,42").split(",")]
    mesh = (make_edge_mesh(4, n_edges=E) if jax.device_count() >= 4
            else None)
    for seed in seeds:
        _soak(seed, mesh)
    _crash_replay(mesh)
