"""Fig 7 + §4.4.2: insertion latency D100 (20 edges) vs D400 (80 edges), the
replica load-balance band across edges, and sharded-runtime insertion scaling
(the paper-scale D400 config over 1/2/4/8 simulated devices — each worker
subprocess forces its own host device count, since jax locks it at backend
initialization).

Balance note: the paper's §3.4.1 discusses the temporal-clustering hotspot —
when every drone emits a shard with the SAME collection timestamp, H_t sends
one replica of each to the same edge. A single synchronous round reproduces
that hotspot here (visible as max >> mean); with multiple rounds (temporal
diversity, as in the paper's 48 h workload) the band tightens toward the
paper's 3846-4479 range.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_store, emit, timed_insert, timeit
from repro.core.placement import ShardMeta

REPO_ROOT = Path(__file__).resolve().parent.parent


# (devices, fleets) sweep: 1-D mesh scaling over 1/2/4/8 devices, plus the
# 2-D ("fleet", "edge") mesh at 1/2/4 fleet partitions on 4 devices — the
# 1/2/4-fleet scaling rows of BENCH_fig7_insertion_scaling.json. Override
# with FIG7_SWEEP="dev:fleet,dev:fleet,..." (CI runs a light subset).
DEFAULT_SWEEP = ((1, 1), (2, 1), (4, 1), (8, 1), (4, 2), (4, 4))


def _sweep():
    spec = os.environ.get("FIG7_SWEEP")
    if not spec:
        return DEFAULT_SWEEP
    return tuple(tuple(int(x) for x in pair.split(":"))
                 for pair in spec.split(","))


def run_sharded_scaling(sweep=None):
    """Paper-scale 80-edge/400-drone ingest through the sharded federated
    runtime, one subprocess per (device count, fleet count) mesh shape."""
    for ndev, nfleet in (_sweep() if sweep is None else sweep):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
        src = str(REPO_ROOT / "src")
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.fed_worker",
             "--devices", str(ndev), "--fleets", str(nfleet)],
            capture_output=True, text=True, env=env, cwd=REPO_ROOT)
        if proc.returncode != 0:
            raise RuntimeError(
                f"fed_worker (devices={ndev}, fleets={nfleet}) failed:\n"
                f"{proc.stderr[-4000:]}")
        for line in proc.stdout.splitlines():
            if line.startswith("fig7/"):
                name, us, derived = line.split(",", 2)
                emit(name, float(us), derived)


def run():
    for name, n_edges, n_drones in [("D100", 20, 100), ("D400", 80, 400)]:
        cfg, state, alive, fleet, _, _ = build_store(
            n_edges=n_edges, n_drones=n_drones, rounds=6, records=15,
            tuple_capacity=1 << 16)
        payload, meta = fleet.next_shards()
        meta = ShardMeta(*[jnp.asarray(x) for x in meta])
        pj = jnp.asarray(payload)
        us, state2 = timeit(
            lambda: timed_insert(cfg, state, alive, pj, meta))
        emit(f"fig7/insert/{name}", us,
             f"us_per_shard={us/n_drones:.1f};drones={n_drones};edges={n_edges}")
        per_edge = np.asarray(state2.tup_count) // cfg.records_per_shard
        emit(f"fig7/replica_balance/{name}", 0.0,
             f"replicas_per_edge_min={per_edge.min()};max={per_edge.max()};"
             f"mean={per_edge.mean():.0f}")
        # single synchronous round: the paper's discussed H_t hotspot
        cfg1, state1, alive1, fleet1, _, _ = build_store(
            n_edges=n_edges, n_drones=n_drones, rounds=1, records=15)
        pe1 = np.asarray(state1.tup_count) // cfg1.records_per_shard
        emit(f"fig7/hotspot_single_round/{name}", 0.0,
             f"max={pe1.max()};mean={pe1.mean():.0f};"
             f"paper_s3.4.1_temporal_clustering")

    # --- sharded federated runtime: D400 over 1/2/4/8 simulated devices on
    # the 1-D mesh, plus 1/2/4 fleet partitions on the 2-D mesh ---
    run_sharded_scaling()
