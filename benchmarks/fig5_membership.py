"""Fig 5: shard-id membership predicate encodings in the per-edge engine.

Paper: InfluxDB OR-clause is linear in #shardIDs while regex grows
super-linearly. TPU analogue: the st_scan kernel's OR-list is a vectorized
(L x block) broadcast-compare — linear in L; we sweep L and also compare the
jnp reference engine, confirming linearity (no regex pathology by design).
"""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels.st_scan import ref as st_ref
from repro.core.datastore import make_pred


def run():
    rng = np.random.default_rng(0)
    e, c, q = 8, 4096, 4
    tup_f = jnp.asarray(rng.uniform(0, 100, (e, 7, c)).astype(np.float32))
    tup_sid = jnp.asarray(rng.integers(0, 500, (e, 2, c)).astype(np.int32))
    cnt = jnp.full((e,), c, jnp.int32)
    pred = make_pred(q=q, t0=0.0, t1=100.0, has_temporal=True, is_and=True)
    for l in (16, 64, 150, 300, 600):
        sub = jnp.asarray(rng.integers(0, 500, (q, e, l, 2)).astype(np.int32))
        slen = jnp.full((q, e), l, jnp.int32)
        us, _ = timeit(lambda s=sub, sl=slen: st_ref.st_scan_ref(
            tup_f, tup_sid, cnt, pred, s, sl))
        emit(f"fig5/or_list_jnp/L={l}", us, f"per_sid_us={us/l:.2f}")
    # paper's >150-sid group splitting: same total work, bounded per-call L
    l = 600
    sub = jnp.asarray(rng.integers(0, 500, (q, e, l, 2)).astype(np.int32))
    groups = [sub[:, :, i:i + 150] for i in range(0, l, 150)]
    def grouped():
        outs = [st_ref.st_scan_ref(tup_f, tup_sid, cnt, pred, g,
                                   jnp.full((q, e), 150, jnp.int32))
                for g in groups]
        return outs[0][0]
    us, _ = timeit(grouped)
    emit("fig5/or_list_grouped_150/L=600", us, "paper_splitting_rule")
