"""Fig 5 companion: batched-query scan cost in the per-edge engine.

The paper's 100x query-speedup claim (§3.5.2, Fig 5) rests on the per-edge
scan staying cheap under mixed analyst traffic — which arrives BATCHED. The
query-tiled st_scan kernel answers a whole ``block_q``-query tile per
resident VMEM tuple tile, so HBM tuple traffic (and, in interpret mode, the
grid-step count) grows as ceil(Q / block_q) instead of Q. This row family
sweeps Q in {1, 8, 64} for both engines over the same column-major log and
reports per-query scan time plus the batching speedup vs Q independent
single-query scans — the acceptance series tracked across PRs via
``--json`` (BENCH_fig5_scan_batch.json).

The kernel/ref COUNT cross-check is a hard gate: any bitwise mismatch
raises, which fails the CI benchmark-smoke job.
"""

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.datastore import make_pred
from repro.kernels.st_scan import ops as st_ops
from repro.kernels.st_scan import ref as st_ref

E, C, W = 8, 2048, 7
Q_SWEEP = (1, 8, 64)
BLOCK_C, BLOCK_Q = 512, 16


def _problem(rng, q):
    """One batched scan problem over a shared log: scan-all sentinel (the
    federated broadcast path — every edge scans everything), ~50%-selective
    temporal windows."""
    t0 = rng.uniform(0, 50, q).astype(np.float32)
    pred = make_pred(q=q, t0=t0, t1=t0 + 50.0, has_temporal=True, is_and=True)
    sublists = jnp.zeros((q, E, 1, 2), jnp.int32)
    slen = jnp.full((q, E), -1, jnp.int32)
    return pred, sublists, slen


def run():
    rng = np.random.default_rng(0)
    tup_f = jnp.asarray(rng.uniform(0, 100, (E, W, C)).astype(np.float32))
    tup_sid = jnp.asarray(rng.integers(0, 500, (E, 2, C)).astype(np.int32))
    cnt = jnp.full((E,), C, jnp.int32)

    per_query = {}
    counts = {}
    for q in Q_SWEEP:
        pred, sublists, slen = _problem(rng, q)
        us_ref, out_ref = timeit(
            lambda p=pred, s=sublists, sl=slen: st_ref.st_scan_ref(
                tup_f, tup_sid, cnt, p, s, sl))
        us_ker, out_ker = timeit(
            lambda p=pred, s=sublists, sl=slen: st_ops.st_scan(
                tup_f, tup_sid, cnt, p, s, sl,
                block_c=BLOCK_C, block_q=BLOCK_Q))
        counts[q] = (np.asarray(out_ref[0]), np.asarray(out_ker[0]))
        for engine, us in (("ref", us_ref), ("kernel", us_ker)):
            per_query[(engine, q)] = us / q
            emit(f"fig5_scan_batch/{engine}/Q={q}", us,
                 f"us_per_query={us / q:.1f};"
                 f"rows={int(counts[q][0].sum())}")

    # The tentpole acceptance series: batching Q queries into one tiled scan
    # vs Q independent single-query scans.
    for engine in ("ref", "kernel"):
        for q in Q_SWEEP[1:]:
            speedup = per_query[(engine, 1)] / per_query[(engine, q)]
            emit(f"fig5_scan_batch/{engine}/batch_speedup/Q={q}", 0.0,
                 f"speedup_vs_qx1={speedup:.2f}x;block_q={BLOCK_Q}")

    # Hard gate: the kernel must agree with the reference bitwise on counts.
    mismatch = [q for q, (cr, ck) in counts.items() if not (cr == ck).all()]
    emit("fig5_scan_batch/count_match", 0.0,
         f"ok={int(not mismatch)};qs={list(counts)}")
    if mismatch:
        raise RuntimeError(
            f"st_scan kernel/ref count mismatch at Q={mismatch}: the "
            "query-tiled kernel diverged from the oracle.")


if __name__ == "__main__":
    run()
