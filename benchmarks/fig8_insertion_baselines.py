"""Fig 8: insertion vs baselines. AerialDB (federated, 3x replication,
indexed) vs Feather-like (local insert only) vs centralized cloud.

Wall-clock on this 1-core host measures TOTAL work (the SPMD emulation
serializes edges); the paper's latency gain comes from per-node parallelism,
so the derived column reports max-tuples-absorbed-per-node — the paper's
bottleneck metric (a single cloud node absorbs everything; AerialDB spreads
3x-replicated intake across 20 edges => ~6.7x less per node).

Drone clocks are staggered by one H_t bucket width (the paper's §3.4.1
random-delay mitigation): perfectly synchronized collection sends every
shard's temporal replica to ONE edge (see fig7/hotspot_single_round)."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_store, emit, timed_insert, timeit
from repro.core.placement import ShardMeta


def run():
    variants = [
        ("aerialdb", dict(n_edges=20, replication=3, use_index=True)),
        ("feather_like", dict(n_edges=20, replication=1, use_index=False)),
        ("cloud_central", dict(n_edges=1, replication=1, use_index=True)),
    ]
    for name, kw in variants:
        cfg, state, alive, fleet, _, _ = build_store(
            n_drones=100, rounds=1, records=60, tuple_capacity=1 << 17,
            stagger_s=300.0, **kw)
        payload, meta = fleet.next_shards()
        meta = ShardMeta(*[jnp.asarray(x) for x in meta])
        pj = jnp.asarray(payload)
        us, st2 = timeit(lambda: timed_insert(cfg, state, alive, pj, meta))
        intake = np.asarray(st2.tup_count) - np.asarray(state.tup_count)
        emit(f"fig8/insert/{name}", us,
             f"us_per_shard={us/100:.1f};max_node_intake={intake.max()};"
             f"total_work={intake.sum()}")
