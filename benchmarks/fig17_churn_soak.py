"""Fig 17: rolling-failure churn soak — sustained ingest under fail/recover.

The paper's resilience story (§4.5.3) is a single failure event; real fleets
churn. This soak drives sustained ingest while edges and whole devices fail
and recover on a rolling schedule, and gates two properties of the
outage-epoch incremental repair path (``core/repair.py``):

* **Bounded recovery** — after every recovery the incremental repair pass
  restores measured completeness (catch-all audit count / tuples inserted)
  to 1.0 in the SAME round, including after a 3-edge outage that exceeds
  what replication can mask mid-outage.
* **O(outage) sweeps** — the final round opens a small 1-edge outage on the
  now-large store; the repair must sweep only the shards written during the
  outage window (plus replica-intersecting ones), so ``swept`` stays small
  while ``tracked`` has grown with the store.

Row families (one per soak round):

* ``fig17/round=NN/<phase>`` — ``us_per_call`` is the audit query latency;
  ``derived`` carries ``completeness=...`` (ground truth), ``bound=...`` /
  ``replicas_lost=...`` (the planner's surfaced ``QueryInfo`` view), and on
  repair rounds ``repair_ms=...;swept=...;tracked=...;copied=...;``
  ``reclaimed=...`` from ``AerialDB.last_repair``.

CI reads ``BENCH_fig17_churn_soak.json`` and asserts completeness == 1.0 on
every ``recovered`` row and ``3 * swept <= tracked`` on the final
small-outage row; ``run()`` asserts the same so local runs fail loudly.
"""
import time

import jax
import numpy as np

from benchmarks.common import build_store, emit, open_session, timeit
from repro.core.datastore import make_pred

PRED = make_pred(q=8, t0=0.0, t1=1e9, has_temporal=True, is_and=True)


def _audit(db, total):
    """Catch-all completeness probe: matched tuples / tuples ever inserted
    (ground truth), plus the planner's own degraded-result surfacing."""
    us, (res, info) = timeit(lambda: db.query(PRED, key=jax.random.key(4)),
                             warmup=0, iters=1)
    got = int(np.asarray(res.count)[0])
    bound = float(np.asarray(info.completeness_bound)[0])
    lost = int(np.asarray(info.replicas_lost)[0])
    return us / 8, got / total, (
        f"completeness={got / total:.4f};bound={bound:.4f};"
        f"replicas_lost={lost}")


def run():
    # 16 edges / 4 failure domains (device blocks of 4), replication 3,
    # planner="random" so the audit query fans out to every live replica
    # set. Capacity is sized so the ring never wraps during the soak —
    # retention never retires anything and "tuples ever inserted" stays the
    # completeness denominator.
    cfg, state, alive, fleet, _, _ = build_store(
        n_edges=16, n_drones=24, rounds=2, planner="random",
        n_failure_domains=4)
    db = open_session(cfg, state, alive)
    total = 2 * 24 * 30  # rounds x drones x records_per_shard

    def ingest(n_rounds):
        nonlocal total
        payloads, metas = fleet.next_rounds(n_rounds)
        db.ingest_rounds(payloads, metas)
        total += int(np.prod(payloads.shape[:3]))

    def repair_derived(wall_ms):
        rep = db.last_repair
        return (f";repair_ms={wall_ms:.1f};swept={rep['shards_swept']};"
                f"tracked={rep['shards_tracked']};"
                f"copied={rep['tuples_copied']};"
                f"reclaimed={rep['slots_reclaimed']}")

    # Rolling schedule: each entry is (phase, action). Recoveries run the
    # incremental repair inline (timed); every round then audits
    # completeness and emits one row. The 3-edge outage (rounds 6-8)
    # overlaps two epochs and recovers in two steps, exercising the
    # pending-shard carryover of a repair run under a still-degraded mask.
    schedule = [
        ("baseline", lambda: None),
        ("outage/edge", lambda: (db.fail_edges(3), ingest(1))),
        ("recovered", lambda: (ingest(1), db.recover_edges(3))),
        ("outage/device", lambda: (db.fail_device(1), ingest(2))),
        ("recovered", lambda: db.recover_device(1)),
        ("outage/edges=3", lambda: (db.fail_edges(2, 9), ingest(1),
                                    db.fail_edges(12), ingest(1))),
        ("partial", lambda: (db.recover_edges(2), ingest(1))),
        ("recovered", lambda: (db.recover_edges(9, 12), ingest(1))),
        ("outage/small", lambda: (db.fail_edges(5), ingest(1))),
        ("recovered/small", lambda: db.recover_edges(5)),
    ]
    recovered, scaling = [], None
    for rnd, (phase, action) in enumerate(schedule):
        t0 = time.perf_counter()
        action()
        wall_ms = (time.perf_counter() - t0) * 1e3
        us, comp, derived = _audit(db, total)
        if phase.startswith("recovered") or phase == "partial":
            derived += repair_derived(wall_ms)
        if phase.startswith("recovered"):
            recovered.append((rnd, comp))
        if phase == "recovered/small":
            scaling = (db.last_repair["shards_swept"],
                       db.last_repair["shards_tracked"])
        emit(f"fig17/round={rnd:02d}/{phase}", us, derived)

    # In-benchmark gates (CI re-asserts these from the JSON): completeness
    # returns to 1.0 in the recovery round itself, and the final 1-edge
    # outage on the full-grown store sweeps O(outage), not O(store).
    for rnd, comp in recovered:
        assert comp == 1.0, f"round {rnd}: completeness {comp} after repair"
    swept, tracked = scaling
    assert 0 < swept and 3 * swept <= tracked, (
        f"repair swept {swept} of {tracked} tracked shards — "
        "not O(outage)")
    emit("fig17/scaling_gate", 0.0,
         f"ok=1;swept={swept};tracked={tracked};recovered_rounds="
         f"{len(recovered)}")
