"""Fig 13: coordinator selection RC vs LC-0 vs LC-n. In SPMD the coordinator
is replicated compute, so the paper's network-hop effect is modeled via
telemetry: #edges contacted under each policy — LC-n answers locally when
the spatial predicate hashes to the coordinator and <= n shards match."""
import jax
import numpy as np

from benchmarks.common import (build_store, emit, open_session,
                               paper_workloads, timeit)


def run():
    cfg, state, alive, _, t_max, anchors = build_store(n_drones=40, rounds=6)
    db = open_session(cfg, state, alive)
    wl = paper_workloads(t_max, n_queries=8, anchors=anchors)
    for wname in ("5min/1km", "30min/1km", "2h/5km"):
        pred = wl[wname]
        us, (res, info) = timeit(
            lambda p=pred: db.query(p, key=jax.random.key(3)))
        lookup = np.asarray(info.lookup_edges).mean()
        sub = np.asarray(info.subquery_edges).mean()
        emit(f"fig13/RC/{wname}", us / 8,
             f"edges_contacted={lookup + sub + 1:.1f}")
        emit(f"fig13/LC-0/{wname}", us / 8,
             f"edges_contacted={lookup + sub:.1f}")
        local = (np.asarray(info.max_shards_per_edge) <= 3).mean()
        emit(f"fig13/LC-3/{wname}", us / 8,
             f"edges_contacted={max(lookup + sub - local, 1):.1f};"
             f"local_answer_frac={local:.2f}")
