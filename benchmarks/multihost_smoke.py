"""2-process ``jax.distributed`` CPU smoke: the cross-host fleet runtime.

The real multi-process path the simulated fleet mesh stands in for: a local
coordinator plus 2 worker processes x 2 simulated host devices each, one
process per fleet partition of a ``(2, 2) ("fleet", "edge")`` mesh
(``launch.mesh.init_fleet_processes`` selects the gloo CPU collectives
transport). Each worker drives the federation differential harness
end-to-end — fused ingest, inserts during an edge outage, queries before /
during / after failures — against a process-local single-device reference,
comparing replicated query results exactly and each process's addressable
state shards against the reference slice (the cross-process state is never
gathered: every process checks exactly the edge blocks it hosts).

Parent mode (no args) spawns the workers and gates on both exiting clean:

    PYTHONPATH=src python -m benchmarks.multihost_smoke

Used by CI as the multihost leg; also a how-to template for running
``benchmarks/fed_worker.py`` with --coordinator/--num-processes/--process-id.
"""

import argparse
import os
import socket
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

N_PROC = 2
DEV_PER_PROC = 2
E = 8
ROUNDS = 3


def child(coordinator: str, process_id: int) -> None:
    from repro.launch.mesh import init_fleet_processes, make_fleet_mesh
    init_fleet_processes(coordinator, N_PROC, process_id)

    import jax
    import jax.numpy as jnp
    import numpy as np

    assert jax.process_count() == N_PROC
    assert jax.local_device_count() == DEV_PER_PROC
    assert jax.device_count() == N_PROC * DEV_PER_PROC
    mesh = make_fleet_mesh(N_PROC, DEV_PER_PROC, n_edges=E)

    from repro.api import AerialDB, Query
    from repro.core.placement import ShardMeta
    from repro.data.synthetic import CityConfig, DroneFleet, make_sites
    from repro.core.datastore import StoreConfig

    sites = make_sites(E, CityConfig(), seed=3)
    cfg = StoreConfig(
        n_edges=E, sites=tuple(map(tuple, sites.tolist())),
        tuple_capacity=2048, index_capacity=512, max_shards_per_query=64,
        records_per_shard=12, retention_every=2)
    db_ref = AerialDB.open(cfg)             # process-local single device
    db_fed = AerialDB.open(cfg, mesh=mesh)  # global (2, 2) fleet mesh

    def check_states(what):
        """Every leaf of the sharded state, checked shard-by-shard against
        the local reference — each process validates the blocks it hosts."""
        for name, ref, fed in zip(
                [jax.tree_util.keystr(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(db_ref.state)[0]],
                jax.tree.leaves(db_ref.state), jax.tree.leaves(db_fed.state)):
            ref = np.asarray(ref)
            for s in fed.addressable_shards:
                np.testing.assert_array_equal(
                    np.asarray(s.data), ref[s.index],
                    err_msg=f"{what}: {name} shard {s.index}")

    def check_query(what, q, key):
        r1, i1 = db_ref.query(q, key=key)
        r2, i2 = db_fed.query(q, key=key)
        for f in r1._fields:
            a, b = np.asarray(getattr(r1, f)), np.asarray(getattr(r2, f))
            if f in ("vsum", "vmean"):  # cross-device accumulation order
                np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6,
                                           err_msg=f"{what}: {f}")
            else:
                np.testing.assert_array_equal(a, b, err_msg=f"{what}: {f}")
        for f in i1._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(i1, f)), np.asarray(getattr(i2, f)),
                err_msg=f"{what}: {f}")

    fleet = DroneFleet(10, records_per_shard=12, seed=43)
    pay, met = fleet.next_rounds(ROUNDS)
    db_ref.ingest_rounds(pay, met)
    db_fed.ingest_rounds(pay, met)
    check_states("post-ingest")

    q = Query().time(0.0, 1e9).agg("count", "mean", channel=1)
    qbox = (Query().bbox(12.85, 13.10, 77.45, 77.75)
            & Query().time(0.0, 1e9)).agg("count", "min", "max", channel=2)
    check_query("healthy", q, jax.random.key(7))
    check_query("healthy-bbox", qbox, jax.random.key(9))

    db_ref.fail_edges(1, 5)
    db_fed.fail_edges(1, 5)
    check_query("degraded", q, jax.random.key(11))
    p, m = DroneFleet(6, records_per_shard=12, seed=8).next_shards()
    m = ShardMeta(*[jnp.asarray(x) for x in m])
    db_ref.insert(p, m)
    db_fed.insert(p, m)
    # repair=False: the anti-entropy pass is host-side control-plane work
    # that gathers the full state — process-local by design, exercised on
    # the simulated (single-process) fleet mesh in tests/test_federation.py.
    db_ref.recover_edges(1, 5, repair=False)
    db_fed.recover_edges(1, 5, repair=False)
    check_states("post-recovery")
    check_query("recovered", q, jax.random.key(13))

    print(f"multihost_smoke: process {process_id} OK "
          f"({jax.process_count()} processes x {DEV_PER_PROC} devices, "
          f"mesh {dict(mesh.shape)})", flush=True)


def parent() -> None:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        port = s.getsockname()[1]
    coordinator = f"localhost:{port}"

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={DEV_PER_PROC}")
    src = str(REPO_ROOT / "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    procs = [
        subprocess.Popen(
            [sys.executable, "-m", "benchmarks.multihost_smoke", "--child",
             "--coordinator", coordinator, "--process-id", str(i)],
            env=env, cwd=REPO_ROOT)
        for i in range(N_PROC)]
    codes = [p.wait() for p in procs]
    if any(codes):
        raise SystemExit(f"multihost smoke failed: worker exit codes {codes}")
    print(f"multihost_smoke: OK ({N_PROC} processes, coordinator "
          f"{coordinator})", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--process-id", type=int, default=None)
    args = ap.parse_args()
    if args.child:
        child(args.coordinator, args.process_id)
    else:
        parent()


if __name__ == "__main__":
    main()
