"""Subprocess worker for the fig7 sharded-runtime scaling sweep.

Runs a paper-scale deployment (default: 80 edges / 400 drones, §4.4.2 D400)
through the sharded federated runtime on N simulated host devices — on the
1-D ``("edge",)`` mesh, or with ``--fleets F`` on the 2-D ``("fleet",
"edge")`` mesh (hierarchical merge + double-buffered query tiling) — and
emits the usual ``name,us_per_call,derived`` rows on stdout. Must be launched
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` already in the
environment (jax locks the device count at first backend initialization, so
the parent — fig7_insertion_scaling.py — sets it and spawns this module).

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m benchmarks.fed_worker --devices 4 --fleets 2

True cross-host mode — one OS process per fleet partition over
``jax.distributed`` (``launch.mesh.init_fleet_processes``); every process
runs the same command, ``--devices`` counts GLOBAL devices, and only process
0 prints rows:

    XLA_FLAGS=--xla_force_host_platform_device_count=2 PYTHONPATH=src \
      python -m benchmarks.fed_worker --devices 4 --fleets 2 \
      --coordinator localhost:9731 --num-processes 2 --process-id $RANK
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, required=True,
                    help="total (global) device count the mesh must span")
    ap.add_argument("--fleets", type=int, default=1,
                    help="fleet partitions: 1 = 1-D ('edge',) mesh, "
                         ">1 = 2-D ('fleet', 'edge') mesh")
    ap.add_argument("--edges", type=int, default=80)
    ap.add_argument("--drones", type=int, default=400)
    ap.add_argument("--records", type=int, default=15)
    ap.add_argument("--prefill-rounds", type=int, default=2)
    ap.add_argument("--coordinator", default=None,
                    help="host:port — run multi-process over jax.distributed "
                         "(one process per fleet partition)")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    args = ap.parse_args()

    if args.coordinator is not None:
        # Must run before any other jax API touches the backend.
        from repro.launch.mesh import init_fleet_processes
        init_fleet_processes(args.coordinator, args.num_processes,
                             args.process_id)

    import jax
    import jax.numpy as jnp
    import numpy as np

    if jax.device_count() != args.devices:
        raise SystemExit(
            f"expected {args.devices} devices, found {jax.device_count()} — "
            "launch with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{args.devices}")
    primary = jax.process_index() == 0

    from benchmarks.common import build_store, timeit
    from repro.core.datastore import make_pred
    from repro.core.placement import ShardMeta
    from repro.distributed.federation import (federated_insert_step,
                                              federated_query_step)
    from repro.launch.mesh import make_edge_mesh, make_fleet_mesh

    if args.fleets > 1:
        mesh = make_fleet_mesh(args.fleets, args.devices // args.fleets,
                               n_edges=args.edges)
    else:
        mesh = make_edge_mesh(args.devices, n_edges=args.edges)
    # tuple_capacity sized so the H_t hotspot edge (§3.4.1: one synchronous
    # round can land every shard's temporal replica on one edge) never wraps
    # within the run — keeps the catch-all count exact. min_edges planner:
    # its greedy loop is O(E) iterations vs O(#shards) for min_shards, which
    # matters at 1200 matched shards.
    cfg, state, alive, fleet, t_max, anchors = build_store(
        n_edges=args.edges, n_drones=args.drones, rounds=args.prefill_rounds,
        records=args.records, tuple_capacity=1 << 15, mesh=mesh,
        planner="min_edges",
        max_shards=2048)

    payload, meta = fleet.next_shards()
    meta = ShardMeta(*[jnp.asarray(x) for x in meta])
    pj = jnp.asarray(payload)
    us, (state2, _) = timeit(
        lambda: federated_insert_step(cfg, state, pj, meta, alive, mesh))
    tag = f"E{args.edges}/D{args.drones}/dev{args.devices}/fleet{args.fleets}"
    if primary:
        print(f"fig7/sharded_insert/{tag},{us:.1f},"
              f"us_per_shard={us / args.drones:.1f};devices={args.devices};"
              f"fleets={args.fleets}",
              flush=True)

    # Query smoke on the sharded store: exact catch-all count proves the
    # sharded runtime answered, not just ingested.
    pred = make_pred(q=1, t0=0.0, t1=1e9, has_temporal=True, is_and=True)
    result, _ = federated_query_step(cfg, state2, pred, alive,
                                     jax.random.key(0), mesh)
    expected = (args.prefill_rounds + 1) * args.drones * args.records
    got = int(np.asarray(result.count)[0])
    if got != expected:
        raise SystemExit(f"sharded catch-all count {got} != {expected}")
    if primary:
        print(f"fig7/sharded_query_exact/{tag},0.0,count={got};"
              f"fleets={args.fleets}", flush=True)


if __name__ == "__main__":
    main()
