"""§Perf hillclimb driver: compile one cell with config overrides and diff
the three roofline terms against the recorded baseline.

    PYTHONPATH=src python -m benchmarks.perf_iterate \
        --arch grok-1-314b --shape train_4k --tag bf16-params \
        --set param_dtype_str=bfloat16 --n-micro 8

Results land in experiments/perf/<arch>_<shape>_<tag>.json; the printed
before/after row is pasted into EXPERIMENTS.md §Perf.
"""

# XLA device-count forcing must precede any jax import (dryrun does it).
from repro.launch.dryrun import lower_cell  # noqa: E402  (sets XLA_FLAGS)

import argparse   # noqa: E402
import ast        # noqa: E402
import json       # noqa: E402
from pathlib import Path  # noqa: E402

PEAK_FLOPS, HBM_BW, LINK_BW = 197e12, 819e9, 50e9


def terms(res):
    ha = res["hlo_analysis_per_device"]
    return (ha["flops"] / PEAK_FLOPS,
            ha["bytes_accessed"] / HBM_BW,
            ha["collectives"]["wire_bytes"] / LINK_BW)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE", help="ModelConfig override")
    ap.add_argument("--n-micro", type=int, default=8)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--baseline", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    res = lower_cell(args.arch, args.shape, args.mesh == "multi",
                     n_micro=args.n_micro, overrides=overrides)
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    res["overrides"] = overrides
    res["n_micro"] = args.n_micro
    res["tag"] = args.tag
    fp = outdir / f"{args.arch}_{args.shape}_{args.tag}.json"
    fp.write_text(json.dumps(res, indent=1))

    base_fp = Path(args.baseline) / f"{args.arch}_{args.shape}_{args.mesh}.json"
    if base_fp.exists():
        base = json.loads(base_fp.read_text())
        bc, bm, bx = terms(base)
        print(f"baseline : compute={bc:8.3f}s memory={bm:8.3f}s "
              f"collective={bx:8.3f}s  dominant={max(('c',bc),('m',bm),('x',bx), key=lambda t:t[1])[0]}")
    nc, nm, nx = terms(res)
    print(f"{args.tag:9s}: compute={nc:8.3f}s memory={nm:8.3f}s "
          f"collective={nx:8.3f}s")
    if base_fp.exists():
        print(f"delta    : compute={nc/bc if bc else 0:.2f}x "
              f"memory={nm/bm if bm else 0:.2f}x "
              f"collective={nx/bx if bx else 0:.2f}x")
    ma = res.get("memory_analysis", {})
    hbm = (ma.get("argument_size_in_bytes", 0) + ma.get("temp_size_in_bytes", 0)) / 1e9
    print(f"hbm/dev  : {hbm:.1f} GB   compile: {res.get('compile_s')}s")


if __name__ == "__main__":
    main()
